"""Financial Analyst workflow (paper Fig. 9a): NALAR vs a sticky-session
baseline on the same emulated cluster, showing the K,V-cache-migration win.

    PYTHONPATH=src python examples/financial_analyst.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.workloads import run_financial, system_config

if __name__ == "__main__":
    print("Financial Analyst workflow — stateful sessions, heavy-tailed "
          "context lengths, HoL blocking at the shared LLM engines\n")
    for name in ("nalar", "autogen", "crewai"):
        r = run_financial(system_config(name), rps=1.5, n_sessions=40,
                          seed=42)
        print(f"  {name:8s} avg={r['avg']:6.2f}s p50={r['p50']:6.2f}s "
              f"p95={r['p95']:6.2f}s p99={r['p99']:6.2f}s "
              f"migrations={r['migrations']}")
    print("\nNALAR's HoL-mitigation policy migrates waiting sessions (and "
          "their K,V caches)\nto idle engine instances; sticky baselines "
          "leave them queued behind long requests.")
