"""Quickstart: define agents as plain Python, deploy under NALAR, run a
request — the paper's Fig. 3/Fig. 4 in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (AgentSpec, Directives, FixedLatency, LLMLatency,
                        NalarRuntime, deployment, emulated)
from repro.core.runtime import current_runtime


def build_runtime() -> NalarRuntime:
    rt = NalarRuntime(simulate=True,
                      nodes={"n0": {"GPU": 4, "CPU": 16},
                             "n1": {"GPU": 4, "CPU": 16}})

    # --- agents: ordinary callables + latency models (stub-generated) ----
    rt.register_agent(AgentSpec(
        name="planner",
        methods={"plan": emulated(
            LLMLatency(base=0.3, jitter_sigma=0.0),
            lambda prompt: [f"{prompt} :: subtask {i}" for i in range(3)])},
        directives=Directives(max_instances=2, resources={"GPU": 1}),
    ))

    def implement_and_test(task):
        """Composite agent (Fig. 3): calls a tool + another agent — these
        look like local calls but return futures under the hood."""
        rt = current_runtime()
        docs = rt.stub("documentation").get(task)
        code = f"code[{task} | {docs.value()}]"
        verdict = rt.stub("tester").unit_test(code)
        return verdict.value(), code

    rt.register_agent(AgentSpec(
        name="developer",
        methods={"implement_and_test": implement_and_test},
        directives=Directives(max_instances=4, resources={"GPU": 1}),
    ), instances=2)

    rt.register_agent(AgentSpec(
        name="documentation",
        methods={"get": emulated(FixedLatency(0.05),
                                 lambda t: f"docs({t[-9:]})")},
        directives=Directives(resources={"CPU": 1}),
    ))
    rt.register_agent(AgentSpec(
        name="tester",
        methods={"unit_test": emulated(FixedLatency(0.4),
                                       lambda c: "Pass")},
        directives=Directives(max_instances=2, resources={"CPU": 1}),
    ), instances=2)
    return rt


def main(prompt: str, max_retries: int = 3):
    """The driver program (Fig. 4): plain Python + transparent futures."""
    rt = current_runtime()
    subtasks = rt.stub("planner").plan(prompt).value()   # blocks here only
    futures = [rt.stub("developer").implement_and_test(t) for t in subtasks]
    results = []
    for i, f in enumerate(futures):
        verdict, code = f.value()
        retries = 0
        while verdict != "Pass" and retries < max_retries:
            verdict, code = rt.stub("developer").implement_and_test(
                subtasks[i]).value()
            retries += 1
        results.append(code)
    return results


if __name__ == "__main__":
    rt = build_runtime()
    out = deployment.main(main, "Enable OAuth login for the website",
                          runtime=rt)
    print("virtual time:", round(rt.kernel.now(), 3), "s")
    for line in out:
        print(" ", line)
    print("request summary:", rt.telemetry.summary())
