"""Three REAL engine replicas behind one agent type, with live migration.

The tentpole demo of the `EnginePool`: N `InferenceEngine` replicas (reduced
qwen3-0.6b, CPU JAX) are the N instances of a single `llm` agent type, so
the paper's control machinery drives real execution end-to-end —

1. concurrent sessions spread across replicas (least-ETA default routing);
2. follow-up turns stick to the replica holding the session's KV cache and
   send only their new suffix (Router KV locality, §4.3.2);
3. a live `migrate(session, src, dst)` replays the session transcript onto
   the destination engine (one replay prefill, visible in its
   prefill-token telemetry), re-homes the KV registry, and the session's
   next turn is a *warm* continuation on the new replica.

The pool is heterogeneous on purpose: the last replica runs half the batch
width, and everything still works because migration moves tokens, not
cache pages.

    PYTHONPATH=src python examples/engine_pool_workflow.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import PolicyChain, deployment
from repro.core.runtime import current_runtime
from repro.workloads.router import build_pool_runtime


def turn(text: str):
    rt = current_runtime()
    return rt.stub("llm").generate(text, _hint={"out_tokens": 5}) \
             .value(timeout=300)


def main() -> None:
    print("[pool] building 3-replica EnginePool (reduced qwen3-0.6b, CPU)...")
    rt = build_pool_runtime(replicas=3, max_new_tokens=5,
                            policy=PolicyChain(), heterogeneous=True)
    pool = rt.engine_backends["llm"]
    print(f"[pool] replicas: {pool.instance_ids}")
    t0 = time.perf_counter()

    # -- 1+2: concurrent sessions, sticky warm follow-ups -------------------
    results = {}

    def session_driver(tag: str):
        r1 = turn(f"session {tag} opening question with context")
        r2 = turn(f"{tag} follow up")
        return r1, r2

    rt.start()
    # stagger arrivals: least-ETA then sees earlier sessions in flight and
    # spreads the cold starts (simultaneous arrivals all route before any
    # lands, which ties every replica at zero load)
    for i, tag in enumerate(("alpha", "beta", "gamma")):
        rt.submit_request(session_driver, tag, delay=i * 0.4,
                          on_done=lambda out, err, t=tag:
                          results.__setitem__(t, (out, err)))
    time.sleep(3 * 0.4 + 0.5)          # let every arrival timer fire
    rt.run()
    used = set()
    for tag, (out, err) in sorted(results.items()):
        assert err is None, f"session {tag} failed: {err}"
        r1, r2 = out
        used.add(r1.engine_id)
        print(f"  {tag}: turn1 on {r1.engine_id} (sent {r1.prompt_tokens}), "
              f"turn2 on {r2.engine_id} (sent {r2.prompt_tokens}, "
              f"reused {r2.prefix_reused_tokens})")
        assert r1.engine_id == r2.engine_id, "follow-up left its KV home"
        assert r2.prefix_reused_tokens > 0, "follow-up was not warm"
    print(f"[pool] {len(used)} distinct replicas served the opening turns")

    # -- 3: live migration with transcript replay ---------------------------
    src = results["alpha"][0][0].engine_id     # alpha's home replica
    # alpha's session id: the registry knows each session's cache home
    sid = next(s for s in rt.sessions._sessions
               if (info := rt.kv_registry.lookup(s)) is not None
               and info.instance_id == src)
    dst = next(i for i in pool.instance_ids if i != src)
    dst_engine = pool.bridge_of(dst).engine
    pt_before = dst_engine.metrics.prefill_tokens

    n = pool.migrate_session(sid, src, dst)
    replayed = dst_engine.metrics.prefill_tokens - pt_before
    print(f"[pool] migrate {sid}: {src} -> {dst} "
          f"(returned {n}, replayed {replayed} prefill tokens)")
    assert n >= 1 and replayed > 0, "transcript replay did not happen"

    pt_after_replay = dst_engine.metrics.prefill_tokens
    r3 = deployment.main(turn, "post migration follow up",
                         runtime=rt, session=sid)
    print(f"[pool] post-migration turn on {r3.engine_id}: "
          f"sent {r3.prompt_tokens}, reused {r3.prefix_reused_tokens}, "
          f"dst prefilled {dst_engine.metrics.prefill_tokens - pt_after_replay} "
          f"more tokens")
    assert r3.engine_id == dst, "follow-up did not land on the destination"
    assert r3.prefix_reused_tokens > 0, \
        "destination did not reuse the replayed transcript"
    assert dst_engine.metrics.prefill_tokens == pt_after_replay, \
        "warm continuation should prefill nothing beyond the replay"
    assert pool.migrate_session(sid, src, dst) == 0, \
        "double-migrate must be a no-op"

    wall = time.perf_counter() - t0
    print(f"[pool] stats: {pool.stats}")
    print(f"[pool] kv-registry reuse: {rt.kv_registry.stats}")
    rt.shutdown()
    print(f"[pool] OK in {wall:.1f}s")


if __name__ == "__main__":
    main()
