"""Multi-step agent workflow executing on a REAL serving engine.

This is the tentpole demo of the runtime/serving bridge: the same router
workflow the paper benchmarks under emulation (workloads/router.py), but with
``NalarRuntime(simulate=False)`` and the chat/code branch agents backed by
actual ``repro.serving.InferenceEngine`` instances (reduced qwen3-0.6b, CPU
JAX, continuous batching + paged KV).  Stub calls create ordinary NALAR
futures; the EngineMethod backend dispatches them into the engine's batching
queue and completion events resolve them.

Watch the engine telemetry: turns 2..N of each session hit the session's KV
cache (prefix_hits), so the engine prefills only the new tokens — the
managed-state / KV-registry contract of §4.3.2 made real.

    PYTHONPATH=src python examples/real_engine_workflow.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import deployment
from repro.core.runtime import current_runtime
from repro.workloads.router import build_engine_runtime


TURNS = [
    ("chat", "please summarize the planning discussion so far"),
    ("chat", "now expand on the second point with more detail"),
    ("code", "write code for the parser we just discussed"),
    ("code", "add code handling the empty input edge case"),
]


def agent_session() -> list:
    """One user session: four dependent turns through router -> branch LLM.

    Every turn routes through the classifier, then generates on the real
    engine.  All turns share the driver's session id, so the runtime pins
    them to the engine instance holding the session's KV cache.
    """
    rt = current_runtime()
    results = []
    for i, (_, text) in enumerate(TURNS):
        query = f"{text} (turn {i})"
        branch = rt.stub("router").classify(query).value(timeout=60)
        agent = "code_llm" if branch == "code" else "chat_llm"
        r = rt.stub(agent).generate(query, _hint={"out_tokens": 6}) \
              .value(timeout=600)
        results.append((agent, r))
    return results


def main() -> None:
    print("[real-engine] building runtime (reduced qwen3-0.6b on CPU)...")
    rt = build_engine_runtime(max_new_tokens=6)
    t0 = time.perf_counter()
    results = deployment.main(agent_session, runtime=rt)
    wall = time.perf_counter() - t0

    print(f"[real-engine] session of {len(results)} turns in {wall:.1f}s")
    for i, (agent, r) in enumerate(results):
        print(f"  turn {i}: {agent:9s} -> {len(r.tokens)} tokens, "
              f"sent {r.prompt_tokens}, reused {r.prefix_reused_tokens} "
              f"prefix tokens ({r.engine_id})")

    reused = sum(r.prefix_reused_tokens for _, r in results)
    assert reused > 0, "expected same-session turns to reuse prefix KV"
    for name, bridge in rt.engine_backends.items():
        t = bridge.telemetry()
        print(f"[real-engine] {name}: prefills={t['prefills']} "
              f"prefill_tokens={t['prefill_tokens']} "
              f"prefix_hits={t['prefix_hits']} "
              f"tokens_generated={t['tokens_generated']}")
    print(f"[real-engine] kv-registry reuse stats: {rt.kv_registry.stats}")
    print(f"[real-engine] request trace: "
          f"{[s.agent_type for s in rt.telemetry.requests[next(iter(rt.telemetry.requests))].stages]}")
    rt.shutdown()
    print("[real-engine] OK")


if __name__ == "__main__":
    main()
