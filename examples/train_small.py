"""Train a ~small language model for a few hundred steps on CPU with the
full training substrate (synthetic corpus, AdamW + cosine, checkpointing).

Default is a reduced qwen3-family config sized for CPU minutes; pass
--steps/--dmodel to scale up (the same code path trains the full configs on
the production mesh via repro.launch.train).

    PYTHONPATH=src python examples/train_small.py --steps 200
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.training import checkpoint, train


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-0.6b")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--dmodel", type=int, default=0)
    p.add_argument("--ckpt", default="/tmp/repro_train_small.ckpt")
    args = p.parse_args()

    cfg = get_smoke_config(args.arch)
    if args.dmodel:
        cfg = cfg.replace(d_model=args.dmodel)
    model = build_model(cfg)
    print(f"[train] {cfg.arch_id}: L={cfg.n_layers} d={cfg.d_model} "
          f"V={cfg.vocab_size}")

    def log(step, metrics):
        print(f"[train] step {step:4d} loss={metrics['loss']:.4f} "
              f"gnorm={metrics['grad_norm']:.2f} lr={metrics['lr']:.2e}")

    params, result = train(model, steps=args.steps, batch_size=args.batch,
                           seq_len=args.seq, peak_lr=1e-3, warmup=20,
                           log_fn=log, log_every=20)
    print(f"[train] {result.steps} steps in {result.wall_seconds:.1f}s; "
          f"loss {result.first_loss:.3f} -> {result.last_loss:.3f}")
    n = checkpoint.save(args.ckpt, params)
    print(f"[train] checkpoint: {args.ckpt} ({n / 1e6:.1f} MB)")
    assert result.last_loss < result.first_loss, "loss must decrease"
    print("[train] OK")


if __name__ == "__main__":
    main()
