"""End-to-end serving driver: a REAL JAX model served with batched requests
through the NALAR-integrated inference engine (the paper's kind is serving,
so this is the deliverable-(b) end-to-end driver).

Two engine instances (NALAR agent instances) serve a reduced qwen3-family
model with continuous batching, paged KV cache, session prefix reuse, and a
NALAR-driven session migration between engines mid-run.

    PYTHONPATH=src python examples/serve_engine.py [--arch qwen3-0.6b]
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import KVRegistry
from repro.models import build_model
from repro.serving import InferenceEngine, Request, SamplingParams


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen3-0.6b")
    p.add_argument("--requests", type=int, default=12)
    p.add_argument("--max-new", type=int, default=12)
    args = p.parse_args()

    cfg = get_smoke_config(args.arch)
    print(f"[serve] arch={cfg.arch_id} (reduced, CPU) vocab={cfg.vocab_size}")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    registry = KVRegistry()
    engines = [InferenceEngine(model, params, max_batch=4, max_seq=128,
                               kv_registry=registry,
                               instance_id=f"llm:{i}") for i in range(2)]

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(6, 24))).tolist()
        r = Request.make(prompt, session_id=f"user{i % 4}",
                         sampling=SamplingParams(max_new_tokens=args.max_new))
        engines[i % 2].submit(r)
        reqs.append(r)

    # continuous batching across both engines until drained
    while not all(r.finished for r in reqs):
        for e in engines:
            e.step()
    wall = time.perf_counter() - t0

    done = sum(r.finished for r in reqs)
    toks = sum(len(r.generated) for r in reqs)
    print(f"[serve] {done}/{len(reqs)} requests, {toks} tokens "
          f"in {wall:.1f}s ({toks / wall:.1f} tok/s on CPU)")
    for e in engines:
        print(f"[serve] {e.instance_id} telemetry: {e.telemetry()}")

    # NALAR K,V-cache migration: move user0's session from llm:0 to llm:1
    payload = engines[0].pool.export_session("user0")
    if payload is not None:
        engines[1].pool.import_session("user0", payload)
        moved = registry.migrate("user0", "llm:0", "llm:1")
        print(f"[serve] migrated session user0 ({moved} cached tokens) "
              f"llm:0 -> llm:1")
        follow = engines[1].generate(
            rng.integers(0, cfg.vocab_size, size=6).tolist(),
            session_id="user0",
            sampling=SamplingParams(max_new_tokens=6))
        print(f"[serve] follow-up on llm:1 reused "
              f"{follow.prefix_reused_tokens} prefix tokens "
              f"(prefix_hits={engines[1].metrics.prefix_hits})")
    print("[serve] OK")


if __name__ == "__main__":
    main()
