"""Software-engineering workflow (paper Fig. 1/9c): recursive retries,
per-agent LLMs, dynamic reallocation + LPT retry prioritization.

    PYTHONPATH=src python examples/software_engineering.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import LPTPolicy, PolicyChain
from repro.workloads import run_swe, system_config
from repro.workloads.baselines import SystemConfig

if __name__ == "__main__":
    print("SWE workflow — PM decomposes tasks; developers implement with "
          "docs lookups; testers gate; failures requeue (recursion)\n")
    for name in ("nalar", "autogen", "crewai"):
        r = run_swe(system_config(name), n_requests=10, seed=3)
        print(f"  {name:8s} avg={r['avg']:6.2f}s p99={r['p99']:6.2f}s "
              f"makespan={r['makespan']:6.2f}s migrations={r['migrations']}")

    # §6.2: add the 12-line LPT policy on top of NALAR's defaults
    nalar = system_config("nalar")
    lpt_cfg = SystemConfig("nalar+lpt",
                           PolicyChain(nalar.policy, LPTPolicy()),
                           sticky_sessions=False, dynamic_resources=True)
    r = run_swe(lpt_cfg, n_requests=10, seed=3)
    print(f"  {'nalar+lpt':8s} avg={r['avg']:6.2f}s p99={r['p99']:6.2f}s "
          f"makespan={r['makespan']:6.2f}s  (retries first — §6.2)")
