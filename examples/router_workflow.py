"""Router-based workflow (paper Fig. 9b): branch mix shifts mid-run; NALAR
reassigns GPU capacity between the chat and code pools.

    PYTHONPATH=src python examples/router_workflow.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.workloads import run_router, system_config

if __name__ == "__main__":
    print("Router workflow — the query mix flips from 90% chat to 90% code "
          "halfway through (Azure-trace-style imbalance)\n")
    for name in ("nalar", "autogen", "crewai"):
        r = run_router(system_config(name), rps=90.0, duration=24.0, seed=7)
        print(f"  {name:8s} n={r['n']:4.0f} avg={r['avg']:5.2f}s "
              f"p99={r['p99']:6.2f}s timeout_rate={r['timeout_rate']:.3f}")
    print("\nNALAR kills idle chat engines and provisions code engines when "
          "the mix flips;\nstatic splits leave the hot branch overloaded.")
