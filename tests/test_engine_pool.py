"""EnginePool: policy-driven routing + live session migration over N real
engine replicas.

Covers the tentpole contract:
 * replicas are ordinary NALAR instances — routing modes and KV affinity
   resolve to concrete engines;
 * ``migrate(session, src, dst)`` physically replays the transcript onto
   the destination (its prefill telemetry shows the one-time rebuild) and
   the next session call is a warm continuation there;
 * edge cases: in-flight futures defer the move, a dead destination falls
   back to a live replica, and double-migrate is a no-op.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import NalarRuntime, PolicyChain, deployment
from repro.core.runtime import current_runtime
from repro.models import build_model
from repro.serving import (GenerationResult, InferenceEngine, SamplingParams,
                           register_engine_pool)


@pytest.fixture(scope="module")
def model_setup():
    cfg = get_smoke_config("qwen3_0_6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def make_pool_runtime(model, params, replicas=3, max_new_tokens=3,
                      max_seq=64):
    # quiet global policy: these tests assert exact routing/migration
    # behaviour, so the default load-balance/HoL chain must stay out of it
    rt = NalarRuntime(simulate=False, policy=PolicyChain(),
                      nodes={"n0": {"GPU": replicas, "CPU": 8}})
    engines = [InferenceEngine(model, params, max_batch=2, max_seq=max_seq)
               for _ in range(replicas)]
    register_engine_pool(
        rt, "llm", engines,
        sampling=SamplingParams(max_new_tokens=max_new_tokens),
        resources={"GPU": 1})
    return rt, rt.engine_backends["llm"]


def run_turn(rt, sid, text):
    def driver():
        return current_runtime().stub("llm").generate(text).value(timeout=300)
    kwargs = {} if sid is None else {"session": sid}
    return deployment.main(driver, runtime=rt, **kwargs)


def session_of(rt):
    return next(iter(rt.sessions._sessions))


def test_round_robin_spreads_then_affinity_sticks(model_setup):
    """Turn 1 of each session round-robins across replicas; turn 2 follows
    the KV cache (Router locality precedes the default mode)."""
    cfg, model, params = model_setup
    rt, pool = make_pool_runtime(model, params)
    rt.router.mode = "round_robin"

    homes = []
    for i in range(3):
        r1 = run_turn(rt, None, f"session {i} opening line")
        sid = [s for s in rt.sessions._sessions][-1]
        r2 = run_turn(rt, sid, "short follow up")
        assert isinstance(r1, GenerationResult)
        assert r2.engine_id == r1.engine_id      # sticky via KV locality
        assert r2.prefix_reused_tokens > 0       # warm continuation
        homes.append(r1.engine_id)
    assert len(set(homes)) == 3                  # all replicas exercised
    assert set(homes) == set(pool.instance_ids)
    rt.shutdown()


def test_migrate_replays_transcript_and_next_turn_is_warm(model_setup):
    cfg, model, params = model_setup
    rt, pool = make_pool_runtime(model, params)

    r1 = run_turn(rt, None, "the quick brown fox jumps over")
    sid = session_of(rt)
    r2 = run_turn(rt, sid, "and keeps running")
    src = r2.engine_id
    dst = next(i for i in pool.instance_ids if i != src)
    dst_engine = pool.bridge_of(dst).engine
    src_pool_pages = pool.bridge_of(src).engine.pool

    pt0 = dst_engine.metrics.prefill_tokens
    moved = pool.migrate_session(sid, src, dst)
    assert moved >= 1
    replay = dst_engine.metrics.prefill_tokens - pt0
    assert replay > 0                            # physical rebuild happened
    # registry re-homed reuse expectations
    assert rt.kv_registry.lookup(sid).instance_id == dst
    # source pool freed the session's pages (migrate_out hint)
    assert src_pool_pages.session(sid) is None

    pt1 = dst_engine.metrics.prefill_tokens
    r3 = run_turn(rt, sid, "post migration turn")
    assert r3.engine_id == dst                   # routing re-homed
    assert r3.prefix_reused_tokens > 0           # replayed transcript reused
    assert dst_engine.metrics.prefill_tokens == pt1   # no second rebuild

    # double-migrate is a no-op: no extra replay prefill
    assert pool.migrate_session(sid, src, dst) == 0
    assert dst_engine.metrics.prefill_tokens == pt1
    assert pool.stats["migrations"] == 1
    assert pool.stats["migrations_noop"] >= 1
    rt.shutdown()


def test_migrate_with_inflight_future_defers_until_resolution(model_setup):
    """A migration issued while the session has a call on the source engine
    must not move anything until that call resolves."""
    cfg, model, params = model_setup
    rt, pool = make_pool_runtime(model, params)

    run_turn(rt, None, "warm up this session first")
    sid = session_of(rt)
    src = rt.kv_registry.lookup(sid).instance_id
    dst = next(i for i in pool.instance_ids if i != src)
    src_bridge = pool.bridge_of(src)
    dst_engine = pool.bridge_of(dst).engine

    # simulate an in-flight same-session call on the source bridge
    with src_bridge._cv:
        src_bridge._session_active.add(sid)
    pt0 = dst_engine.metrics.prefill_tokens
    assert pool.migrate_session(sid, src, dst) == 1   # scheduled, not done
    assert pool.stats["migrations_deferred"] == 1
    assert rt.kv_registry.lookup(sid).instance_id == src   # nothing moved
    assert dst_engine.metrics.prefill_tokens == pt0        # no replay yet

    # the in-flight call resolves -> the deferred migration runs
    src_bridge._advance_session(sid)
    assert rt.kv_registry.lookup(sid).instance_id == dst
    assert dst_engine.metrics.prefill_tokens > pt0
    assert pool.stats["migrations"] == 1

    r = run_turn(rt, sid, "after deferred migration")
    assert r.engine_id == dst
    assert r.prefix_reused_tokens > 0
    rt.shutdown()


def test_migrate_to_dead_replica_falls_back_to_live_one(model_setup):
    cfg, model, params = model_setup
    rt, pool = make_pool_runtime(model, params)

    run_turn(rt, None, "place this session somewhere")
    sid = session_of(rt)
    src = rt.kv_registry.lookup(sid).instance_id
    others = [i for i in pool.instance_ids if i != src]
    dead, alive = others[0], others[1]
    rt.kill_instance(dead)
    assert not rt.instance(dead).alive

    moved = pool.migrate_session(sid, src, dead)
    assert moved >= 1
    assert pool.stats["migrations_fallback"] == 1
    home = rt.kv_registry.lookup(sid).instance_id
    assert home == alive                          # consistent fallback

    r = run_turn(rt, sid, "retry lands on the fallback")
    assert r.engine_id == alive
    assert r.prefix_reused_tokens > 0

    # unknown destination id behaves the same way (no crash, live placement)
    moved2 = pool.migrate_session(sid, alive, "llm:n0/does-not-exist")
    assert moved2 >= 1
    assert rt.kv_registry.lookup(sid).instance_id != alive
    rt.shutdown()


def test_deferred_migration_revalidates_dead_destination(model_setup):
    """A destination that dies while the migration is deferred must be
    re-resolved at fire time, not replayed onto a corpse."""
    cfg, model, params = model_setup
    rt, pool = make_pool_runtime(model, params)

    run_turn(rt, None, "seed the session transcript")
    sid = session_of(rt)
    src = rt.kv_registry.lookup(sid).instance_id
    others = [i for i in pool.instance_ids if i != src]
    dst, fallback = others[0], others[1]
    src_bridge = pool.bridge_of(src)

    with src_bridge._cv:
        src_bridge._session_active.add(sid)
    assert pool.migrate_session(sid, src, dst) == 1      # deferred
    rt.kill_instance(dst)                                 # dies in the window
    src_bridge._advance_session(sid)                      # in-flight resolves

    home = rt.kv_registry.lookup(sid).instance_id
    assert home == fallback                               # re-resolved live
    r = run_turn(rt, sid, "post migration turn")
    assert r.engine_id == fallback
    assert r.prefix_reused_tokens > 0
    rt.shutdown()


def test_pool_rejected_on_sim_kernel(model_setup):
    cfg, model, params = model_setup
    rt = NalarRuntime(simulate=True)
    engine = InferenceEngine(model, params, max_batch=2, max_seq=64)
    with pytest.raises(RuntimeError, match="simulate=False"):
        register_engine_pool(rt, "llm", [engine])
    rt.shutdown()


def test_hard_kill_recovers_sessions_and_retries_inflight(model_setup):
    """Fault injection on a real pool: ``kill_instance(..., hard=True)``
    fails the dead replica's in-flight work into the retry ladder and
    recovers its sessions on a survivor by transcript replay, so the retried
    call completes there and follow-ups resume warm."""
    import time

    cfg, model, params = model_setup
    rt, pool = make_pool_runtime(model, params, replicas=2)
    rt.apply_directives("llm", {"max_retries": 1})

    r1 = run_turn(rt, None, "hello from a doomed replica")
    sid = session_of(rt)
    victim = rt.kv_registry.lookup(sid).instance_id
    survivor = next(i for i in pool.instance_ids if i != victim)
    victim_bridge = pool.bridge_of(victim)
    survivor_engine = pool.bridge_of(survivor).engine

    # hold the session "in flight" on the victim so the follow-up call
    # parks in its bridge queue (deterministic in-flight loss)
    with victim_bridge._cv:
        victim_bridge._session_active.add(sid)
    done = {}
    rt.start()
    rt.submit_request(
        lambda: rt.stub("llm").generate("the follow up").value(timeout=240),
        session=sid, on_done=lambda o, e: done.update(out=o, err=e))
    deadline = time.time() + 120
    while time.time() < deadline:
        with victim_bridge._cv:
            if victim_bridge._session_q.get(sid):
                break
        time.sleep(0.02)
    assert victim_bridge._session_q.get(sid)

    pt0 = survivor_engine.metrics.prefill_tokens
    rt.kill_instance(victim, hard=True)
    rt.run()

    assert done["err"] is None                       # retried to completion
    assert done["out"].engine_id == survivor
    assert rt.kv_registry.lookup(sid).instance_id == survivor
    assert survivor_engine.metrics.prefill_tokens > pt0   # transcript replay
    assert pool.stats["replica_failures"] == 1
    assert pool.stats["failed_inflight"] >= 1
    assert pool.stats["sessions_recovered"] >= 1
    assert victim in rt.blacklist
    assert not rt.instance(victim).alive

    r3 = run_turn(rt, sid, "and one more turn")      # routing re-homed
    assert r3.engine_id == survivor
    rt.shutdown()


def test_cancelled_session_queued_call_never_hits_engine(model_setup):
    """A future cancelled while parked in the bridge's session queue must be
    skipped at dequeue, not submitted for a full generation whose result
    would then be discarded."""
    import time

    from repro.core import FutureCancelled

    cfg, model, params = model_setup
    rt, pool = make_pool_runtime(model, params, replicas=2)

    run_turn(rt, None, "open the session")
    sid = session_of(rt)
    home = rt.kv_registry.lookup(sid).instance_id
    bridge = pool.bridge_of(home)

    with bridge._cv:
        bridge._session_active.add(sid)      # pretend a call is in flight
    done = {}
    rt.start()
    rt.submit_request(
        lambda: rt.stub("llm").generate("never runs").value(timeout=240),
        session=sid, on_done=lambda o, e: done.update(out=o, err=e))
    deadline = time.time() + 120
    while time.time() < deadline:
        with bridge._cv:
            if bridge._session_q.get(sid):
                break
        time.sleep(0.02)
    fut = bridge._session_q[sid][0][0]
    assert rt.cancel_future(fut, "user abandoned")

    pt0 = bridge.engine.metrics.prefill_tokens
    bridge._advance_session(sid)             # the in-flight call "resolves"
    rt.run()
    assert isinstance(done["err"], FutureCancelled)
    assert bridge.engine.metrics.prefill_tokens == pt0   # never submitted
    with bridge._cv:
        assert sid not in bridge._session_active
    rt.shutdown()


def test_engine_warm_session_populates_cache(model_setup):
    """The replay primitive in isolation: warm_session prefills tokens into
    the session pool so a later request resumes instead of prefilling."""
    cfg, model, params = model_setup
    engine = InferenceEngine(model, params, max_batch=2, max_seq=64)
    toks = list(range(1, 20))
    cached = engine.warm_session("s-warm", toks)
    assert cached >= len(toks)
    assert engine.pool.session("s-warm") is not None
    pt = engine.metrics.prefill_tokens
    req = engine.generate([7, 8, 9], session_id="s-warm",
                          sampling=SamplingParams(max_new_tokens=2))
    assert req.prefix_reused_tokens == cached     # resumed, not re-prefilled
    assert engine.metrics.prefill_tokens == pt
    assert engine.warm_session("s-warm", []) == 0


# ------------------------------------------------- page-shipping migration
def test_migrate_ships_pages_and_matches_replay(model_setup):
    """Page-shipping migrate must be a pure optimization: same destination
    cache (numerically) and identical follow-up decode tokens as the
    transcript-replay path, at a fraction of the prefill cost."""
    cfg, model, params = model_setup

    def one_run(page_migration):
        rt, pool = make_pool_runtime(model, params)
        pool.page_migration = page_migration
        r1 = run_turn(rt, None, "the quick brown fox jumps over")
        sid = session_of(rt)
        src = r1.engine_id
        dst = next(i for i in pool.instance_ids if i != src)
        dst_engine = pool.bridge_of(dst).engine
        pt0 = dst_engine.metrics.prefill_tokens
        assert pool.migrate_session(sid, src, dst) >= 1
        prefilled = dst_engine.metrics.prefill_tokens - pt0
        k, v, tokens = dst_engine.pool.gather_contiguous(sid, 64)
        dst_engine.pool.check_invariants()
        r2 = run_turn(rt, sid, "and keeps running")
        out = (np.asarray(k[:, :tokens]).copy(),
               np.asarray(v[:, :tokens]).copy(), tokens,
               list(r2.tokens), prefilled, dict(pool.stats),
               list(pool.migrations))
        rt.shutdown()
        return out

    k_r, v_r, t_r, gen_r, cost_r, stats_r, mig_r = one_run(False)
    k_p, v_p, t_p, gen_p, cost_p, stats_p, mig_p = one_run(True)

    # replay path untouched by the toggle
    assert stats_r["migrations_page_shipped"] == 0
    assert mig_r[0]["mode"] == "replay"
    # shipped path actually shipped, and prefilled strictly less
    assert stats_p["migrations_page_shipped"] == 1
    assert stats_p["pages_shipped"] >= 1
    assert mig_p[0]["mode"] == "pages"
    assert 0 < cost_p < cost_r
    # same destination state: cache covers the same tokens with the same
    # values, and the next turn decodes the same tokens
    assert t_r == t_p
    np.testing.assert_allclose(k_p, k_r, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(v_p, v_r, rtol=2e-4, atol=2e-4)
    assert gen_p == gen_r


def test_migrate_page_ship_deferred_while_inflight(model_setup):
    """The deferred (migrate-while-inflight) path also ships pages once the
    in-flight call resolves, with the same warm follow-up."""
    cfg, model, params = model_setup
    rt, pool = make_pool_runtime(model, params)

    run_turn(rt, None, "warm up this session first")
    sid = session_of(rt)
    src = rt.kv_registry.lookup(sid).instance_id
    dst = next(i for i in pool.instance_ids if i != src)
    src_bridge = pool.bridge_of(src)
    dst_engine = pool.bridge_of(dst).engine

    with src_bridge._cv:
        src_bridge._session_active.add(sid)
    pt0 = dst_engine.metrics.prefill_tokens
    assert pool.migrate_session(sid, src, dst) == 1      # deferred
    assert pool.stats["migrations_deferred"] == 1
    assert pool.stats["migrations_page_shipped"] == 0    # nothing yet

    src_bridge._advance_session(sid)                     # resolves -> fires
    assert rt.kv_registry.lookup(sid).instance_id == dst
    assert pool.stats["migrations_page_shipped"] == 1
    assert pool.stats["pages_shipped"] >= 1
    # the resident prefix covered all but the transcript tail: the rebuild
    # cost is bounded by a page, not the whole transcript
    transcript = pool.bridge_of(dst).transcript.tokens(sid)
    assert 0 < dst_engine.metrics.prefill_tokens - pt0 < len(transcript)
    dst_engine.pool.check_invariants()

    r = run_turn(rt, sid, "after deferred migration")
    assert r.engine_id == dst
    assert r.prefix_reused_tokens > 0
    rt.shutdown()


def test_warm_session_shared_prefix_skips_redundant_prefill(model_setup):
    """Regression for the warm_session waste: re-homing a session whose
    (shared) prefix is already resident must not prefill anything."""
    cfg, model, params = model_setup
    engine = InferenceEngine(model, params, max_batch=2, max_seq=64)
    toks = list(range(1, 39))

    warmed = engine.warm_session("first", toks)
    assert warmed == len(toks)
    pf0 = engine.metrics.prefills
    pt0 = engine.metrics.prefill_tokens
    ds0 = engine.metrics.decode_steps

    # a different session with the same transcript: everything resident
    warmed2 = engine.warm_session("second", toks)
    assert warmed2 == len(toks)
    assert engine.metrics.prefills == pf0              # zero prefill steps
    assert engine.metrics.prefill_tokens == pt0        # zero prefill tokens
    assert engine.metrics.decode_steps == ds0          # zero decode steps
    engine.pool.check_invariants()

    # and re-warming the same session is also free
    assert engine.warm_session("first", toks) == len(toks)
    assert engine.metrics.prefill_tokens == pt0
