"""Speculative decoding on the paged data plane + model-tier routing.

The load-bearing invariant: greedy speculative decode is *bitwise* the
non-speculative sequence — same tokens AND same committed cache bytes —
because the verifier is the same fused ``decode_chunk_paged`` program the
plain path runs (chunked == sequential is already pinned), greedy
acceptance walks the in-jit argmax, and the COW append bracket rolls the
rejected tail's reserved pages back before anything is published.

Stochastic verification is property-tested at the sampler level: the
accept-with-p/q, resample-from-residual rule must preserve the target
distribution exactly for point-mass (argmax draft) proposals.
"""

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.model import build_model
from repro.serving.engine import InferenceEngine, Request
from repro.serving.sampler import SamplingParams, speculative_verify, target_probs
from repro.serving.speculative import DraftEngine, truncated_draft

MAX_SEQ = 96
PAGE = 8

_MODELS = {}


def _model(arch):
    if arch not in _MODELS:
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _MODELS[arch] = (model, params)
    return _MODELS[arch]


def _engine(arch, *, spec=False, max_seq=MAX_SEQ, **kw):
    model, params = _model(arch)
    if spec:
        dm, dp = truncated_draft(model, params, 1)
        kw.setdefault("spec_k", 3)
        kw.update(draft_model=dm, draft_params=dp)
    return InferenceEngine(model, params, max_batch=4, max_seq=max_seq,
                           page_size=PAGE, prefill_chunk=4, rng_seed=0, **kw)


def _serve(eng, n_req=6, gen_len=16, temperature=0.0, seed=None):
    cfg = eng.cfg
    sp = SamplingParams(temperature=temperature, max_new_tokens=gen_len,
                        seed=seed)
    reqs = []
    for j in range(n_req):
        prompt = [(7 * j + t) % cfg.vocab_size for t in range(5 + j)]
        r = Request.make(prompt, session_id=f"s{j}", sampling=sp)
        eng.submit(r)
        reqs.append(r)
    while eng.step():
        pass
    return reqs


def _session_bytes(eng, sid):
    k, v, tokens = eng.pool.gather_contiguous(sid, eng.max_seq)
    return np.asarray(k[:, :tokens]), np.asarray(v[:, :tokens]), tokens


# --------------------------------------------- greedy bitwise differential
@pytest.mark.parametrize("arch", ["qwen3_0_6b", "granite_moe_1b_a400m"])
def test_greedy_speculative_matches_baseline_bitwise(arch):
    """Same greedy tokens and same committed K/V bytes, transformer and
    MoE.  (MoE decodes through the dropless dispatch — the capacity impls
    are priority-ordered across the batch, so their drops depend on batch
    composition and no multi-token verify could ever be bitwise.)"""
    base = _engine(arch)
    spec = _engine(arch, spec=True, spec_min_accept=0.0)
    b_reqs = _serve(base)
    s_reqs = _serve(spec)
    assert spec.metrics.spec_rounds > 0
    assert spec.metrics.spec_proposed > 0
    for rb, rs in zip(b_reqs, s_reqs):
        assert rb.generated == rs.generated, rb.session_id
        kb, vb, tb = _session_bytes(base, rb.session_id)
        ks, vs, ts = _session_bytes(spec, rs.session_id)
        assert tb == ts
        np.testing.assert_array_equal(kb, ks)
        np.testing.assert_array_equal(vb, vs)
    base.pool.check_invariants()
    spec.pool.check_invariants()
    # speculation actually paid on the dense config (the MoE smoke's
    # 1-layer draft tracks it too weakly to assert a margin there)
    if arch == "qwen3_0_6b":
        assert spec.metrics.spec_acceptance > 0.15
        assert (spec.metrics.decode_tokens_per_step
                > base.metrics.decode_tokens_per_step)


def test_stochastic_speculative_serves_and_is_reproducible():
    """Seeded stochastic spec decode completes, commits exact provenance,
    and the same seed yields the same tokens on a fresh engine (request
    streams are seeded per-request, independent of batch composition)."""
    outs = []
    for _ in range(2):
        eng = _engine("qwen3_0_6b", spec=True, spec_min_accept=0.0)
        reqs = _serve(eng, n_req=4, temperature=0.8, seed=17)
        eng.pool.check_invariants()
        assert eng.metrics.spec_rounds > 0
        for r in reqs:
            assert len(r.generated) == r.sampling.max_new_tokens
        outs.append([list(r.generated) for r in reqs])
    assert outs[0] == outs[1]


# ------------------------------------------------- sampler-level properties
def test_verify_greedy_walks_argmax_prefix():
    V = 16
    rng = np.random.default_rng(0)
    logits = rng.standard_normal((4, V)).astype(np.float32)
    g = np.argmax(logits, axis=-1)
    sp = SamplingParams(temperature=0.0)
    # full agreement: all 3 drafts + bonus
    toks, m = speculative_verify(logits, [int(x) for x in g[:3]], sp, None)
    assert m == 3 and toks == [int(x) for x in g]
    # divergence at position 1: keep d0, emit argmax correction, stop
    drafts = [int(g[0]), int((g[1] + 1) % V), int(g[2])]
    toks, m = speculative_verify(logits, drafts, sp, None)
    assert m == 1 and toks == [int(g[0]), int(g[1])]


def test_verify_stochastic_preserves_target_distribution():
    """Point-mass proposal, one draft position: the emitted token's law
    must be exactly the target's — accept d w.p. p(d), else resample from
    the renormalized residual, which marginalizes back to p."""
    V = 8
    rng = np.random.default_rng(1)
    logits = np.concatenate([rng.standard_normal((1, V)),
                             rng.standard_normal((1, V))]).astype(np.float32)
    sp = SamplingParams(temperature=0.7)
    p = target_probs(logits, sp)[0]
    d = int(np.argmax(p))                      # what an argmax draft proposes
    counts = np.zeros(V)
    trials = 4000
    for i in range(trials):
        toks, _ = speculative_verify(logits, [d], sp,
                                     jax.random.PRNGKey(i))
        counts[toks[0]] += 1
    tv = 0.5 * np.abs(counts / trials - p).sum()
    assert tv < 0.05, f"total variation {tv:.3f}, p={p}, emp={counts/trials}"


def test_verify_accepts_everything_when_draft_equals_target():
    V = 8
    rng = np.random.default_rng(2)
    logits = rng.standard_normal((3, V)).astype(np.float32)
    sp = SamplingParams(temperature=1.0)
    p = target_probs(logits, sp)
    drafts = [3, 5]
    toks, m = speculative_verify(logits, drafts, sp, jax.random.PRNGKey(0),
                                 draft_probs=p[:2])
    assert m == 2 and toks[:2] == drafts and len(toks) == 3


def test_verify_rejection_never_reemits_pointmass_draft():
    """Residual max(p - q, 0) zeroes the rejected argmax-draft token, so a
    rejection can never resample the very token it just rejected."""
    V = 8
    rng = np.random.default_rng(3)
    sp = SamplingParams(temperature=0.5)
    for i in range(64):
        logits = rng.standard_normal((2, V)).astype(np.float32)
        d = int(np.argmin(logits[0]))          # unlikely draft: often rejected
        toks, m = speculative_verify(logits, [d], sp, jax.random.PRNGKey(i))
        if m == 0:
            assert toks[0] != d
    # and at least some rejections actually occurred in 64 low-p trials
    # (if not, the accept rule is broken in the permissive direction)


# ----------------------------------------------------- draft engine protocol
def test_draft_engine_refuses_windowed_and_recurrent_drafts():
    model, params = _model("starcoder2_15b")    # sliding_window set
    with pytest.raises(ValueError, match="non-windowed"):
        DraftEngine(model, params, max_batch=2, max_seq=32)


def test_draft_engine_propose_rollback_stream_consistency():
    model, params = _model("qwen3_0_6b")
    dm, dp = truncated_draft(model, params, 1)
    assert dm.cfg.n_layers == 1
    eng = DraftEngine(dm, dp, max_batch=2, max_seq=32)
    eng.observe(0, [1, 2, 3])
    props = eng.propose({0: 3})[0]
    assert len(props) == 3
    assert eng._stream[0] == [1, 2, 3] + props
    # verifier kept only the first proposal: stream truncates, pos rewinds
    eng.rollback(0, 4)
    assert eng._stream[0] == [1, 2, 3, props[0]]
    assert int(np.asarray(eng.cache["pos"])[0]) <= 4
    # a fully consumed stream cannot be extended: the engine always
    # observes the verifier's last emission before the next propose
    with pytest.raises(ValueError, match="nothing pending"):
        eng.propose({0: 2})
    # re-proposing after observing the next token is deterministic: the
    # rolled-back cache must behave exactly like a fresh one
    eng.observe(0, [42])
    again = eng.propose({0: 2})[0]
    eng2 = DraftEngine(dm, dp, max_batch=2, max_seq=32)
    eng2.observe(0, [1, 2, 3, props[0], 42])
    assert eng2.propose({0: 2})[0] == again


def test_spec_auto_disables_per_session_when_acceptance_poor():
    eng = _engine("qwen3_0_6b", spec=True,
                  spec_min_accept=1.01,          # unsatisfiable threshold
                  spec_warmup=4)
    reqs = _serve(eng, n_req=2, gen_len=20)
    assert eng.metrics.spec_rounds > 0
    for r in reqs:
        assert r.session_id in eng._spec_off
        assert len(r.generated) == 20            # still served correctly


# ------------------------------------------------ dense-ring fallback stamp
def test_windowed_overflow_stamps_dense_ring_and_serves():
    """A windowed config with max_seq > window cannot ride the paged plane
    (ring wraparound breaks the linear page layout); it must stamp
    ``decode_path == "dense-ring"`` and still serve correctly."""
    model, params = _model("starcoder2_15b")
    W = model.cfg.sliding_window
    assert W and W < 128
    eng = InferenceEngine(model, params, max_batch=2, max_seq=128,
                          page_size=PAGE, prefill_chunk=4)
    assert not eng._paged
    sp = SamplingParams(temperature=0.0, max_new_tokens=6)
    reqs = [eng.generate(list(range(1, 9)), session_id="ring0", sampling=sp),
            eng.generate(list(range(3, 20)), session_id="ring1", sampling=sp)]
    for r in reqs:
        assert r.decode_path == "dense-ring"
        assert len(r.generated) == 6
        assert all(0 <= t < model.cfg.vocab_size for t in r.generated)
    # same config within the window rides the paged plane as before
    paged = InferenceEngine(model, params, max_batch=2, max_seq=W,
                            page_size=PAGE, prefill_chunk=4)
    r = paged.generate(list(range(1, 9)), session_id="p0", sampling=sp)
    assert r.decode_path == "paged"


# ------------------------------------------------- metrics/policy plumbing
def test_spec_and_tier_gauges_reach_instance_view():
    from repro.core.policy import ActionSink, ClusterView, TierRoutePolicy

    view = ClusterView(now=0.0)
    for iid, tier in [("llm:0", "small"), ("llm:1", "large"),
                      ("llm:2", "large")]:
        view.upsert_instance(iid, {
            "agent_type": "llm", "alive": True,
            "engine_tier": tier,
            "engine_spec_acceptance": 0.4,
            "engine_decode_tokens_per_step": 1.8,
        }, default_node="n0", is_live=lambda s: True)
    iv = view.instances["llm:0"]
    assert iv.engine_tier == "small"
    assert iv.engine_spec_acceptance == pytest.approx(0.4)
    assert iv.engine_decode_tokens_per_step == pytest.approx(1.8)

    pol = TierRoutePolicy()
    sink = ActionSink()
    pol.step(view, sink)
    assert [a.kind for a in sink.actions] == ["route_tier"]
    assert sink.actions[0].payload["tiers"] == {
        "small": ["llm:0"], "large": ["llm:1", "llm:2"]}
    # unchanged table: no re-emission next round
    sink2 = ActionSink()
    pol.step(view, sink2)
    assert sink2.actions == []


def test_tier_route_action_installs_router_table():
    from repro.core import NalarRuntime
    from repro.core.controller_global import GlobalController
    from repro.core.policy import ActionSink

    rt = NalarRuntime(simulate=True)
    sink = ActionSink()
    sink.route_tier("llm", {"small": ["llm:0"], "large": ["llm:1"]})
    GlobalController(rt, policy=None).apply(sink)
    assert rt.router._tiers["llm"] == {"small": ["llm:0"],
                                       "large": ["llm:1"]}


def test_distill_draft_improves_argmax_agreement():
    """A few distillation steps on a fixed batch must move the draft's
    argmax toward the target's on that batch (the on-policy objective),
    preserving the param tree structure."""
    import jax.numpy as jnp

    from repro.serving.speculative import distill_draft

    model, params = _model("qwen3_0_6b")
    draft, dparams = truncated_draft(model, params, 1)
    V = model.cfg.vocab_size
    batch = jax.random.randint(jax.random.PRNGKey(5), (8, 16), 1, V)

    def agree(dp):
        tl = model.forward(params, {"tokens": batch})
        tl = tl[0] if isinstance(tl, tuple) else tl
        dl = draft.forward(dp, {"tokens": batch})
        dl = dl[0] if isinstance(dl, tuple) else dl
        return float(jnp.mean(jnp.argmax(dl, -1) == jnp.argmax(tl, -1)))

    before = agree(dparams)
    trained = distill_draft(draft, dparams, model, params,
                            lambda k: batch, steps=40, seed=3)
    assert jax.tree_util.tree_structure(
        trained) == jax.tree_util.tree_structure(dparams)
    assert agree(trained) > before
