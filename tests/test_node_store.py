"""Node store: hashes, CAS, pub/sub, versioning (paper §4.1)."""

from repro.core import NodeStore, StoreCluster


def test_hash_ops():
    s = NodeStore("n0")
    s.hset("k", "f", 1)
    s.hset_many("k", {"g": 2, "h": 3})
    assert s.hget("k", "f") == 1
    assert s.hgetall("k") == {"f": 1, "g": 2, "h": 3}
    assert s.hdel("k", "f")
    assert not s.hdel("k", "f")
    assert s.hget("k", "f", default="d") == "d"


def test_versions_bump_on_write():
    s = NodeStore("n0")
    v0 = s.version("k")
    s.hset("k", "f", 1)
    assert s.version("k") == v0 + 1


def test_cas():
    s = NodeStore("n0")
    s.hset("k", "owner", "a")
    assert not s.cas("k", "owner", "b", "c")
    assert s.cas("k", "owner", "a", "c")
    assert s.hget("k", "owner") == "c"


def test_incr():
    s = NodeStore("n0")
    assert s.incr("m", "count") == 1
    assert s.incr("m", "count", 4) == 5


def test_pubsub_fires_on_write():
    s = NodeStore("n0")
    got = []
    s.subscribe("cmd:x", lambda f, v: got.append((f, v)))
    s.hset("cmd:x", "migrate", {"dst": "y"})
    assert got == [("migrate", {"dst": "y"})]
    s.unsubscribe("cmd:x", s._subs["cmd:x"][0])
    s.hset("cmd:x", "z", 1)
    assert len(got) == 1


def test_keys_prefix_scan():
    s = NodeStore("n0")
    s.hset("metrics:a", "q", 1)
    s.hset("metrics:b", "q", 2)
    s.hset("future:f1", "state", "ready")
    assert sorted(s.keys("metrics:")) == ["metrics:a", "metrics:b"]


def test_cluster_directory():
    c = StoreCluster()
    a = c.get("n0")
    b = c.get("n0")
    assert a is b
    c.get("n1")
    assert sorted(c.nodes()) == ["n0", "n1"]


# ------------------------------------------------------------- delta scans
def test_scan_changed_bootstrap_returns_existing_keys():
    s = NodeStore("n0")
    s.hset("future:f1", "state", "pending")
    s.hset("future:f2", "state", "pending")
    changed, deleted, cur = s.scan_changed("future:", 0)
    assert sorted(changed) == ["future:f1", "future:f2"]
    assert deleted == []
    # nothing moved since: empty delta, cursor stable
    changed, deleted, cur2 = s.scan_changed("future:", cur)
    assert changed == [] and deleted == [] and cur2 == cur


def test_scan_changed_coalesces_repeated_writes():
    s = NodeStore("n0")
    _, _, cur = s.scan_changed("future:", 0)
    for _ in range(10):
        s.hset("future:f1", "state", "running")
    changed, deleted, cur = s.scan_changed("future:", cur)
    assert changed == ["future:f1"] and deleted == []


def test_scan_changed_reports_deletions_once():
    s = NodeStore("n0")
    s.hset("future:f1", "state", "pending")
    _, _, cur = s.scan_changed("future:", 0)
    s.delete("future:f1")
    changed, deleted, cur = s.scan_changed("future:", cur)
    assert changed == [] and deleted == ["future:f1"]
    changed, deleted, cur = s.scan_changed("future:", cur)
    assert changed == [] and deleted == []


def test_scan_changed_rebirth_after_delete():
    """delete + re-create between scans reads as a change, not a delete."""
    s = NodeStore("n0")
    s.hset("future:f1", "state", "pending")
    _, _, cur = s.scan_changed("future:", 0)
    s.delete("future:f1")
    s.hset("future:f1", "state", "running")
    changed, deleted, _ = s.scan_changed("future:", cur)
    assert changed == ["future:f1"] and deleted == []


def test_scan_changed_only_matching_prefix():
    s = NodeStore("n0")
    _, _, cur = s.scan_changed("future:", 0)
    s.hset("metrics:a", "q", 1)
    s.hset("future:f1", "state", "pending")
    changed, _, _ = s.scan_changed("future:", cur)
    assert changed == ["future:f1"]


def test_scan_changed_stale_cursor_not_replayed_after_ack():
    """Single-consumer contract: scanning at cursor C acknowledges (and
    compacts) every delta at or below C."""
    s = NodeStore("n0")
    s.hset("future:f1", "state", "pending")
    _, _, cur = s.scan_changed("future:", 0)
    s.scan_changed("future:", cur)           # ack
    changed, _, _ = s.scan_changed("future:", 0)   # rewound cursor
    assert changed == []                      # journal already compacted


def test_keys_backed_by_index_and_snapshot():
    s = NodeStore("n0")
    s.hset("metrics:a", "q", 1)
    s.hset("other:x", "q", 1)
    # unindexed prefix: snapshot + filter path
    assert sorted(s.keys("metrics:")) == ["metrics:a"]
    s.scan_changed("metrics:", 0)             # registers the index
    s.hset("metrics:b", "q", 2)
    assert sorted(s.keys("metrics:")) == ["metrics:a", "metrics:b"]
    s.delete("metrics:a")
    assert s.keys("metrics:") == ["metrics:b"]
    assert sorted(s.keys("")) == ["metrics:b", "other:x"]


def test_hgetall_many_and_delete_many():
    s = NodeStore("n0")
    for i in range(5):
        s.hset(f"future:f{i}", "state", i)
    got = s.hgetall_many([f"future:f{i}" for i in range(5)] + ["future:nope"])
    assert len(got) == 5 and got["future:f3"] == {"state": 3}
    s.delete_many(["future:f0", "future:f1"])
    assert sorted(s.keys("future:")) == ["future:f2", "future:f3", "future:f4"]


def test_cursor_tracks_mutations():
    s = NodeStore("n0")
    c0 = s.cursor()
    s.hset("k", "f", 1)
    assert s.cursor() == c0 + 1
