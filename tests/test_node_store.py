"""Node store: hashes, CAS, pub/sub, versioning (paper §4.1)."""

from repro.core import NodeStore, StoreCluster


def test_hash_ops():
    s = NodeStore("n0")
    s.hset("k", "f", 1)
    s.hset_many("k", {"g": 2, "h": 3})
    assert s.hget("k", "f") == 1
    assert s.hgetall("k") == {"f": 1, "g": 2, "h": 3}
    assert s.hdel("k", "f")
    assert not s.hdel("k", "f")
    assert s.hget("k", "f", default="d") == "d"


def test_versions_bump_on_write():
    s = NodeStore("n0")
    v0 = s.version("k")
    s.hset("k", "f", 1)
    assert s.version("k") == v0 + 1


def test_cas():
    s = NodeStore("n0")
    s.hset("k", "owner", "a")
    assert not s.cas("k", "owner", "b", "c")
    assert s.cas("k", "owner", "a", "c")
    assert s.hget("k", "owner") == "c"


def test_incr():
    s = NodeStore("n0")
    assert s.incr("m", "count") == 1
    assert s.incr("m", "count", 4) == 5


def test_pubsub_fires_on_write():
    s = NodeStore("n0")
    got = []
    s.subscribe("cmd:x", lambda f, v: got.append((f, v)))
    s.hset("cmd:x", "migrate", {"dst": "y"})
    assert got == [("migrate", {"dst": "y"})]
    s.unsubscribe("cmd:x", s._subs["cmd:x"][0])
    s.hset("cmd:x", "z", 1)
    assert len(got) == 1


def test_keys_prefix_scan():
    s = NodeStore("n0")
    s.hset("metrics:a", "q", 1)
    s.hset("metrics:b", "q", 2)
    s.hset("future:f1", "state", "ready")
    assert sorted(s.keys("metrics:")) == ["metrics:a", "metrics:b"]


def test_cluster_directory():
    c = StoreCluster()
    a = c.get("n0")
    b = c.get("n0")
    assert a is b
    c.get("n1")
    assert sorted(c.nodes()) == ["n0", "n1"]
