"""Integration tests: runtime behaviours the paper claims (§3-§5).

Covers: stub generation (incl. the YAML declaration path), directives,
stateful routing, managed state + migration, session priorities, the Fig. 4
retry workflow, and the migration protocol.
"""

import pytest

from repro.core import (AgentSpec, Directives, FixedLatency, LLMLatency,
                        ManagedDict, ManagedList, NalarRuntime,
                        HighPrioritySessionPolicy, PolicyChain,
                        deployment, emulated, parse_spec)
from repro.core.runtime import current_runtime


def two_node_rt(**kw):
    return NalarRuntime(simulate=True,
                        nodes={"n0": {"CPU": 16, "GPU": 4},
                               "n1": {"CPU": 16, "GPU": 4}}, **kw)


def test_parse_spec_yaml_declaration():
    spec = parse_spec(
        """
        name: developer
        functions:
          - implement
          - review
        batchable: true
        max_batch: 4
        max_instances: 3
        resources: GPU=1,CPU=2
        """,
        impls={"implement": emulated(FixedLatency(0.1), lambda t: t),
               "review": emulated(FixedLatency(0.1), lambda t: t)})
    assert spec.name == "developer"
    assert set(spec.methods) == {"implement", "review"}
    assert spec.directives.batchable and spec.directives.max_batch == 4
    assert spec.directives.resources == {"GPU": 1.0, "CPU": 2.0}


def test_parse_spec_missing_impl_fails():
    with pytest.raises(ValueError, match="no implementation"):
        parse_spec("name: a\nfunctions:\n  - f\n", impls={})


def test_directive_conflict_batchable_managed_state():
    d = Directives(batchable=True, uses_managed_state=True)
    with pytest.raises(ValueError, match="batchable"):
        d.validate()


def test_stateful_agent_pins_session():
    rt = two_node_rt()
    rt.register_agent(AgentSpec(
        name="chat",
        methods={"msg": emulated(FixedLatency(0.05), lambda m: m)},
        directives=Directives(stateful=True, max_instances=4,
                              resources={"CPU": 1}),
    ), instances=4)

    executors = []

    def driver():
        for i in range(5):
            f = rt.stub("chat").msg(i)
            f.value()
            executors.append(f.meta.executor)

    deployment.main(driver, runtime=rt)
    assert len(set(executors)) == 1     # same instance for the whole session


def test_managed_state_persists_across_requests():
    rt = two_node_rt()
    history = ManagedList("history")

    def remember(item):
        history.append(item)
        return history.snapshot()

    rt.register_agent(AgentSpec(
        name="memory",
        methods={"remember": emulated(FixedLatency(0.01), remember)},
        directives=Directives(resources={"CPU": 1}),
    ), instances=1)

    session = rt.sessions.new_session().session_id
    outs = []

    def driver(item):
        outs.append(rt.stub("memory").remember(item).value())

    rt.start()
    rt.submit_request(driver, "a", session=session)
    rt.run()
    rt.submit_request(driver, "b", session=session)
    rt.run()
    assert outs[0] == ["a"]
    assert outs[1] == ["a", "b"]        # state survived across requests


def test_managed_state_isolated_between_sessions():
    rt = two_node_rt()
    d = ManagedDict("kv")

    def put(k, v):
        d[k] = v
        return d.snapshot()

    rt.register_agent(AgentSpec(
        name="kvstore",
        methods={"put": emulated(FixedLatency(0.01), put)},
        directives=Directives(resources={"CPU": 1}),
    ), instances=1)

    outs = {}

    def driver(tag):
        outs[tag] = rt.stub("kvstore").put(tag, 1).value()

    rt.start()
    rt.submit_request(driver, "s1")
    rt.submit_request(driver, "s2")
    rt.run()
    assert outs["s1"] == {"s1": 1}
    assert outs["s2"] == {"s2": 1}


def test_fig4_retry_workflow():
    """The paper's three-agent workflow with driver-side retries."""
    rt = two_node_rt()
    fail_once = {"n": 0}

    def test_code(code):
        # first attempt of task1 fails, retry passes
        if "task1" in code and fail_once["n"] == 0:
            fail_once["n"] += 1
            return "Fail"
        return "Pass"

    rt.register_agent(AgentSpec(
        name="planner",
        methods={"plan": emulated(LLMLatency(base=0.1, jitter_sigma=0.0),
                                  lambda p: [f"{p}::task{i}" for i in range(3)])},
        directives=Directives(resources={"GPU": 1})), instances=1)
    rt.register_agent(AgentSpec(
        name="developer",
        methods={"implement_and_test": emulated(
            LLMLatency(base=0.2, jitter_sigma=0.0),
            lambda t: (test_code(f"code({t})"), f"code({t})"))},
        directives=Directives(max_instances=4, resources={"GPU": 1})),
        instances=2)

    def main(prompt, max_retries=3):
        rt_ = current_runtime()
        subtasks = rt_.stub("planner").plan(prompt).value()
        futures = [rt_.stub("developer").implement_and_test(t) for t in subtasks]
        done = [False] * len(futures)
        codes = [None] * len(futures)
        retries = 0
        while not all(done):
            assert retries <= max_retries
            for i, f in enumerate(futures):
                if done[i]:
                    continue
                res, code = f.value()
                if res == "Pass":
                    done[i], codes[i] = True, code
                else:
                    futures[i] = rt_.stub("developer").implement_and_test(
                        subtasks[i], _hint={"retry": retries + 1})
                    retries += 1
        return codes

    codes = deployment.main(main, "OAuth", runtime=rt)
    assert len(codes) == 3 and all("code(" in c for c in codes)
    assert fail_once["n"] == 1          # exactly one retry happened


def test_migration_protocol_moves_queued_future():
    rt = two_node_rt(control_interval=10.0)   # keep global controller quiet
    rt.register_agent(AgentSpec(
        name="work",
        methods={"run": emulated(FixedLatency(1.0), lambda x: x)},
        directives=Directives(max_instances=2, resources={"CPU": 1})),
        instances=2)
    insts = rt.instances_of_type("work")

    moved = {}

    def driver():
        from repro.core import get_context
        rt_ = current_runtime()
        # fill instance 0 so the next future queues behind it
        f1 = rt_.stub("work").run(1)
        rt_.kernel.sleep(0.1)
        # force-route the second future to the busy instance
        rt_.router.pin(get_context()[0], "work", insts[0])
        f2 = rt_.stub("work").run(2)
        rt_.kernel.sleep(0.1)
        assert f2.meta.executor == insts[0]
        ctrl = rt_.controller_of(insts[0])
        ok = ctrl.migrate_out(f2, insts[1])           # Fig. 8 steps 2-6
        moved["ok"] = ok
        moved["exec"] = f2.meta.executor
        return f1.value(), f2.value()

    out = deployment.main(driver, runtime=rt)
    assert out == (1, 2)
    assert moved["ok"] and moved["exec"] == insts[1]
    assert len(rt.telemetry.migrations) == 1


def test_priority_boost_policy_runs():
    """Fig. 6 policy: high-priority session gets boosted + migrated."""
    rt = two_node_rt(control_interval=0.05)
    session = rt.sessions.new_session().session_id
    rt.global_controller.policy = PolicyChain(
        HighPrioritySessionPolicy(session))
    rt.register_agent(AgentSpec(
        name="svc",
        methods={"run": emulated(FixedLatency(0.5), lambda x: x)},
        directives=Directives(max_instances=2, resources={"CPU": 1})),
        instances=2)

    def driver():
        return rt.stub("svc").run("hi").value()

    rt.start()
    done = {}
    rt.submit_request(driver, session=session,
                      on_done=lambda o, e: done.update(out=o, err=e))
    rt.run()
    assert done["err"] is None
    assert rt.sessions.get(session).priority == 10.0


def test_provision_and_kill_respect_bounds():
    rt = two_node_rt()
    rt.register_agent(AgentSpec(
        name="svc",
        methods={"run": emulated(FixedLatency(0.1), lambda: 1)},
        directives=Directives(min_instances=1, max_instances=2,
                              resources={"CPU": 1})), instances=1)
    iid2 = rt.provision_instance("svc", "n1")
    assert iid2 is not None
    assert rt.provision_instance("svc", "n0") is None   # max reached
    rt.kill_instance(iid2)
    assert len(rt.live_instances("svc")) == 1
    # min floor: cannot kill the last one
    rt.kill_instance(rt.instances_of_type("svc")[0])
    assert len(rt.live_instances("svc")) == 1


def test_resource_accounting():
    rt = NalarRuntime(simulate=True, nodes={"n0": {"GPU": 2}})
    rt.register_agent(AgentSpec(
        name="big",
        methods={"run": emulated(FixedLatency(0.1), lambda: 1)},
        directives=Directives(max_instances=8, resources={"GPU": 1})),
        instances=2)
    assert rt.provision_instance("big", "n0") is None   # out of GPUs
    free = rt.free_resources()["n0"]["GPU"]
    assert free == 0


def test_preemptable_running_future_migrates():
    """Table-1 `preemptable`: a RUNNING future can be preempted (with
    restart) and migrated; non-preemptable running futures cannot."""
    preempted = []
    rt = two_node_rt(control_interval=10.0)
    rt.register_agent(AgentSpec(
        name="pre",
        methods={"run": emulated(FixedLatency(2.0), lambda x: x)},
        directives=Directives(max_instances=2, resources={"CPU": 1},
                              preemptable=lambda f: preempted.append(f.fid))),
        instances=2)
    rt.register_agent(AgentSpec(
        name="nopre",
        methods={"run": emulated(FixedLatency(2.0), lambda x: x)},
        directives=Directives(max_instances=2, resources={"CPU": 1})),
        instances=2)
    insts_p = rt.instances_of_type("pre")
    insts_n = rt.instances_of_type("nopre")
    moved = {}

    def driver():
        rt_ = current_runtime()
        f1 = rt_.stub("pre").run(1)
        f2 = rt_.stub("nopre").run(2)
        rt_.kernel.sleep(0.5)          # both are mid-execution now
        c_p = rt_.controller_of(f1.meta.executor)
        c_n = rt_.controller_of(f2.meta.executor)
        dst_p = next(i for i in insts_p if i != f1.meta.executor)
        dst_n = next(i for i in insts_n if i != f2.meta.executor)
        moved["pre"] = c_p.migrate_out(f1, dst_p)
        moved["nopre"] = c_n.migrate_out(f2, dst_n)
        return f1.value(), f2.value()

    out = deployment.main(driver, runtime=rt)
    assert out == (1, 2)               # both still complete correctly
    assert moved["pre"] is True        # preempted + migrated
    assert moved["nopre"] is False     # running, not preemptable
    assert len(preempted) == 1
