"""Fault-tolerance subsystem: retry ladder, state epochs, cancellation,
replica-failure recovery (ISSUE 3 acceptance tests).

Covers, on the deterministic SimKernel:
 * exactly-once managed state across retries (ManagedList/ManagedDict/
   SessionTranscript), including a migration landing between attempts;
 * local in-place retries with backoff and the attempt counter;
 * escalation to the global controller's RetryPolicy on budget exhaustion
   and on instance death (hard kill), with dead-replica blacklisting;
 * cancellation of queued / parked / running / engine-in-flight futures
   (the ``complete_async`` CANCELLED-guard regression);
 * bounded FutureTable (GC of resolved futures + node-store mirrors);
 * retry telemetry (metrics counters, ``retry#n`` trace marks);
 * deadline propagation (inherited remaining budgets, launch-time expiry
   as a terminal non-retryable failure) and hedged dispatch (first
   completion wins, the loser never double-materializes, engine-side
   cancellation releases slots and KV pages).
"""

import pytest

from repro.core import (AgentSpec, DeadlineExceeded, Directives, FixedLatency,
                        FutureCancelled, FutureState, InstanceDied,
                        ManagedDict, ManagedList, NalarRuntime, deployment,
                        emulated, get_context)
from repro.core.debug import format_trace
from repro.core.runtime import current_runtime
from repro.core.state import SessionTranscript


def two_node_rt(**kw):
    return NalarRuntime(simulate=True,
                        nodes={"n0": {"CPU": 16}, "n1": {"CPU": 16}}, **kw)


# ---------------------------------------------------------------- exactly-once
def _stateful_agent(rt, fail_attempts, latency=0.05, max_retries=2,
                    instances=1):
    """Agent whose method writes a ManagedList, a ManagedDict, and the
    SessionTranscript, then fails on its first ``fail_attempts`` executions."""
    lst = ManagedList("items")
    dct = ManagedDict("kv")
    calls = {"n": 0}

    def work(x):
        lst.append(x)
        dct[f"k{x}"] = dct.get(f"k{x}", 0) + 1
        rt_ = current_runtime()
        sid, _rid, caller = get_context()
        tr = SessionTranscript(rt_.state_store, caller.split(":")[0],
                               rt_.node_of_instance(caller))
        tr.extend(sid, [x, x + 1])
        calls["n"] += 1
        if calls["n"] <= fail_attempts:
            raise RuntimeError(f"flaky attempt {calls['n']}")
        return lst.snapshot(), dct.snapshot(), tr.tokens(sid)

    rt.register_agent(AgentSpec(
        name="stateful",
        methods={"run": emulated(FixedLatency(latency), work)},
        directives=Directives(max_retries=max_retries, max_instances=4,
                              resources={"CPU": 1})), instances=instances)
    return calls


def test_retry_exactly_once_over_managed_state():
    """A method that fails mid-way and is retried leaves managed state
    identical to a single clean execution."""
    rt = two_node_rt()
    calls = _stateful_agent(rt, fail_attempts=1)

    def driver():
        f = rt.stub("stateful").run(7)
        return f.value(), f.meta.attempt

    (lst, dct, toks), attempt = deployment.main(driver, runtime=rt)
    assert calls["n"] == 2              # two executions...
    assert attempt == 1
    assert lst == [7]                   # ...but state as if one
    assert dct == {"k7": 1}
    assert toks == [7, 8]


def test_retry_exactly_once_with_migration_between_attempts():
    """The epoch rollback is logical: a session migration landing between
    the failed attempt and the retry must not resurrect the failed writes."""
    rt = two_node_rt()
    calls = _stateful_agent(rt, fail_attempts=1, latency=0.05, instances=1)
    sid = rt.sessions.new_session().session_id
    out = {}

    def driver():
        f = rt.stub("stateful").run(3)
        out["res"] = f.value()

    # attempt 0 fails at t=0.05 (rollback), retry re-executes at ~0.10;
    # migrate the session's state to the other node in between
    rt.kernel.schedule(0.075, lambda: rt.state_store.migrate_session(
        sid, "stateful", "n1"))
    rt.start()
    rt.submit_request(driver, session=sid)
    rt.run()
    lst, dct, toks = out["res"]
    assert calls["n"] == 2
    assert lst == [3] and dct == {"k3": 1} and toks == [3, 4]


def test_clean_failure_rolls_back_partial_writes():
    """Terminal failure (budget exhausted everywhere) leaves no partial
    state behind either."""
    rt = two_node_rt()
    lst = ManagedList("log")

    def work(x):
        lst.append(x)
        raise ValueError("always broken")

    rt.register_agent(AgentSpec(
        name="bad",
        methods={"run": emulated(FixedLatency(0.02), work)},
        directives=Directives(resources={"CPU": 1})), instances=1)
    sid = rt.sessions.new_session().session_id

    def driver():
        with pytest.raises(ValueError, match="always broken"):
            rt.stub("bad").run(1).value()
        return True

    rt.start()
    rt.submit_request(driver, session=sid)
    rt.run()
    assert rt.state_store.load(sid, "bad", "log", "n0", default=[]) == []


# ------------------------------------------------------------- retry ladder
def test_local_retry_with_backoff_and_metrics():
    rt = two_node_rt()
    calls = _stateful_agent(rt, fail_attempts=2, max_retries=3)

    def driver():
        f = rt.stub("stateful").run(1)
        v = f.value()
        return v, f.meta.attempt, f.meta.escalations

    (lst, _, _), attempt, esc = deployment.main(driver, runtime=rt)
    assert lst == [1]
    assert calls["n"] == 3 and attempt == 2 and esc == 0
    inst = rt.instance(rt.instances_of_type("stateful")[0])
    assert inst.metrics.retries == 2
    assert inst.metrics.failed == 0     # absorbed, never terminal


def test_per_call_retry_hint_overrides_directive():
    """``_hint={"retry": n}`` is the per-call budget (directive says 0)."""
    rt = two_node_rt()
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("once")
        return "ok"

    rt.register_agent(AgentSpec(
        name="svc",
        methods={"run": emulated(FixedLatency(0.02), flaky)},
        directives=Directives(max_retries=0, resources={"CPU": 1})),
        instances=1)

    def driver():
        with pytest.raises(RuntimeError):
            rt.stub("svc").run().value()        # no budget: fails fast
        return rt.stub("svc").run(_hint={"retry": 2}).value()

    assert deployment.main(driver, runtime=rt) == "ok"


def test_retry_zero_scheduling_hint_keeps_directive_budget():
    """``{"retry": 0}`` is the LPT re-entrance signal for first attempts of
    driver-managed loops — it must not disable the agent's max_retries.
    ``{"max_retries": 0}`` is the explicit way to opt a call out."""
    rt = two_node_rt()
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] in (1, 3):
            raise RuntimeError("transient")
        return "ok"

    rt.register_agent(AgentSpec(
        name="svc",
        methods={"run": emulated(FixedLatency(0.02), flaky)},
        directives=Directives(max_retries=2, resources={"CPU": 1})),
        instances=1)

    def driver():
        # scheduling hint only: the directive's budget still applies
        v = rt.stub("svc").run(_hint={"retry": 0}).value()
        # explicit opt-out: fails fast despite the directive
        with pytest.raises(RuntimeError, match="transient"):
            rt.stub("svc").run(_hint={"max_retries": 0}).value()
        return v

    assert deployment.main(driver, runtime=rt) == "ok"


def test_budget_exhaustion_escalates_to_surviving_replica():
    """Local retries keep landing on the same (poisoned) instance; the
    escalation reroutes to a sibling via RetryPolicy."""
    rt = two_node_rt(control_interval=10.0)
    rt.register_agent(AgentSpec(
        name="svc",
        methods={"run": emulated(
            FixedLatency(0.05),
            lambda: ("ok" if not get_context()[2].startswith(bad[0])
                     else (_ for _ in ()).throw(RuntimeError("bad replica"))))},
        directives=Directives(max_retries=1, max_instances=2,
                              resources={"CPU": 1})), instances=2)
    insts = rt.instances_of_type("svc")
    bad = [insts[0]]

    def driver():
        rt_ = current_runtime()
        rt_.router.pin(get_context()[0], "svc", bad[0])
        f = rt_.stub("svc").run()
        v = f.value()
        return v, f.meta.escalations, f.meta.executor

    v, esc, executor = deployment.main(driver, runtime=rt)
    assert v == "ok"
    assert esc == 1
    assert executor == insts[1]         # rerouted off the failing replica


def test_no_surviving_replica_fails_with_original_error():
    rt = two_node_rt(control_interval=10.0)
    rt.register_agent(AgentSpec(
        name="solo",
        methods={"run": emulated(FixedLatency(0.02),
                                 lambda: (_ for _ in ()).throw(
                                     ValueError("root cause")))},
        directives=Directives(max_retries=1, max_instances=1,
                              resources={"CPU": 1})), instances=1)

    def driver():
        with pytest.raises(ValueError, match="root cause"):
            rt.stub("solo").run().value()
        return True

    assert deployment.main(driver, runtime=rt)


def test_instance_death_reroutes_and_blacklists():
    """Hard kill (fault injection): the in-flight future escalates, the
    RetryPolicy blacklists the dead instance and the retry completes on the
    survivor."""
    rt = two_node_rt(control_interval=10.0)
    rt.register_agent(AgentSpec(
        name="w",
        methods={"run": emulated(FixedLatency(0.5), lambda x: x * 2)},
        directives=Directives(max_retries=1, max_instances=2,
                              resources={"CPU": 1})), instances=2)

    def driver():
        r = current_runtime()
        f = r.stub("w").run(21)
        r.kernel.sleep(0.1)             # future is RUNNING now
        victim = f.meta.executor
        r.kill_instance(victim, hard=True)
        return f.value(), victim, f.meta.executor, f.meta.attempt

    v, victim, executor, attempt = deployment.main(driver, runtime=rt)
    assert v == 42
    assert executor != victim and attempt == 1
    assert victim in rt.blacklist
    assert not rt.instance(victim).alive


def test_instance_death_without_retries_fails_inflight():
    rt = two_node_rt()
    rt.register_agent(AgentSpec(
        name="w",
        methods={"run": emulated(FixedLatency(0.5), lambda x: x)},
        directives=Directives(max_instances=2, resources={"CPU": 1})),
        instances=2)

    def driver():
        r = current_runtime()
        f = r.stub("w").run(1)
        r.kernel.sleep(0.1)
        r.kill_instance(f.meta.executor, hard=True)
        with pytest.raises(InstanceDied):
            f.value()
        return True

    assert deployment.main(driver, runtime=rt)


def test_hard_kill_requeues_queued_futures():
    """Queued (not yet started) futures survive a hard kill without
    consuming any retry budget."""
    rt = two_node_rt()
    rt.register_agent(AgentSpec(
        name="w",
        methods={"run": emulated(FixedLatency(0.3), lambda x: x)},
        directives=Directives(max_instances=2, resources={"CPU": 1})),
        instances=2)
    insts = rt.instances_of_type("w")

    def driver():
        r = current_runtime()
        sid = get_context()[0]
        r.router.pin(sid, "w", insts[0])
        futs = [r.stub("w").run(i) for i in range(4)]   # 1 running, 3 queued
        r.kernel.sleep(0.05)
        r.router.unpin(sid, "w")
        r.kill_instance(insts[0], hard=True)
        # the queued three re-route and complete; only the running one died
        vals = []
        for f in futs[1:]:
            vals.append(f.value())
        return vals, [f.meta.attempt for f in futs[1:]]

    vals, attempts = deployment.main(driver, runtime=rt)
    assert vals == [1, 2, 3]
    assert attempts == [0, 0, 0]


# -------------------------------------------------------------- cancellation
def echo_rt(latency=1.0, instances=1):
    rt = two_node_rt()
    rt.register_agent(AgentSpec(
        name="e",
        methods={"run": emulated(FixedLatency(latency), lambda x: x)},
        directives=Directives(max_instances=4, resources={"CPU": 1})),
        instances=instances)
    return rt


def test_cancel_queued_future():
    rt = echo_rt()

    def driver():
        r = current_runtime()
        f1 = r.stub("e").run(1)
        f2 = r.stub("e").run(2)         # queued behind f1
        r.kernel.sleep(0.1)
        assert r.cancel_future(f2, "user abandoned")
        v1 = f1.value()
        with pytest.raises(FutureCancelled, match="user abandoned"):
            f2.value()
        return v1, f2.state

    v1, state = deployment.main(driver, runtime=rt)
    assert v1 == 1 and state == FutureState.CANCELLED
    inst = rt.instance(rt.instances_of_type("e")[0])
    assert inst.metrics.cancelled == 1
    assert inst.metrics.completed == 1  # f2 never executed


def test_cancel_running_future_discards_completion():
    rt = echo_rt()

    def driver():
        r = current_runtime()
        f = r.stub("e").run(5)
        r.kernel.sleep(0.1)
        assert f.state == FutureState.RUNNING
        r.cancel_future(f)
        r.kernel.sleep(2.0)             # past the service-completion event
        assert f.state == FutureState.CANCELLED
        with pytest.raises(FutureCancelled):
            f.value()
        return True

    assert deployment.main(driver, runtime=rt)


def test_cancel_propagates_to_dependents():
    rt = echo_rt()

    def driver():
        r = current_runtime()
        f1 = r.stub("e").run(1)
        f2 = r.stub("e").run(f1)        # parked on f1
        r.kernel.sleep(0.1)
        r.cancel_future(f1)
        with pytest.raises(FutureCancelled):
            f2.value()                  # unblocked, observes the cancellation
        return True

    assert deployment.main(driver, runtime=rt)


def test_cancel_session_sweeps_unresolved_futures():
    rt = echo_rt()
    sid = rt.sessions.new_session().session_id
    out = {}

    def driver():
        r = current_runtime()
        futs = [r.stub("e").run(i) for i in range(3)]
        r.kernel.sleep(0.1)
        out["n"] = r.cancel_session(get_context()[0])
        for f in futs:
            with pytest.raises(FutureCancelled):
                f.value()
        return True

    rt.start()
    rt.submit_request(driver, session=sid)
    rt.run()
    assert out["n"] == 3


def test_complete_async_ignores_cancelled_future():
    """Regression (satellite): a future cancelled while in flight on an
    engine must NOT be materialized by the late async completion."""
    rt = echo_rt()

    def driver():
        r = current_runtime()
        f = r.stub("e").run(9)
        r.kernel.sleep(0.1)
        assert f.state == FutureState.RUNNING
        ctrl = r.controller_of(f.meta.executor)
        r.cancel_future(f)
        # the engine's pump thread reports a result after the cancellation
        ctrl.complete_async(f, value="zombie result")
        r.kernel.sleep(0.5)
        assert f.state == FutureState.CANCELLED
        with pytest.raises(FutureCancelled):
            f.value()
        return True

    assert deployment.main(driver, runtime=rt)


def test_cancelled_future_counts_as_resolved_dependency():
    """``available`` includes CANCELLED so dependency scans don't hang."""
    rt = echo_rt()
    from repro.core.future import Future, FutureMetadata
    f = Future(rt, FutureMetadata())
    assert not f.available
    assert f.cancel(0.0)
    assert f.available
    assert not f.cancel(1.0)            # idempotent
    assert not f.reset_for_retry(1.0)   # cancellation is terminal


def test_no_live_instance_failure_unparks_dependents():
    """When the last replica dies and a drained future cannot be
    re-dispatched, its parked dependents must observe the failure instead
    of staying parked forever."""
    rt = two_node_rt(control_interval=10.0)
    rt.register_agent(AgentSpec(
        name="a",
        methods={"run": emulated(FixedLatency(0.5), lambda x: x)},
        directives=Directives(max_instances=1, resources={"CPU": 1})),
        instances=1)
    rt.register_agent(AgentSpec(
        name="b",
        methods={"run": emulated(FixedLatency(0.05), lambda x: x)},
        directives=Directives(max_instances=1, resources={"CPU": 1})),
        instances=1)

    def driver():
        r = current_runtime()
        f1 = r.stub("a").run(1)         # running on the lone 'a' replica
        f1b = r.stub("a").run(2)        # queued behind it
        f2 = r.stub("b").run(f1b)       # parked on f1b at 'b''s controller
        r.kernel.sleep(0.1)
        r.kill_instance(f1.meta.executor, hard=True)
        # drain re-dispatches f1b, but no live 'a' remains -> it fails,
        # and the failure must flow through to f2
        with pytest.raises(RuntimeError, match="no live instance"):
            f2.value()
        return True

    assert deployment.main(driver, runtime=rt)


def test_zombie_composite_writes_dropped_after_hard_kill():
    """A hard-killed *composite* keeps executing on its driver thread
    (threads cannot be killed).  Its post-rollback writes must be dropped —
    otherwise the retry double-applies and exactly-once breaks."""
    rt = two_node_rt(control_interval=10.0)
    log = ManagedList("log")

    def slow_workflow(x):
        log.append(f"{x}:first")
        current_runtime().kernel.sleep(1.0)
        log.append(f"{x}:second")       # the zombie reaches this too
        return log.snapshot()

    rt.register_agent(AgentSpec(
        name="comp",
        methods={"run": slow_workflow},
        directives=Directives(max_retries=1, max_instances=2,
                              uses_managed_state=True,
                              resources={"CPU": 1})), instances=2)
    sid = rt.sessions.new_session().session_id
    out = {}

    def driver():
        r = current_runtime()
        f = r.stub("comp").run("a")
        r.kernel.sleep(0.2)             # composite is mid-sleep now
        r.kill_instance(f.meta.executor, hard=True)
        out["val"] = f.value()          # the retry's clean result
        r.kernel.sleep(2.0)             # let the zombie thread finish too

    rt.start()
    rt.submit_request(driver, session=sid)
    rt.run()
    # exactly one clean execution's worth of writes — the killed attempt's
    # first append was rolled back, its zombie second append was dropped
    assert out["val"] == ["a:first", "a:second"]
    assert rt.state_store.load(sid, "comp", "log", "n0",
                               default=[]) == ["a:first", "a:second"]


def test_stale_completion_during_retry_window_is_discarded():
    """``reset_for_retry`` closes the run-id fence immediately: a zombie
    completion captured under the superseded attempt must not materialize
    the future while it sits PENDING awaiting re-dispatch."""
    rt = echo_rt()

    def driver():
        r = current_runtime()
        f = r.stub("e").run(9)
        r.kernel.sleep(0.1)
        assert f.state == FutureState.RUNNING
        ctrl = r.controller_of(f.meta.executor)
        old_run = f._run_id
        # what every real reset path does before superseding an attempt
        ctrl.detach_running(f)
        assert f.reset_for_retry(r.kernel.now())    # superseded attempt
        assert f._run_id == old_run + 1
        ctrl.complete_async(f, value="zombie", expect_run=old_run)
        r.kernel.sleep(0.2)
        assert f.state == FutureState.PENDING       # fence held
        ctrl.submit(f)                              # genuine re-dispatch
        return f.value()

    assert deployment.main(driver, runtime=rt) == 9


# -------------------------------------------------------------- future table
def test_future_table_stays_bounded():
    """Satellite: resolved futures (and their node-store mirrors) are
    retired once the table outgrows its threshold."""
    rt = two_node_rt(future_gc_threshold=32)
    rt.register_agent(AgentSpec(
        name="e",
        methods={"run": emulated(FixedLatency(0.001), lambda x: x)},
        directives=Directives(resources={"CPU": 1})), instances=1)

    def driver():
        for i in range(300):
            rt.stub("e").run(i).value()
        return True

    assert deployment.main(driver, runtime=rt)
    assert len(rt.futures) <= 64
    assert rt.futures.retired >= 200
    mirrors = sum(len(s.keys("future:")) for s in rt.stores.all_stores())
    assert mirrors <= 64


def test_future_table_sweep_backs_off_when_nothing_collectable():
    """A burst of still-pending futures must not make every add O(n): after
    a fruitless sweep the trigger backs off geometrically, and collapses
    back to the threshold once futures resolve."""
    from repro.core.future import Future, FutureMetadata, FutureTable
    rt = two_node_rt()
    table = FutureTable(gc_threshold=4)
    futs = [Future(rt, FutureMetadata()) for _ in range(10)]
    for f in futs:
        table.add(f)
    assert table.needs_sweep()
    assert table.sweep() == []          # nothing resolved yet
    assert not table.needs_sweep()      # backed off past 10 live entries
    for f in futs:
        f.materialize(1, 0.0)
    assert table.sweep() and len(table) == 0
    assert not table.needs_sweep()      # floor collapsed to the threshold


def test_future_table_gc_disabled_keeps_everything():
    rt = two_node_rt(future_gc_threshold=0)
    rt.register_agent(AgentSpec(
        name="e",
        methods={"run": emulated(FixedLatency(0.001), lambda x: x)},
        directives=Directives(resources={"CPU": 1})), instances=1)

    def driver():
        for i in range(50):
            rt.stub("e").run(i).value()
        return True

    assert deployment.main(driver, runtime=rt)
    assert len(rt.futures) == 50


# ----------------------------------------------------------------- telemetry
def test_trace_marks_retried_stage():
    rt = two_node_rt()
    _stateful_agent(rt, fail_attempts=1)
    rid = {}

    def driver():
        rid["r"] = get_context()[1]
        return rt.stub("stateful").run(1).value()

    deployment.main(driver, runtime=rt)
    rec = rt.telemetry.trace(rid["r"])
    txt = format_trace(rec)
    assert "retry#1" in txt


def test_retry_counters_surface_in_cluster_view():
    rt = two_node_rt()
    _stateful_agent(rt, fail_attempts=1)

    def driver():
        return rt.stub("stateful").run(1).value()

    deployment.main(driver, runtime=rt)
    view = rt.global_controller.collect_view()
    iid = rt.instances_of_type("stateful")[0]
    assert view.instances[iid].retries == 1
    assert view.instances[iid].cancelled == 0


# ---------------------------------------------------------------- deadlines
def test_launch_time_expiry_is_terminal_and_burns_no_retry_budget():
    """A queued future whose deadline passes before it launches fails
    ``DeadlineExceeded`` immediately — no execution, no retry attempts,
    and the ``expired`` counter (not ``failed``-via-retries) records it."""
    rt = two_node_rt()
    rt.register_agent(AgentSpec(
        name="e",
        methods={"run": emulated(FixedLatency(0.5), lambda x: x)},
        directives=Directives(max_retries=3, max_instances=1,
                              resources={"CPU": 1})), instances=1)

    def driver():
        r = current_runtime()
        f1 = r.stub("e").run(1)
        # queued behind f1 (0.5 s service) with a 0.3 s budget: its launch
        # slot opens only after its deadline has passed
        f2 = r.stub("e").run(2, _hint={"deadline_s": 0.3})
        v1 = f1.value()
        with pytest.raises(DeadlineExceeded):
            f2.value()
        return v1, f2.state, f2.meta.attempt, f2.meta.escalations

    v1, state, attempt, esc = deployment.main(driver, runtime=rt)
    assert v1 == 1
    assert state == FutureState.FAILED      # terminal — never re-armed
    assert attempt == 0 and esc == 0        # no retry budget burned
    inst = rt.instance(rt.instances_of_type("e")[0])
    assert inst.metrics.expired == 1
    assert inst.metrics.retries == 0


def test_expired_future_never_rearms_despite_retry_budget():
    """DeadlineExceeded raised *during* execution is non-retryable even
    when the directive's retry budget is untouched."""
    rt = two_node_rt()
    calls = {"n": 0}

    def work():
        calls["n"] += 1
        raise DeadlineExceeded("budget spent downstream")

    rt.register_agent(AgentSpec(
        name="e",
        methods={"run": emulated(FixedLatency(0.02), work)},
        directives=Directives(max_retries=5, max_instances=2,
                              resources={"CPU": 1})), instances=2)

    def driver():
        f = current_runtime().stub("e").run()
        with pytest.raises(DeadlineExceeded):
            f.value()
        return f.meta.attempt, f.meta.escalations

    attempt, esc = deployment.main(driver, runtime=rt)
    assert calls["n"] == 1                  # executed exactly once
    assert attempt == 0 and esc == 0


def test_child_call_inherits_remaining_deadline_budget():
    """The request-level budget propagates: a child future's absolute
    deadline is the parent's, and a narrower per-call budget shrinks it."""
    rt = two_node_rt()
    rt.register_agent(AgentSpec(
        name="e",
        methods={"run": emulated(FixedLatency(0.05), lambda x: x)},
        directives=Directives(resources={"CPU": 1})), instances=1)
    out = {}

    def driver():
        r = current_runtime()
        t0 = r.kernel.now()
        f_inherit = r.stub("e").run(1)
        f_narrow = r.stub("e").run(2, _hint={"deadline_s": 1.0})
        out["inherit"] = f_inherit.meta.deadline
        out["narrow"] = f_narrow.meta.deadline
        out["t0"] = t0
        f_inherit.value(), f_narrow.value()

    rt.start()
    rt.submit_request(driver, deadline_s=10.0)
    rt.run()
    assert out["inherit"] == pytest.approx(10.0)       # parent's absolute
    assert out["narrow"] == pytest.approx(out["t0"] + 1.0)  # min() applies


def test_deadline_outcomes_in_telemetry():
    rt = two_node_rt()
    rt.register_agent(AgentSpec(
        name="e",
        methods={"run": emulated(FixedLatency(0.4), lambda x: x)},
        directives=Directives(resources={"CPU": 1})), instances=1)

    def ok():
        current_runtime().stub("e").run(1).value()

    def late():
        current_runtime().stub("e").run(2).value()   # 0.4 s > 0.1 s budget

    rt.start()
    rt.submit_request(ok, deadline_s=5.0)
    rt.submit_request(late, delay=1.0, deadline_s=0.1)
    rt.run()
    dl = rt.telemetry.deadline_outcomes()
    assert dl["requests"] == 2 and dl["with_deadline"] == 2
    assert dl["deadline_missed"] == 1 and dl["unfinished"] == 0


# ------------------------------------------------------------------ hedging
def hedged_rt(service=0.2, straggler_factor=50.0):
    """Three replicas, one slowed 50x.  The HedgePolicy compares a
    candidate's elapsed time against the *median* replica EMA, so the two
    healthy replicas must carry warm EMAs before the straggler's inflated
    one can be outvoted — drivers warm them up first."""
    from repro.core import HedgePolicy
    from repro.core.policy import default_policies
    from repro.serving.chaos import slow_instance
    chain = default_policies()
    chain.policies.append(HedgePolicy(
        factor=2.0, min_delay=0.5, budget_frac=1.0, agent_types=("e",)))
    rt = NalarRuntime(simulate=True,
                      nodes={"n0": {"CPU": 16}, "n1": {"CPU": 16}},
                      policy=chain, control_interval=0.25)
    runs = {"n": 0}

    def work(x):
        runs["n"] += 1
        return x * 2

    rt.register_agent(AgentSpec(
        name="e",
        methods={"run": emulated(FixedLatency(service), work)},
        directives=Directives(max_instances=3, resources={"CPU": 1})),
        instances=3)
    victim = rt.instances_of_type("e")[0]
    slow_instance(rt, victim, factor=straggler_factor)
    return rt, victim, runs


def test_hedged_pair_first_completion_wins_never_double_materializes():
    """A future trapped on a straggler gets a hedged duplicate; a sibling
    wins, and the straggler's (much later) natural completion must neither
    re-materialize nor perturb the resolved future."""
    rt, victim, runs = hedged_rt()
    healthy = [i for i in rt.instances_of_type("e") if i != victim]

    def driver():
        r = current_runtime()
        sid = get_context()[0]
        for iid in healthy:                 # warm sibling EMAs
            r.router.pin(sid, "e", iid)
            r.stub("e").run(0).value()
        r.router.pin(sid, "e", victim)      # trap the call on the straggler
        f = r.stub("e").run(21)
        r.router.unpin(sid, "e")
        t0 = r.kernel.now()
        v = f.value()                       # hedge winner resolves it
        t_won = r.kernel.now() - t0
        winner = f.meta.executor
        run_id = f._run_id
        r.kernel.sleep(15.0)                # straggler (10 s) finishes too
        assert f.value() == v               # still the winner's result
        assert f.meta.executor == winner
        assert f._run_id == run_id          # never re-armed
        return v, t_won, winner

    v, t_won, winner = deployment.main(driver, runtime=rt)
    assert v == 42
    assert winner != victim                 # a sibling won
    assert t_won < 2.0                      # rescued, not straggler-bound
    assert rt.hedges_issued == 1
    # 2 warmups + the winning duplicate: the straggler held its slot for
    # the full 10 s but its late completion event found the future already
    # resolved and dropped the body without ever invoking compute —
    # exactly one materialization, no double-execution of the user fn
    assert runs["n"] == 3
    assert rt.telemetry.deadline_outcomes()["requests"] == 1


def test_unhedged_future_claims_its_own_completion():
    rt, victim, _ = hedged_rt()
    f_fid = "nonexistent-fid"
    assert rt.claim_hedge_completion(f_fid)     # unhedged: always claims


def test_hedge_claim_fence_is_single_winner():
    rt, victim, _ = hedged_rt()
    rt._hedges["fid-x"] = (victim, "e:1")
    assert rt.claim_hedge_completion("fid-x")       # first claim wins
    assert not rt.claim_hedge_completion("fid-x")   # second stands down
    rt._hedges.pop("fid-x", None)


# ------------------------------------------- engine-side cancellation/expiry
@pytest.fixture(scope="module")
def small_engine_setup():
    import jax

    from repro.configs import get_smoke_config
    from repro.models import build_model
    cfg = get_smoke_config("qwen3_0_6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, **kw):
    from repro.serving import InferenceEngine
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("prefix_sharing", False)   # exact free-page accounting
    return InferenceEngine(model, params, **kw)


def test_cancel_request_releases_slot_and_kv_pages(small_engine_setup):
    """The hedge loser's cancellation path: ``cancel_request`` on an
    actively-decoding request vacates its slot and returns its protected
    KV pages to the pool — no callback, no finished record."""
    from repro.serving import Request, SamplingParams
    cfg, model, params = small_engine_setup
    eng = _engine(model, params)
    free0 = eng.pool.free_pages()
    fired = []
    req = Request.make(list(range(8)),
                       sampling=SamplingParams(max_new_tokens=32))
    eng.submit_async(req, on_done=lambda r: fired.append(r))
    eng.step()                              # prefill: slot + pages held
    eng.step()                              # decoding
    assert eng.metrics.active == 1
    assert eng.pool.free_pages() < free0
    assert eng.cancel_request(req.request_id)
    assert eng.metrics.active == 0
    assert eng.pool.free_pages() == free0   # pages fully reclaimed
    eng.run_until_idle()
    eng.drain_completions()
    assert fired == []                      # loser never reports back
    assert not eng.cancel_request(req.request_id)   # idempotent


def test_cancel_request_removes_queued_request(small_engine_setup):
    from repro.serving import Request, SamplingParams
    cfg, model, params = small_engine_setup
    eng = _engine(model, params, max_batch=1)
    r1 = Request.make(list(range(6)),
                      sampling=SamplingParams(max_new_tokens=4))
    r2 = Request.make(list(range(6, 12)),
                      sampling=SamplingParams(max_new_tokens=4))
    eng.submit(r1)
    eng.submit(r2)
    eng.step()                              # r1 admitted, r2 still queued
    assert eng.metrics.queued == 1
    assert eng.cancel_request(r2.request_id)
    assert eng.metrics.queued == 0
    eng.run_until_idle()
    assert r1.finished and not r2.finished


def test_engine_preempts_expired_slot_mid_decode(small_engine_setup):
    """Deadline enforcement inside the step loop: an in-flight request
    whose wall deadline passes is preempted — slot vacated, pages
    reclaimed, ``expired`` counted, completion delivered as expired."""
    import time as _time

    from repro.serving import Request, SamplingParams
    cfg, model, params = small_engine_setup
    eng = _engine(model, params)
    free0 = eng.pool.free_pages()
    req = Request.make(list(range(8)),
                       sampling=SamplingParams(max_new_tokens=256))
    req.deadline_wall = _time.monotonic() + 60.0
    eng.submit(req)
    eng.step()
    assert eng.metrics.active == 1
    req.deadline_wall = _time.monotonic() - 0.001   # budget just ran out
    eng.step()
    assert req.expired and req.finished
    assert eng.metrics.expired == 1
    assert eng.metrics.active == 0
    assert eng.pool.free_pages() == free0
    assert req in eng.poll_finished()       # delivered, marked expired


def test_engine_rejects_expired_at_admission(small_engine_setup):
    import time as _time

    from repro.serving import Request, RequestExpired, SamplingParams
    cfg, model, params = small_engine_setup
    eng = _engine(model, params)
    req = Request.make(list(range(4)),
                       sampling=SamplingParams(max_new_tokens=4))
    req.deadline_wall = _time.monotonic() - 1.0
    with pytest.raises(RequestExpired):
        eng.submit(req)
    assert eng.metrics.expired == 1
    assert eng.queue.expired_rejects == 1


def test_waitqueue_expiry_uses_swappable_clock():
    from repro.serving import Request, RequestExpired, SamplingParams
    from repro.serving.batching import WaitQueue
    q = WaitQueue()
    t = [0.0]
    q.clock = lambda: t[0]
    r = Request.make([1, 2, 3], sampling=SamplingParams(max_new_tokens=1))
    r.deadline_wall = 5.0
    q.push(r)                               # t=0: admitted
    assert q.pop_next() is r
    t[0] = 6.0
    r2 = Request.make([4, 5], sampling=SamplingParams(max_new_tokens=1))
    r2.deadline_wall = 5.0
    with pytest.raises(RequestExpired):
        q.push(r2)                          # t=6 > deadline: rejected
    assert q.expired_rejects == 1 and r2.expired
