"""Differential harness: paged-native decode vs the dense gather path.

The paged-native data plane (engine steps consume page tables and scatter
new K/V straight into pool pages) must be *byte-identical* to the legacy
dense path (per-slot cache + gather on admission + write-back on finish):
same greedy tokens, same stochastic samples, same session cache bytes.
The equivalence is by construction — the paged step gathers the tables to
a dense view of exactly the slot-cache length and reuses the same
attention functions — and this suite locks it in across all ten zoo
configs and the scheduling scenarios that exercise every admission path:
chunked and monolithic prefill, resumed sessions, shared-prefix adoption,
and mid-stream eviction/re-admission.

Recurrent families (ssm/hybrid) have no pages; for them the differential
is fused ``decode_chunk`` vs the per-token masked fallback.
"""

import jax
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models.model import build_model
from repro.serving.engine import InferenceEngine
from repro.serving.kv_cache import PagedKVPool
from repro.serving.sampler import SamplingParams

MAX_SEQ = 64
PAGE = 8

_MODELS = {}


def _model(arch):
    if arch not in _MODELS:
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _MODELS[arch] = (model, params)
    return _MODELS[arch]


def _extras(cfg, seed=1):
    if cfg.family == "audio":
        return {"frames": np.asarray(jax.random.normal(
            jax.random.PRNGKey(seed), (cfg.encoder_seq, cfg.d_model)),
            np.float32)}
    if cfg.family == "vlm":
        return {"image_embeds": np.asarray(jax.random.normal(
            jax.random.PRNGKey(seed), (cfg.n_image_tokens, cfg.d_model)),
            np.float32)}
    return {}


def _engine(arch, paged, **kw):
    model, params = _model(arch)
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_seq", MAX_SEQ)
    kw.setdefault("page_size", PAGE)
    kw.setdefault("prefill_chunk", 4)
    kw.setdefault("rng_seed", 0)
    return InferenceEngine(model, params, paged_decode=paged, **kw)


def _session_bytes(eng, sid):
    """Dense view of the session's pooled cache (None for state pools)."""
    if not isinstance(eng.pool, PagedKVPool):
        return None
    got = eng.pool.gather_contiguous(sid, eng.max_seq)
    if got is None:
        return None
    k, v, tokens = got
    return np.asarray(k[:, :tokens]), np.asarray(v[:, :tokens]), tokens


def _assert_same_session(dense, paged, sid):
    a, b = _session_bytes(dense, sid), _session_bytes(paged, sid)
    if a is None or b is None:
        assert a is None and b is None
        return
    assert a[2] == b[2], f"{sid}: token count {a[2]} != {b[2]}"
    np.testing.assert_array_equal(a[0], b[0], err_msg=f"{sid}: K bytes")
    np.testing.assert_array_equal(a[1], b[1], err_msg=f"{sid}: V bytes")


# -------------------------------------------------------------- all configs
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_paged_matches_dense_all_archs(arch):
    """Chunked-prefill serving: greedy tokens and cached session bytes are
    byte-identical between the paged-native and dense engines."""
    cfg = get_smoke_config(arch)
    extras = _extras(cfg)
    results = {}
    for paged in (False, True):
        eng = _engine(arch, paged)
        reqs = [eng.generate(list(range(1 + j, 12 + j)), session_id=f"s{j}",
                             sampling=SamplingParams(temperature=0.0,
                                                     max_new_tokens=6),
                             **extras)
                for j in range(3)]
        results[paged] = (eng, [r.generated for r in reqs],
                          [r.decode_path for r in reqs])
    dense, paged_e = results[False][0], results[True][0]
    assert results[False][1] == results[True][1], f"{arch}: greedy mismatch"
    if isinstance(paged_e.pool, PagedKVPool) and cfg.family != "audio":
        # audio engines serve paged too, but xk/xv is per-request so the
        # acceptance here is output-level only
        assert paged_e._paged, f"{arch}: expected paged-native serving"
        assert all(p == "paged" for p in results[True][2])
        for j in range(3):
            _assert_same_session(dense, paged_e, f"s{j}")
        paged_e.pool.check_invariants()


@pytest.mark.parametrize("arch", ["qwen3_0_6b", "starcoder2_15b",
                                  "whisper_medium"])
def test_monolithic_prefill_parity(arch):
    """prefill_chunk=0 forces the legacy bucketed prefill at admission; the
    paged engine must shred that prefill cache into pool pages and decode
    to identical tokens."""
    cfg = get_smoke_config(arch)
    extras = _extras(cfg)
    outs = {}
    for paged in (False, True):
        eng = _engine(arch, paged, prefill_chunk=0)
        r = eng.generate(list(range(2, 14)), session_id="mono",
                         sampling=SamplingParams(temperature=0.0,
                                                 max_new_tokens=6),
                         **extras)
        outs[paged] = (eng, r.generated)
    assert outs[False][1] == outs[True][1]
    if cfg.family != "audio":
        _assert_same_session(outs[False][0], outs[True][0], "mono")


@pytest.mark.parametrize("arch", ["qwen3_0_6b", "starcoder2_15b",
                                  "phi_3_vision_4_2b"])
def test_resumed_session_parity(arch):
    """Follow-up requests in the same session resume from the pool: the
    paged resume adopts pages in place (zero copies) and must match the
    dense gather-restore byte for byte."""
    cfg = get_smoke_config(arch)
    extras = _extras(cfg)
    outs = {}
    for paged in (False, True):
        eng = _engine(arch, paged)
        r1 = eng.generate(list(range(1, 10)), session_id="sess",
                          sampling=SamplingParams(temperature=0.0,
                                                  max_new_tokens=4),
                          **extras)
        r2 = eng.generate(list(range(20, 26)), session_id="sess",
                          sampling=SamplingParams(temperature=0.0,
                                                  max_new_tokens=4))
        outs[paged] = (eng, r1.generated, r2.generated,
                       r2.prefix_reused_tokens)
    assert outs[False][1] == outs[True][1]
    assert outs[False][2] == outs[True][2]
    assert outs[False][3] == outs[True][3]       # same resume coverage
    if cfg.family != "vlm":      # image prefix makes resume provenance moot
        assert outs[True][0].metrics.prefix_hits > 0
    _assert_same_session(outs[False][0], outs[True][0], "sess")


def test_shared_prefix_adoption_parity():
    """A cold session admitted onto another session's indexed prefix pages
    (PR 6 sharing) behaves identically under paged-native decode — and the
    adopted pages are COW-privatized, never written in place."""
    outs = {}
    prefix = list(range(1, 17))                   # two full pages of prefix
    for paged in (False, True):
        eng = _engine("qwen3_0_6b", paged)
        ra = eng.generate(prefix + [30, 31], session_id="donor",
                          sampling=SamplingParams(temperature=0.0,
                                                  max_new_tokens=4))
        rb = eng.generate(prefix + [40, 41, 42], session_id="adopter",
                          sampling=SamplingParams(temperature=0.0,
                                                  max_new_tokens=4))
        outs[paged] = (eng, ra.generated, rb.generated)
        assert eng.metrics.shared_prefix_hits >= 1
    assert outs[False][1] == outs[True][1]
    assert outs[False][2] == outs[True][2]
    for sid in ("donor", "adopter"):
        _assert_same_session(outs[False][0], outs[True][0], sid)
    outs[True][0].pool.check_invariants()


def test_mid_stream_eviction_and_readmission():
    """A session evicted from a tight pool mid-stream must re-admit cold
    and still match the dense engine token-for-token; active slots'
    protected pages survive the pressure."""
    outs = {}
    for paged in (False, True):
        # pool big enough for ~2 resident sessions, so the third evicts LRU
        eng = _engine("qwen3_0_6b", paged, max_batch=2, pool_pages=24)
        sp = SamplingParams(temperature=0.0, max_new_tokens=4)
        seqs = {}
        for j in range(4):
            r = eng.generate(list(range(1 + 8 * j, 13 + 8 * j)),
                             session_id=f"e{j}", sampling=sp)
            seqs[f"e{j}"] = list(r.generated)
        # session e0 has likely been evicted by now: follow-up re-admits
        r = eng.generate([99, 98, 97], session_id="e0", sampling=sp)
        seqs["e0-again"] = list(r.generated)
        outs[paged] = (eng, seqs)
    assert outs[False][1] == outs[True][1]
    outs[True][0].pool.check_invariants()


def test_stochastic_sampling_parity():
    """Per-request RNG streams are path-independent: temperature sampling
    draws identical tokens on both data planes (the [B,V] rows handed to
    the sampler are bitwise identical)."""
    outs = {}
    sp = SamplingParams(temperature=0.8, top_k=8, seed=1234,
                        max_new_tokens=6)
    for paged in (False, True):
        eng = _engine("qwen3_0_6b", paged)
        r = eng.generate(list(range(3, 12)), session_id="st", sampling=sp)
        outs[paged] = r.generated
    assert outs[False] == outs[True]


# ------------------------------------------------- recurrent: fused chunk
@pytest.mark.parametrize("arch", ["mamba2_130m", "recurrentgemma_9b"])
def test_recurrent_fused_chunk_matches_masked(arch):
    """ssm/hybrid have no pages; their PR-7 data-plane change is the fused
    in-jit chunk scan.  It must match the per-token masked fallback."""
    outs = {}
    for fused in (False, True):
        eng = _engine(arch, paged=False)
        if not fused:
            eng._decode_chunk = None             # force the masked path
        r = eng.generate(list(range(1, 14)), session_id="r1",
                         sampling=SamplingParams(temperature=0.0,
                                                 max_new_tokens=6))
        outs[fused] = r.generated
    assert outs[False] == outs[True], f"{arch}: fused chunk diverged"


def test_paged_off_knob_restores_dense_plane():
    """``paged_decode=False`` keeps the full dense slot cache and the
    gather/write-back flow (the fallback knob the acceptance requires)."""
    eng = _engine("qwen3_0_6b", paged=False)
    assert not eng._paged
    assert "k" in eng.cache and "v" in eng.cache
    eng2 = _engine("qwen3_0_6b", paged=True)
    assert eng2._paged
    assert "k" not in eng2.cache and "v" not in eng2.cache
    r = eng2.generate(list(range(1, 8)), session_id="knob",
                      sampling=SamplingParams(temperature=0.0,
                                              max_new_tokens=3))
    assert r.decode_path == "paged"
    assert eng2.pool.stats["inplace_appends"] > 0
