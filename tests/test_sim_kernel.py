"""SimKernel unit tests: the ``wait_event`` timeout machinery the retry
backoff and future timeouts lean on (satellite of ISSUE 3).

Two previously-untested behaviours of the deterministic clock:
 * a timeout fires at exactly ``now + timeout`` in virtual time and returns
   False without the event being set;
 * a normal wakeup (``notify``) cancels the pending timeout handle, so the
   timeout event neither fires later nor keeps the simulation alive.
"""

import threading

import pytest

from repro.core import SimKernel


def test_wait_event_timeout_fires_at_deadline():
    k = SimKernel()
    out = {}

    def driver():
        evt = threading.Event()
        t0 = k.now()
        ok = k.wait_event(evt, timeout=2.0)
        out["ok"] = ok
        out["elapsed"] = k.now() - t0
        out["set"] = evt.is_set()

    k.spawn_driver(driver)
    end = k.run()
    assert out["ok"] is False
    assert out["elapsed"] == pytest.approx(2.0)
    assert out["set"] is False
    assert end == pytest.approx(2.0)


def test_wait_event_normal_wakeup_cancels_timeout_handle():
    k = SimKernel()
    out = {}
    evt = threading.Event()
    k.schedule(0.5, lambda: k.notify(evt))

    def driver():
        ok = k.wait_event(evt, timeout=50.0)
        out["ok"] = ok
        out["woke_at"] = k.now()

    k.spawn_driver(driver)
    end = k.run()
    assert out["ok"] is True
    assert out["woke_at"] == pytest.approx(0.5)
    # the cancelled timeout must not keep virtual time alive to t=50
    assert end == pytest.approx(0.5)
    assert k._np_count == 0             # its liveness contribution released


def test_wait_event_already_set_returns_immediately():
    k = SimKernel()
    out = {}
    evt = threading.Event()
    evt.set()

    def driver():
        out["ok"] = k.wait_event(evt, timeout=10.0)
        out["t"] = k.now()

    k.spawn_driver(driver)
    end = k.run()
    assert out["ok"] is True
    assert out["t"] == 0.0 and end == 0.0


def test_wait_event_multiple_waiters_single_notify():
    """All drivers blocked on one event wake (serialized, deterministic)."""
    k = SimKernel()
    woke = []
    evt = threading.Event()
    k.schedule(1.0, lambda: k.notify(evt))

    def make_driver(i):
        def driver():
            k.wait_event(evt, timeout=30.0)
            woke.append((i, k.now()))
        return driver

    for i in range(3):
        k.spawn_driver(make_driver(i))
    end = k.run()
    assert sorted(i for i, _ in woke) == [0, 1, 2]
    assert all(t == pytest.approx(1.0) for _, t in woke)
    assert end == pytest.approx(1.0)
