"""§5 debuggability (workflow-path traces, failure reports) + runtime
determinism (identical runs produce identical telemetry)."""

import pytest

from repro.core import (AgentSpec, Directives, FixedLatency, NalarRuntime,
                        deployment, emulated)
from repro.core.debug import failure_report, format_trace, session_report, slowest_stage
from repro.core.runtime import current_runtime
from repro.workloads import run_financial, run_swe, system_config


def build_rt():
    rt = NalarRuntime(simulate=True, nodes={"n0": {"CPU": 8}})
    rt.register_agent(AgentSpec(
        name="fast",
        methods={"run": emulated(FixedLatency(0.1), lambda x: x)},
        directives=Directives(resources={"CPU": 1})), instances=1)
    rt.register_agent(AgentSpec(
        name="slow",
        methods={"run": emulated(FixedLatency(1.0), lambda x: x)},
        directives=Directives(resources={"CPU": 1})), instances=1)
    return rt


def test_trace_renders_workflow_path():
    rt = build_rt()

    def driver():
        rt_ = current_runtime()
        a = rt_.stub("fast").run(1).value()
        return rt_.stub("slow").run(a).value()

    out = deployment.main(driver, runtime=rt)
    assert out == 1
    rec = next(iter(rt.telemetry.requests.values()))
    txt = format_trace(rec)
    assert "fast.run" in txt and "slow.run" in txt
    assert "service=" in txt and "ok" in txt
    worst = slowest_stage(rec)
    assert worst.agent_type == "slow"
    rep = session_report(rt.telemetry, rec.session_id)
    assert "1 requests" in rep and "fast,slow" in rep


def test_failure_report_names_the_agent():
    rt = build_rt()
    rt.register_agent(AgentSpec(
        name="bad",
        methods={"run": emulated(FixedLatency(0.05),
                                 lambda: (_ for _ in ()).throw(RuntimeError("x")))},
        directives=Directives(resources={"CPU": 1})), instances=1)

    def driver():
        rt_ = current_runtime()
        rt_.stub("fast").run(1).value()
        return rt_.stub("bad").run().value()

    with pytest.raises(RuntimeError):
        deployment.main(driver, runtime=rt)
    (line,) = failure_report(rt.telemetry)
    assert "failed at bad @" in line
    assert "fast.run -> bad.run" in line


@pytest.mark.parametrize("runner,kwargs", [
    (run_financial, dict(rps=2.0, n_sessions=12, seed=3)),
    (run_swe, dict(n_requests=4, seed=3)),
])
def test_workloads_are_deterministic(runner, kwargs):
    a = runner(system_config("nalar"), **kwargs)
    b = runner(system_config("nalar"), **kwargs)
    for k, v in a.items():
        if isinstance(v, float):
            assert b[k] == v, (k, v, b[k])
