"""Unit tests: futures, metadata, dependency extraction (paper §3.2, §4.3.1)."""

import threading

import pytest

from repro.core import (AgentSpec, Directives, FixedLatency, FutureState,
                        NalarRuntime, deployment, emulated)
from repro.core.future import extract_dependencies, Future, FutureMetadata


def make_rt(**kw):
    return NalarRuntime(simulate=True, nodes={"n0": {"CPU": 32}}, **kw)


def echo_agent(rt, name="echo", latency=0.1, instances=1):
    return rt.register_agent(AgentSpec(
        name=name,
        methods={"run": emulated(FixedLatency(latency), lambda x: f"done:{x}")},
        directives=Directives(max_instances=8, resources={"CPU": 1}),
    ), instances=instances)


def test_future_lifecycle_and_value():
    rt = make_rt()
    echo_agent(rt)

    def driver():
        f = rt.stub("echo").run("a")
        assert not f.available          # Op 1 created, non-blocking
        v = f.value()                   # Op 3 blocks
        assert f.available
        return v

    out = deployment.main(driver, runtime=rt)
    assert out == "done:a"


def test_value_immutable_once_materialized():
    rt = make_rt()
    f = Future(rt, FutureMetadata())
    f.materialize("x", now=0.0)
    with pytest.raises(RuntimeError):
        f.materialize("y", now=1.0)
    assert f.value() == "x"


def test_metadata_mutable_value_not():
    rt = make_rt()
    f = Future(rt, FutureMetadata(executor="a:0"))
    f.meta.executor = "a:1"             # metadata is mutable (late binding)
    f.meta.consumers.append("driver:r0")
    assert f.meta.executor == "a:1"
    f.materialize(1, now=0.0)
    assert f.state == FutureState.READY


def test_timeout():
    rt = make_rt()
    rt.register_agent(AgentSpec(
        name="slow",
        methods={"run": emulated(FixedLatency(10.0), lambda: 1)},
        directives=Directives(resources={"CPU": 1}),
    ), instances=1)

    def driver():
        f = rt.stub("slow").run()
        with pytest.raises(TimeoutError):
            f.value(timeout=1.0)
        return f.value(timeout=60.0)    # eventually fine

    assert deployment.main(driver, runtime=rt) == 1


def test_dependency_extraction_nested():
    rt = make_rt()
    f1 = Future(rt, FutureMetadata())
    f2 = Future(rt, FutureMetadata())
    deps = extract_dependencies(
        (f1, [1, f2], {"k": f1}), {"kw": (f2,), "plain": 3})
    assert deps.count(f1.fid) == 2
    assert deps.count(f2.fid) == 2


def test_future_chaining_through_agents():
    """A future passed as an argument defers execution until it's ready."""
    rt = make_rt()
    echo_agent(rt)

    def driver():
        f1 = rt.stub("echo").run("x")
        f2 = rt.stub("echo").run(f1)    # depends on f1; value flows in
        return f2.value()

    out = deployment.main(driver, runtime=rt)
    assert out == "done:done:x"
    # dependency was recorded in metadata
    futs = rt.futures.snapshot()
    f2 = max(futs, key=lambda f: int(f.fid[1:]))
    assert len(f2.meta.dependencies) == 1


def test_failure_propagates_with_traceback():
    rt = make_rt()
    rt.register_agent(AgentSpec(
        name="bad",
        methods={"run": emulated(FixedLatency(0.01),
                                 lambda: (_ for _ in ()).throw(ValueError("boom")))},
        directives=Directives(resources={"CPU": 1}),
    ), instances=1)

    def driver():
        return rt.stub("bad").run().value()

    with pytest.raises(ValueError, match="boom"):
        deployment.main(driver, runtime=rt)


def test_parallel_futures_resolve_independently():
    rt = make_rt()
    echo_agent(rt, instances=4)

    def driver():
        fs = [rt.stub("echo").run(i) for i in range(8)]
        # polling API: available is non-blocking
        ready_before = sum(f.available for f in fs)
        vals = [f.value() for f in fs]
        return ready_before, vals

    ready_before, vals = deployment.main(driver, runtime=rt)
    assert vals == [f"done:{i}" for i in range(8)]
