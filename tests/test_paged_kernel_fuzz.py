"""Property/fuzz tests for the paged-attention decode kernels.

Randomized page tables (permuted page order, -1 padding, partially filled
final pages), ragged per-row lengths, and arbitrary GQA group shapes —
the Pallas kernels (interpret mode on CPU) must match both the jnp
oracles in ``ref.py`` and a from-scratch float64 numpy dense attention
that shares no code with either.

When hypothesis is not installed, the deterministic fallback shim
(tests/_hypothesis_fallback.py) stands in.
"""

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.kernels.paged_attention.ops import (paged_decode_attention,
                                               paged_decode_chunk_attention)
from repro.kernels.paged_attention.ref import (paged_decode_chunk_ref,
                                               paged_decode_ref)

SETTINGS = dict(max_examples=20, deadline=None)
TOL = dict(rtol=2e-5, atol=2e-5)


def _random_tables(rng, B, n_pages, maxp, page, max_len):
    """Per-row ragged lengths + page tables drawn as a random *permutation*
    of the pool's pages — adjacency in the table never implies adjacency in
    the pool, and the final page is partially filled whenever
    ``len % page != 0``."""
    lens = rng.integers(1, max_len + 1, B)
    perm = rng.permutation(n_pages)
    pt = np.full((B, maxp), -1, np.int64)
    used = 0
    for b in range(B):
        need = -(-int(lens[b]) // page)            # ceil-div: pages needed
        pt[b, :need] = perm[used:used + need]
        used += need
    return jnp.asarray(lens, jnp.int32), jnp.asarray(pt, jnp.int32)


def _dense_oracle(q, kp, vp, pt, qpos, scale):
    """Independent float64 numpy attention: gather per row, mask positions
    > qpos[b, t], softmax, weighted sum.  No shared code with ref.py."""
    q, kp, vp = (np.asarray(x, np.float64) for x in (q, kp, vp))
    pt = np.asarray(pt)
    B, T, H, D = q.shape
    _, page, Hkv, _ = kp.shape
    rep = H // Hkv
    C = pt.shape[1] * page
    out = np.zeros_like(q)
    for b in range(B):
        k = kp[np.maximum(pt[b], 0)].reshape(C, Hkv, D)
        v = vp[np.maximum(pt[b], 0)].reshape(C, Hkv, D)
        for t in range(T):
            n = int(qpos[b, t]) + 1                # attends positions <= qpos
            for h in range(H):
                s = (k[:n, h // rep] @ q[b, t, h]) * scale
                w = np.exp(s - s.max())
                out[b, t, h] = (w / w.sum()) @ v[:n, h // rep]
    return out


# ------------------------------------------------- single-token paged decode
@given(st.integers(1, 4), st.integers(1, 3), st.integers(1, 4),
       st.integers(0, 10_000))
@settings(**SETTINGS)
def test_paged_decode_fuzz(B, Hkv, n_rep, seed):
    rng = np.random.default_rng(seed)
    page = int(rng.choice([4, 8, 16]))
    maxp = int(rng.integers(2, 6))
    n_pages = B * maxp + 2
    D = int(rng.choice([8, 16, 32]))
    lens, pt = _random_tables(rng, B, n_pages, maxp, page, maxp * page)
    q = jnp.asarray(rng.standard_normal((B, Hkv * n_rep, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((n_pages, page, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((n_pages, page, Hkv, D)), jnp.float32)
    out = paged_decode_attention(q, kp, vp, pt, lens, scale=D ** -0.5,
                                 n_rep=n_rep)
    ref = paged_decode_ref(q, kp, vp, pt, lens, scale=D ** -0.5, n_rep=n_rep)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)
    dense = _dense_oracle(q[:, None], kp, vp, pt,
                          np.asarray(lens)[:, None] - 1, D ** -0.5)[:, 0]
    np.testing.assert_allclose(np.asarray(out, np.float64), dense,
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------- chunked paged decode
@given(st.integers(1, 3), st.integers(1, 4), st.integers(1, 2),
       st.integers(1, 3), st.integers(0, 10_000))
@settings(**SETTINGS)
def test_paged_decode_chunk_fuzz(B, T, Hkv, n_rep, seed):
    """T-token chunk over pooled pages: row t of batch b attends positions
    <= pos[b]+t.  Pages are pre-filled past pos (the engine scatters the
    chunk's K/V before attending on the non-windowed path)."""
    rng = np.random.default_rng(seed)
    page = int(rng.choice([4, 8]))
    maxp = int(rng.integers(2, 5))
    n_pages = B * maxp + 2
    D = int(rng.choice([8, 16]))
    # pos = tokens already cached; chunk occupies pos .. pos+T-1, so the
    # table must cover pos+T positions (partial final page exercised when
    # (pos+T) % page != 0)
    total, pt = _random_tables(rng, B, n_pages, maxp, page, maxp * page)
    pos = jnp.maximum(total - T, 0)
    q = jnp.asarray(rng.standard_normal((B, T, Hkv * n_rep, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((n_pages, page, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((n_pages, page, Hkv, D)), jnp.float32)
    out = paged_decode_chunk_attention(q, kp, vp, pt, pos, scale=D ** -0.5,
                                       n_rep=n_rep)
    ref = paged_decode_chunk_ref(q, kp, vp, pt, pos, scale=D ** -0.5,
                                 n_rep=n_rep)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)
    qpos = np.asarray(pos)[:, None] + np.arange(T)[None, :]
    dense = _dense_oracle(q, kp, vp, pt, qpos, D ** -0.5)
    np.testing.assert_allclose(np.asarray(out, np.float64), dense,
                               rtol=1e-4, atol=1e-4)


def test_paged_chunk_permutation_invariance():
    """Relabeling pool pages (and permuting the table to match) must not
    change the output: the kernel may depend only on the *logical* layout
    the table describes, never on physical page ids."""
    rng = np.random.default_rng(7)
    B, T, Hkv, n_rep, D, page, maxp, n_pages = 2, 3, 2, 2, 16, 4, 4, 12
    lens, pt = _random_tables(rng, B, n_pages, maxp, page, maxp * page)
    pos = jnp.maximum(lens - T, 0)
    q = jnp.asarray(rng.standard_normal((B, T, Hkv * n_rep, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((n_pages, page, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((n_pages, page, Hkv, D)), jnp.float32)
    base = paged_decode_chunk_attention(q, kp, vp, pt, pos, scale=D ** -0.5,
                                        n_rep=n_rep)
    perm = rng.permutation(n_pages)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(n_pages)                # new id of old page p
    pt_p = jnp.where(pt >= 0, jnp.asarray(inv)[jnp.maximum(pt, 0)], -1)
    relabeled = paged_decode_chunk_attention(
        q, jnp.asarray(np.asarray(kp)[perm]), jnp.asarray(np.asarray(vp)[perm]),
        pt_p.astype(jnp.int32), pos, scale=D ** -0.5, n_rep=n_rep)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(relabeled))


def test_paged_chunk_ignores_garbage_beyond_pos():
    """Bytes past ``pos+t`` in the gathered window — stale page tails,
    -1-padded table slots aliased to page 0 — must not leak into the
    output (the COW pool recycles pages without zeroing them)."""
    rng = np.random.default_rng(11)
    B, T, Hkv, n_rep, D, page, maxp, n_pages = 2, 2, 1, 2, 8, 4, 3, 8
    lens = jnp.asarray([5, 9], jnp.int32)         # partial final pages
    pt = jnp.asarray([[2, 4, -1], [6, 1, 3]], jnp.int32)
    pos = lens - T
    q = jnp.asarray(rng.standard_normal((B, T, Hkv * n_rep, D)), jnp.float32)
    kp = rng.standard_normal((n_pages, page, Hkv, D)).astype(np.float32)
    vp = rng.standard_normal((n_pages, page, Hkv, D)).astype(np.float32)
    out = paged_decode_chunk_attention(q, jnp.asarray(kp), jnp.asarray(vp),
                                       pt, pos, scale=D ** -0.5, n_rep=n_rep)
    # trash every byte beyond each row's visible range (and all unused pages)
    used = np.zeros((n_pages, page), bool)
    for b in range(B):
        for t_ in range(int(lens[b])):
            used[np.asarray(pt)[b, t_ // page], t_ % page] = True
    kp2, vp2 = kp.copy(), vp.copy()
    kp2[~used] = 1e9
    vp2[~used] = -1e9
    out2 = paged_decode_chunk_attention(q, jnp.asarray(kp2), jnp.asarray(vp2),
                                        pt, pos, scale=D ** -0.5, n_rep=n_rep)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
