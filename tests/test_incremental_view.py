"""Incremental view collection: delta scans, persistent ClusterView,
indexed FutureTable, and batched publication (the Fig. 10 control plane).

The centerpiece is a property-style equivalence test: after any randomized
interleaving of future creation / completion / failure / retry / cancel / GC
and instance kill / provision, N rounds of delta collection must leave the
persistent ClusterView identical to a from-scratch rebuild.
"""

import random

import pytest

from repro.core import (AgentSpec, Directives, FixedLatency, NalarRuntime,
                        SRTFSchedule, default_policies, emulated)
from repro.core.policy import ActionSink
from repro.core.session import clear_context, set_context


# ---------------------------------------------------------------- helpers
def make_runtime(seed=0, gc_threshold=24):
    rt = NalarRuntime(
        simulate=True,
        nodes={"n0": {"CPU": 8}, "n1": {"CPU": 8}},
        policy=default_policies(),
        control_interval=1e9,          # rounds driven manually
        future_gc_threshold=gc_threshold,
        seed=seed)

    fail_always = object()
    fail_once_seen = set()

    def work_fn(x):
        if x is fail_always:
            raise RuntimeError("permanent failure")
        if isinstance(x, tuple) and x[0] == "flaky" and x not in fail_once_seen:
            fail_once_seen.add(x)
            raise RuntimeError("transient failure")
        return x

    for name in ("work", "tool"):
        rt.register_agent(AgentSpec(
            name=name,
            methods={"run": emulated(FixedLatency(0.05), work_fn)},
            directives=Directives(max_instances=4, min_instances=1,
                                  max_retries=2, retry_backoff=0.01,
                                  resources={"CPU": 1})), instances=2)
    return rt, fail_always


def call(rt, sid, agent, arg):
    rid = rt.sessions.new_request(sid)
    set_context(sid, rid, f"driver:{rid}")
    try:
        return rt.stub(agent).run(arg)
    finally:
        clear_context()


def assert_views_equal(dv, fv):
    assert dv.instances == fv.instances
    norm = lambda bt: {k: sorted(v) for k, v in bt.items() if v}  # noqa: E731
    assert norm(dv.by_type) == norm(fv.by_type)
    assert dv.futures == fv.futures
    assert dv.session_priority == fv.session_priority
    assert dv.kv_residency == fv.kv_residency
    assert dv.blacklisted == fv.blacklisted


def assert_indexes_consistent(rt):
    """The table's counters/indexes must equal a brute-force recount."""
    live, by_exec, by_type = {}, {}, {}
    for f in rt.futures.snapshot():
        if f.available:
            continue
        if f.meta.session_id:
            live[f.meta.session_id] = live.get(f.meta.session_id, 0) + 1
        if f.meta.executor:
            by_exec.setdefault(f.meta.executor, set()).add(f.fid)
        if f.meta.agent_type:
            by_type.setdefault(f.meta.agent_type, set()).add(f.fid)
    table = rt.futures
    assert table.live_sessions() == set(live)
    for sid, n in live.items():
        assert table.live_count(sid) == n
    with table._lock:
        exec_keys = set(table._live_by_executor)
        type_keys = set(table._live_by_type)
    assert exec_keys == set(by_exec)
    assert type_keys == set(by_type)
    for iid, fids in by_exec.items():
        assert {f.fid for f in table.live_of_executor(iid)} == fids
    for at, fids in by_type.items():
        assert {f.fid for f in table.live_of_type(at)} == fids


# ------------------------------------------------- equivalence (tentpole)
@pytest.mark.parametrize("seed", range(5))
def test_delta_view_equals_full_rebuild_under_random_interleavings(seed):
    rt, fail_always = make_runtime(seed=seed)
    gc = rt.global_controller
    gc.full_rebuild_interval = 0       # delta-only after bootstrap: any
    # drift the escape hatch would mask must fail this test instead
    rng = random.Random(seed)
    sessions = [rt.sessions.new_session().session_id for _ in range(6)]
    created = []
    t = [0.0]

    def advance():
        t[0] += rng.uniform(0.01, 0.3)
        rt.kernel.run(max_time=t[0])

    def op_call():
        agent = rng.choice(("work", "tool"))
        roll = rng.random()
        if roll < 0.15:
            arg = fail_always
        elif roll < 0.4:
            arg = ("flaky", rng.randrange(1000))
        else:
            arg = rng.randrange(1000)
        created.append(call(rt, rng.choice(sessions), agent, arg))

    def op_cancel():
        live = [f for f in created if not f.available]
        if live:
            rt.cancel_future(rng.choice(live))

    def op_cancel_session():
        rt.cancel_session(rng.choice(sessions))

    def op_kill():
        iids = rt.instances_of_type(rng.choice(("work", "tool")))
        if iids:
            rt.kill_instance(rng.choice(iids), hard=rng.random() < 0.3)

    def op_provision():
        rt.provision_instance(rng.choice(("work", "tool")),
                              rng.choice(("n0", "n1")))

    ops = [op_call] * 6 + [op_cancel, op_cancel_session, op_kill,
                           op_provision]
    gc.run_once()                       # bootstrap (full rebuild)
    for step in range(40):
        rng.choice(ops)()
        advance()
        if rng.random() < 0.5:
            gc.run_once()               # delta round
        if step % 10 == 9:
            dv = gc.collect_view()              # delta
            fv = gc.collect_view(full=True)     # from-scratch rebuild
            assert_views_equal(dv, fv)
            assert_indexes_consistent(rt)
    t[0] += 50.0
    rt.kernel.run(max_time=t[0])        # quiesce
    dv = gc.collect_view()
    fv = gc.collect_view(full=True)
    assert_views_equal(dv, fv)
    assert_indexes_consistent(rt)
    assert gc.delta_rounds > 0          # the delta path actually ran
    rt.shutdown()


def test_periodic_full_rebuild_escape_hatch():
    rt, _ = make_runtime()
    gc = rt.global_controller
    gc.full_rebuild_interval = 3
    for _ in range(8):
        gc.run_once()
    # round 1 bootstraps, then every 3 delta rounds a rebuild fires
    assert gc.rebuild_rounds >= 2
    assert gc.delta_rounds >= 4
    rt.shutdown()


# ------------------------------------------- live counters (satellite 3)
def test_completed_then_gcd_future_decrements_session_exactly_once():
    """Regression: GC retirement must not decrement a session's live
    counter again — resolution already did."""
    rt, _ = make_runtime(gc_threshold=4)
    sid = rt.sessions.new_session().session_id

    futs = [call(rt, sid, "work", i) for i in range(3)]
    assert rt.futures.live_count(sid) == 3
    rt.kernel.run(max_time=10.0)
    assert all(f.available for f in futs)
    assert rt.futures.live_count(sid) == 0

    # overflow the table so the resolved futures are GC'd
    other = rt.sessions.new_session().session_id
    keep = [call(rt, other, "work", 100 + i) for i in range(6)]
    assert rt.futures.retired >= 3
    assert rt.futures.live_count(sid) == 0         # not decremented again

    # the counter still tracks new work for the same session exactly
    f = call(rt, sid, "work", 7)
    assert rt.futures.live_count(sid) == 1
    rt.kernel.run(max_time=20.0)
    assert rt.futures.live_count(sid) == 0
    assert f.available and all(k.available for k in keep)
    rt.shutdown()


def test_collect_view_waiting_pruned_via_counters_without_mirror_change():
    """A session that goes dead between rounds is pruned from the persistent
    view's waiting lists even when the instance mirror itself never
    republishes (the dirty-session refresh path)."""
    rt, _ = make_runtime()
    gc = rt.global_controller
    iid = rt.instances_of_type("work")[0]
    store = rt.stores.get(rt.instance(iid).node_id)
    sid = rt.sessions.new_session().session_id
    f = call(rt, sid, "work", 1)
    gc.run_once()                                   # bootstrap

    # forge a stale mirror claiming the session still waits here, scan it
    # into the view, then resolve the session WITHOUT touching the mirror
    store.hset(f"metrics:{iid}", "waiting_sessions", [sid])
    view = gc.collect_view()
    assert sid in view.instances[iid].waiting_sessions
    rt.kernel.run(max_time=10.0)
    assert f.available and rt.futures.live_count(sid) == 0
    # simulate "no republish": overwrite the mirror's waiting claim again
    store.hset(f"metrics:{iid}", "waiting_sessions", [sid])
    view = gc.collect_view()
    assert sid not in view.instances[iid].waiting_sessions
    # ...and a revived session resurfaces from the same raw mirror data
    call(rt, sid, "work", 2)
    view = gc.collect_view()
    assert sid in view.instances[iid].waiting_sessions
    rt.kernel.run(max_time=20.0)
    rt.shutdown()


# --------------------------------------------- batched publication (IV)
def test_metrics_publishes_coalesce_inside_batch():
    rt, _ = make_runtime()
    iid = rt.instances_of_type("work")[0]
    ctrl = rt.controller_of(iid)
    store = rt.stores.get(ctrl.inst.node_id)
    before = store.write_ops
    with ctrl._metrics_batch():
        ctrl._publish_metrics()
        ctrl._publish_metrics()
        ctrl._publish_metrics()
    assert store.write_ops == before + 1
    ctrl._publish_metrics()                 # unbatched: writes through
    assert store.write_ops == before + 2
    rt.shutdown()


def test_completion_coalesces_metric_writes():
    """One completion event = one metrics-mirror write (dequeue + completion
    + re-dispatch bookkeeping all fold into the batch)."""
    rt, _ = make_runtime()
    sid = rt.sessions.new_session().session_id
    f = call(rt, sid, "work", 1)
    rt.kernel.run(max_time=0.04)            # dispatched, not yet complete
    iid = f.meta.executor
    store = rt.stores.get(rt.instance(iid).node_id)
    before = store.write_ops
    rt.kernel.run(max_time=10.0)            # completion fires
    assert f.available
    writes = store.write_ops - before
    # completion flush + future-mirror upkeep; never the 3+ metric writes
    # of the unbatched path
    assert writes <= 3
    rt.shutdown()


def test_apply_batches_command_writes_per_destination():
    rt, _ = make_runtime()
    gc = rt.global_controller
    iids = rt.instances_of_type("work")
    src, dst = iids[0], iids[1]
    store = rt.stores.get(rt.instance(src).node_id)
    key = f"cmd:{src}"
    v0 = store.version(key)
    got = []
    store.subscribe(key, lambda fld, val: got.append(fld))
    sink = ActionSink()
    sink.migrate("sA", src, dst)
    sink.migrate("sB", src, dst)
    gc.apply(sink)
    # two commands, ONE store write; both fields delivered to the consumer
    assert store.version(key) == v0 + 1
    assert sorted(got) == ["mig:sA", "mig:sB"]
    rt.shutdown()


def test_apply_flushes_commands_before_direct_actions():
    """Ordering barrier: a migrate emitted before a kill must land on the
    command key before the kill executes — batching must not reorder a
    policy's action sequence."""
    rt, _ = make_runtime()
    gc = rt.global_controller
    iids = rt.instances_of_type("work")
    src, dst = iids[0], iids[1]
    store = rt.stores.get(rt.instance(src).node_id)
    order = []
    store.subscribe(f"cmd:{src}", lambda fld, val: order.append(
        ("cmd", rt.instance(src).alive)))
    sink = ActionSink()
    sink.migrate("sA", src, dst)
    sink.kill(src)
    gc.apply(sink)
    # the command arrived while the instance was still alive
    assert order == [("cmd", True)]
    rt.shutdown()


def test_apply_batches_schedule_installs():
    rt, _ = make_runtime()
    gc = rt.global_controller
    sink = ActionSink()
    sink.install_schedule("work", SRTFSchedule())
    gc.apply(sink)
    for iid in rt.instances_of_type("work"):
        ctrl = rt.controller_of(iid)
        assert isinstance(ctrl.schedule_policy, SRTFSchedule)
    rt.shutdown()


# --------------------------------------------------- future-table indexes
def test_future_table_secondary_indexes_follow_execution():
    rt, _ = make_runtime()
    sid = rt.sessions.new_session().session_id
    f = call(rt, sid, "work", 42)
    assert {x.fid for x in rt.futures.live_of_type("work")} == {f.fid}
    rt.kernel.run(max_time=0.04)            # routed: executor assigned
    assert f.meta.executor
    assert {x.fid for x in rt.futures.live_of_executor(f.meta.executor)} \
        == {f.fid}
    rt.kernel.run(max_time=10.0)            # resolved: indexes emptied
    assert f.available
    assert rt.futures.live_of_type("work") == []
    assert rt.futures.live_of_executor(f.meta.executor) == []
    assert rt.futures.futures_of_session(sid) != []   # registry keeps it
    rt.shutdown()


def test_mirror_single_homing():
    """Re-homing a future's mirror scrubs the copy on the previous node —
    the incremental view never has to arbitrate between stale duplicates."""
    rt, _ = make_runtime()
    sid = rt.sessions.new_session().session_id
    f = call(rt, sid, "work", 1)
    rt.mirror_future(f)
    homes = lambda: [s.node_id for s in rt.stores.all_stores()  # noqa: E731
                     if s.hgetall(f"future:{f.fid}")]
    assert len(homes()) == 1
    # force a re-home: pretend the executor moved to the other node
    other = next(i for i in rt.instances_of_type("work")
                 if rt.instance(i).node_id != homes()[0])
    rt.futures.set_executor(f, other)
    rt.mirror_future(f)
    assert homes() == [rt.instance(other).node_id]
    assert f.meta.mirror_nodes == [rt.instance(other).node_id]
    rt.kernel.run(max_time=10.0)
    rt.shutdown()
