"""Pallas kernel validation: shape/dtype sweeps, assert_allclose vs ref.py
oracles (interpret=True executes kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.paged_attention.ops import (decode_attention_kernel,
                                               paged_decode_attention)
from repro.kernels.paged_attention.ref import decode_ring_ref, paged_decode_ref
from repro.kernels.rglru_scan.ops import rglru_scan_fused
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.ssd.ops import ssd_fused
from repro.kernels.ssd.ref import ssd_ref, ssd_sequential_ref

TOL = {"float32": dict(rtol=2e-5, atol=2e-5),
       "bfloat16": dict(rtol=3e-2, atol=3e-2)}


def _mk(shape, dtype, key, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ------------------------------------------------------------- flash attn
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("B,S,H,D", [(1, 128, 2, 64), (2, 200, 4, 32),
                                     (1, 384, 1, 128)])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 96),
                                           (False, None)])
def test_flash_attention_sweep(dtype, B, S, H, D, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (_mk((B, S, H, D), dtype, kk) for kk in ks)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    ref = attention_ref(qf, kf, vf, causal=causal, window=window)
    ref = ref.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


def test_flash_attention_cross_lengths():
    """Sq != Skv (e.g. chunked prefill against a longer KV)."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _mk((2, 64, 2, 32), "float32", ks[0])
    k = _mk((2, 192, 2, 32), "float32", ks[1])
    v = _mk((2, 192, 2, 32), "float32", ks[2])
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    qf = q.transpose(0, 2, 1, 3).reshape(4, 64, 32)
    kf = k.transpose(0, 2, 1, 3).reshape(4, 192, 32)
    vf = v.transpose(0, 2, 1, 3).reshape(4, 192, 32)
    ref = attention_ref(qf, kf, vf, causal=False)
    ref = ref.reshape(2, 2, 64, 32).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------- decode kernels
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("B,C,Hkv,n_rep,D", [(2, 128, 1, 4, 64),
                                             (3, 256, 2, 2, 32),
                                             (1, 96, 4, 1, 128)])
@pytest.mark.parametrize("window", [None, 48])
def test_decode_ring_sweep(dtype, B, C, Hkv, n_rep, D, window):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    H = Hkv * n_rep
    q = _mk((B, 1, H, D), dtype, ks[0])
    ck = _mk((B, C, Hkv, D), dtype, ks[1])
    cv = _mk((B, C, Hkv, D), dtype, ks[2])
    pos = jax.random.randint(ks[3], (B,), 1, 2 * C)  # incl. wrapped positions
    if window is None:
        pos = jnp.minimum(pos, C - 1)
    out = decode_attention_kernel(q, ck, cv, pos, window=window,
                                  scale=D ** -0.5, n_rep=n_rep)
    ref = decode_ring_ref(q, ck, cv, pos, scale=D ** -0.5, n_rep=n_rep,
                          window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_paged_decode_sweep(dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    B, Hkv, n_rep, D = 3, 2, 4, 64
    n_pages, page, maxp = 24, 64, 5
    H = Hkv * n_rep
    q = _mk((B, H, D), dtype, ks[0])
    kp = _mk((n_pages, page, Hkv, D), dtype, ks[1])
    vp = _mk((n_pages, page, Hkv, D), dtype, ks[2])
    pt = jnp.array([[3, 7, 11, -1, -1],
                    [0, 1, 2, 4, 6],
                    [5, -1, -1, -1, -1]], jnp.int32)
    lens = jnp.array([150, 300, 17], jnp.int32)
    out = paged_decode_attention(q, kp, vp, pt, lens, scale=D ** -0.5,
                                 n_rep=n_rep)
    ref = paged_decode_ref(q, kp, vp, pt, lens, scale=D ** -0.5, n_rep=n_rep)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


# --------------------------------------------------------------- rglru scan
@pytest.mark.parametrize("B,S,W", [(1, 64, 32), (2, 300, 96), (3, 128, 256)])
@pytest.mark.parametrize("with_h0", [False, True])
def test_rglru_scan_sweep(B, S, W, with_h0):
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    a = jax.random.uniform(ks[0], (B, S, W), minval=0.7, maxval=0.999)
    b = _mk((B, S, W), "float32", ks[1], scale=0.1)
    h0 = _mk((B, W), "float32", ks[2]) if with_h0 else None
    out = rglru_scan_fused(a, b, h0)
    ref = rglru_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------- ssd
@pytest.mark.parametrize("B,S,H,P,N,chunk", [(1, 128, 2, 32, 64, 64),
                                             (2, 256, 3, 64, 128, 128),
                                             (1, 192, 1, 16, 32, 64)])
def test_ssd_kernel_sweep(B, S, H, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = _mk((B, S, H, P), "float32", ks[0], 0.5)
    dt = jax.nn.softplus(_mk((B, S, H), "float32", ks[1]))
    A = jnp.abs(_mk((H,), "float32", ks[2])) + 0.1
    Bm = _mk((B, S, N), "float32", ks[3], 0.3)
    Cm = _mk((B, S, N), "float32", ks[4], 0.3)
    out = ssd_fused(x, dt, A, Bm, Cm, chunk=chunk)
    ref = ssd_ref(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunked_matches_sequential_ground_truth():
    """Validates the model's own SSD reference against a token-by-token
    recurrence — the oracle's oracle."""
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    B, S, H, P, N = 2, 128, 2, 16, 32
    x = _mk((B, S, H, P), "float32", ks[0], 0.5)
    dt = jax.nn.softplus(_mk((B, S, H), "float32", ks[1]))
    A = jnp.abs(_mk((H,), "float32", ks[2])) + 0.1
    Bm = _mk((B, S, N), "float32", ks[3], 0.3)
    Cm = _mk((B, S, N), "float32", ks[4], 0.3)
    ref = ssd_ref(x, dt, A, Bm, Cm, chunk=32)
    seq = ssd_sequential_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(seq),
                               rtol=1e-4, atol=1e-4)


def test_model_attention_pallas_path_matches_xla():
    """attention_impl='pallas' through the real model layer."""
    from repro.configs import get_smoke_config
    from repro.models import build_model
    cfg = get_smoke_config("qwen3_0_6b").replace(dtype="float32")
    m_x = build_model(cfg, attention_impl="xla")
    m_p = build_model(cfg, attention_impl="pallas")
    params = m_x.init(jax.random.PRNGKey(7))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(8), (2, 64), 0,
                                          cfg.vocab_size)}
    lx, _ = m_x.forward(params, batch)
    lp, _ = m_p.forward(params, batch)
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lp),
                               rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------- moe expert ffn
@pytest.mark.parametrize("E,C,D,F,bc,bf", [(2, 128, 64, 128, 64, 64),
                                           (3, 200, 32, 96, 64, 32),
                                           (1, 64, 128, 64, 128, 64)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_moe_ffn_sweep(E, C, D, F, bc, bf, dtype):
    from repro.kernels.moe_ffn.ops import moe_ffn_fused
    from repro.kernels.moe_ffn.ref import moe_ffn_ref
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    xe = _mk((E, C, D), dtype, ks[0], 0.5)
    wg = _mk((E, D, F), dtype, ks[1], 0.1)
    wu = _mk((E, D, F), dtype, ks[2], 0.1)
    wd = _mk((E, F, D), dtype, ks[3], 0.1)
    out = moe_ffn_fused(xe, wg, wu, wd, block_c=bc, block_f=bf)
    ref = moe_ffn_ref(xe, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


def test_model_decode_pallas_path_matches_xla():
    """attention_impl='pallas' through the real decode path (ring kernel)."""
    from repro.configs import get_smoke_config
    from repro.models import build_model
    cfg = get_smoke_config("qwen3_0_6b").replace(dtype="float32")
    m_x = build_model(cfg, attention_impl="xla")
    m_p = build_model(cfg, attention_impl="pallas")
    params = m_x.init(jax.random.PRNGKey(10))
    toks = jax.random.randint(jax.random.PRNGKey(11), (2, 16), 0,
                              cfg.vocab_size)
    lx, cx = m_x.prefill(params, {"tokens": toks}, pad_cache_to=24)
    lp, cp = m_p.prefill(params, {"tokens": toks}, pad_cache_to=24)
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lp),
                               rtol=2e-4, atol=2e-4)
    tok = jnp.argmax(lx, -1).astype(jnp.int32)
    for _ in range(3):
        lx, cx = m_x.decode_step(params, tok, cx)
        lp, cp = m_p.decode_step(params, tok, cp)
        np.testing.assert_allclose(np.asarray(lx), np.asarray(lp),
                                   rtol=2e-4, atol=2e-4)
        tok = jnp.argmax(lx, -1).astype(jnp.int32)
