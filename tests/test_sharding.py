"""Distribution-layer tests.

Rule-level checks run in-process (PartitionSpec construction only); the
lower+compile integration check runs in a subprocess so the 8 fake XLA
host devices never leak into the main test process (tests must see 1
device, per the dry-run contract).
"""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_param_specs_divisibility_rules():
    """Every spec only shards dims that divide the axis size."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.configs import ARCH_IDS, get_config
        from repro.distributed.sharding import ShardingRules, axis_size
        from repro.models import build_model

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            model = build_model(cfg)
            shapes = model.param_shapes()
            for mode in ("train", "serve"):
                rules = ShardingRules(cfg, mesh, mode=mode)
                specs = rules.param_specs(shapes)
                flat_shapes = jax.tree_util.tree_leaves(shapes)
                flat_specs = jax.tree_util.tree_leaves(
                    specs, is_leaf=lambda x: isinstance(x, P))
                assert len(flat_shapes) == len(flat_specs)
                for sh, sp in zip(flat_shapes, flat_specs):
                    assert len(sp) <= len(sh.shape)
                    for dim, ax in zip(sh.shape, sp):
                        if ax is None:
                            continue
                        n = axis_size(mesh, ax)
                        assert dim % n == 0, (arch, mode, sh.shape, sp)
        print("RULES-OK")
    """)
    out = subprocess.run([sys.executable, "-c", code],
                         env={**os.environ, "PYTHONPATH": SRC},
                         capture_output=True, text=True, timeout=560)
    assert "RULES-OK" in out.stdout, out.stdout + out.stderr


def test_smoke_config_lowers_and_compiles_on_mini_mesh():
    """End-to-end dry-run (train + decode) on a 2x4 fake-device mesh."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from repro.launch.dryrun import lower_combo
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for arch, shape in [("qwen3-0.6b", "train_4k"),
                            ("granite-moe-1b-a400m", "prefill_32k"),
                            ("mamba2-130m", "decode_32k"),
                            ("recurrentgemma-9b", "long_500k")]:
            rec = lower_combo(arch, shape, mesh=mesh)
            assert "error" not in rec, rec
            assert rec["flops_per_device_raw"] > 0
        print("LOWER-OK")
    """)
    out = subprocess.run([sys.executable, "-c", code],
                         env={**os.environ, "PYTHONPATH": SRC},
                         capture_output=True, text=True, timeout=560)
    assert "LOWER-OK" in out.stdout, out.stdout[-2000:] + out.stderr[-3000:]


def test_collective_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %all-gather = f32[64,256]{0,1} all-gather(%copy), channel_id=1, replica_groups=[2,4]<=[8], metadata={op_name="jit(f)/x"}
  %all-reduce.1 = f32[128]{0} all-reduce(%p), replica_groups=[2,4]<=[8], metadata={op_name="jit(f)/while/body/y"}
  %other = f32[8]{0} add(%a, %b)
"""
    out = collective_bytes(hlo, loop_trip_counts=[10], total_devices=8)
    # all-gather: 64*256*4 bytes * 3/4
    assert out["all-gather"] == 64 * 256 * 4 * 3 / 4
    # all-reduce inside loop: 2 * 128*4 * 3/4 * 10 trips
    assert out["all-reduce"] == 2 * 128 * 4 * 3 / 4 * 10
    assert out["total"] == out["all-gather"] + out["all-reduce"]


def test_mesh_constants():
    from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
    assert PEAK_FLOPS_BF16 == 197e12 and HBM_BW == 819e9 and ICI_BW == 50e9
