"""Serving engine integration: continuous batching, paged KV pool, prefix
reuse, NALAR KV-registry hints, session migration between engines."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import KVRegistry
from repro.models import build_model
from repro.serving import (InferenceEngine, PagedKVPool, Request,
                           SamplingParams, StateCachePool)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_smoke_config("qwen3_0_6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def make_engine(model, params, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_seq", 128)
    return InferenceEngine(model, params, **kw)


def test_continuous_batching_completes_all(dense_setup):
    cfg, model, params = dense_setup
    eng = make_engine(model, params)
    rng = np.random.default_rng(0)
    reqs = [Request.make(rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 24))),
                         sampling=SamplingParams(max_new_tokens=6))
            for _ in range(9)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    assert all(r.finished for r in reqs)
    assert all(len(r.generated) == 6 for r in reqs)
    assert eng.metrics.completed == 9


def test_deterministic_greedy_output(dense_setup):
    cfg, model, params = dense_setup
    prompt = list(range(1, 11))
    outs = []
    for _ in range(2):
        eng = make_engine(model, params)
        r = eng.generate(prompt, sampling=SamplingParams(max_new_tokens=5))
        outs.append(r.generated)
    assert outs[0] == outs[1]


def test_batched_equals_unbatched_greedy(dense_setup):
    """Continuous batching must not change greedy outputs."""
    cfg, model, params = dense_setup
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=12).tolist()
               for _ in range(4)]
    solo = []
    for p in prompts:
        eng = make_engine(model, params, max_batch=1)
        solo.append(eng.generate(p, sampling=SamplingParams(max_new_tokens=4)).generated)
    eng = make_engine(model, params, max_batch=4)
    reqs = [Request.make(p, sampling=SamplingParams(max_new_tokens=4))
            for p in prompts]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    assert [r.generated for r in reqs] == solo


def test_prefix_reuse_same_session(dense_setup):
    cfg, model, params = dense_setup
    eng = make_engine(model, params)
    r1 = eng.generate(list(range(8)), session_id="sess",
                      sampling=SamplingParams(max_new_tokens=4))
    assert eng.metrics.prefix_hits == 0
    r2 = eng.generate(list(range(8, 12)), session_id="sess",
                      sampling=SamplingParams(max_new_tokens=4))
    assert r2.finished
    assert eng.metrics.prefix_hits == 1
    assert r2.prefix_reused_tokens > 0


def test_kv_registry_drop_hint_evicts(dense_setup):
    cfg, model, params = dense_setup
    reg = KVRegistry()
    eng = make_engine(model, params, kv_registry=reg, instance_id="llm:0")
    eng.generate(list(range(8)), session_id="s0",
                 sampling=SamplingParams(max_new_tokens=3))
    assert eng.pool.session("s0") is not None
    reg.release("s0")                      # session over -> drop hint
    assert eng.pool.session("s0") is None


def test_kv_migration_between_engines(dense_setup):
    """The paper's K,V migration: session cache moves across instances."""
    cfg, model, params = dense_setup
    reg = KVRegistry()
    e0 = make_engine(model, params, kv_registry=reg, instance_id="llm:0")
    e1 = make_engine(model, params, kv_registry=reg, instance_id="llm:1")
    e0.generate(list(range(10)), session_id="s0",
                sampling=SamplingParams(max_new_tokens=3))
    payload = e0.pool.export_session("s0")
    assert payload is not None
    assert e1.pool.import_session("s0", payload)
    tokens = reg.migrate("s0", "llm:0", "llm:1")
    assert e0.pool.session("s0") is None       # migrate_out hook freed pages
    # follow-up on the new instance reuses the migrated prefix
    r = e1.generate(list(range(10, 14)), session_id="s0",
                    sampling=SamplingParams(max_new_tokens=3))
    assert r.finished and e1.metrics.prefix_hits == 1


def test_ssm_engine_state_cache():
    cfg = get_smoke_config("mamba2_130m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    eng = InferenceEngine(model, params, max_batch=2, max_seq=64)
    assert isinstance(eng.pool, StateCachePool)
    r = eng.generate(list(range(12)), session_id="s",
                     sampling=SamplingParams(max_new_tokens=5))
    assert r.finished and len(r.generated) == 5
    assert eng.pool.load("s") is not None      # O(1) state stored


def test_paged_pool_allocation_and_eviction():
    cfg = get_smoke_config("qwen3_0_6b")
    pool = PagedKVPool(cfg, n_pages=8, page_size=16)
    sp = pool.allocate("a", 40, now=1.0)       # 3 pages
    assert len(sp.pages) == 3
    pool.allocate("b", 60, now=2.0)            # 4 pages
    assert pool.free_pages() == 1
    # "a" is LRU and unpinned -> evicted to make room
    sp_c = pool.allocate("c", 30, now=3.0)
    assert sp_c is not None
    assert pool.session("a").pages == []


def test_paged_pool_pin_blocks_eviction():
    cfg = get_smoke_config("qwen3_0_6b")
    pool = PagedKVPool(cfg, n_pages=4, page_size=16)
    pool.allocate("a", 64, now=1.0)            # all 4 pages
    pool.on_hint("a", "retain")
    assert pool.allocate("b", 32, now=2.0) is None   # pinned: cannot evict
    pool.on_hint("a", "drop")
    assert pool.allocate("b", 32, now=3.0) is not None


def test_priority_admission_order(dense_setup):
    cfg, model, params = dense_setup
    eng = make_engine(model, params, max_batch=1)
    lo = Request.make(list(range(6)), priority=0.0, now=0.0,
                      sampling=SamplingParams(max_new_tokens=2))
    hi = Request.make(list(range(6)), priority=5.0, now=1.0,
                      sampling=SamplingParams(max_new_tokens=2))
    eng.submit(lo)
    eng.submit(hi)
    eng.run_until_idle()
    assert hi.finished_at <= lo.finished_at    # high priority admitted first


def test_paged_kernel_reads_engine_pool(dense_setup):
    """The Pallas paged-decode kernel consumes the engine pool's page
    tables directly (vLLM-style): kernel(pool pages, page table) must match
    dense attention over the pool's materialized cache."""
    import jax.numpy as jnp
    from repro.kernels.paged_attention.ops import paged_decode_attention
    from repro.kernels.paged_attention.ref import decode_ring_ref

    cfg, model, params = dense_setup
    eng = make_engine(model, params)
    eng.generate(list(range(20)), session_id="pk",
                 sampling=SamplingParams(max_new_tokens=4))
    pool = eng.pool
    sp = pool.session("pk")
    assert sp is not None and sp.tokens > 0
    max_pages = len(sp.pages)
    pt = jnp.asarray(pool.page_table("pk", max_pages))[None]   # [1, P]
    lens = jnp.asarray([sp.tokens], jnp.int32)

    layer = 0
    n_rep = cfg.n_heads // cfg.n_kv_heads
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (1, cfg.n_heads, cfg.head_dim_), jnp.float32)
    out = paged_decode_attention(
        q, pool.k[layer].astype(jnp.float32),
        pool.v[layer].astype(jnp.float32), pt, lens,
        scale=cfg.head_dim_ ** -0.5, n_rep=n_rep)

    k, v, tokens = pool.gather_contiguous("pk", eng.max_seq)
    ref = decode_ring_ref(q[:, None], k[layer][None].astype(jnp.float32),
                          v[layer][None].astype(jnp.float32),
                          jnp.asarray([tokens - 1]),
                          scale=cfg.head_dim_ ** -0.5, n_rep=n_rep)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
