"""Serving engine integration: continuous batching, chunked prefill,
admission control, paged KV pool, prefix reuse, NALAR KV-registry hints,
session migration between engines."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import KVRegistry
from repro.models import build_model
from repro.serving import (EngineOverloaded, InferenceEngine, PagedKVPool,
                           Request, SamplingParams, StateCachePool,
                           WaitQueue)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_smoke_config("qwen3_0_6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def make_engine(model, params, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_seq", 128)
    return InferenceEngine(model, params, **kw)


def test_continuous_batching_completes_all(dense_setup):
    cfg, model, params = dense_setup
    eng = make_engine(model, params)
    rng = np.random.default_rng(0)
    reqs = [Request.make(rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 24))),
                         sampling=SamplingParams(max_new_tokens=6))
            for _ in range(9)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    assert all(r.finished for r in reqs)
    assert all(len(r.generated) == 6 for r in reqs)
    assert eng.metrics.completed == 9


def test_deterministic_greedy_output(dense_setup):
    cfg, model, params = dense_setup
    prompt = list(range(1, 11))
    outs = []
    for _ in range(2):
        eng = make_engine(model, params)
        r = eng.generate(prompt, sampling=SamplingParams(max_new_tokens=5))
        outs.append(r.generated)
    assert outs[0] == outs[1]


def test_batched_equals_unbatched_greedy(dense_setup):
    """Continuous batching must not change greedy outputs."""
    cfg, model, params = dense_setup
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=12).tolist()
               for _ in range(4)]
    solo = []
    for p in prompts:
        eng = make_engine(model, params, max_batch=1)
        solo.append(eng.generate(p, sampling=SamplingParams(max_new_tokens=4)).generated)
    eng = make_engine(model, params, max_batch=4)
    reqs = [Request.make(p, sampling=SamplingParams(max_new_tokens=4))
            for p in prompts]
    for r in reqs:
        eng.submit(r)
    eng.run_until_idle()
    assert [r.generated for r in reqs] == solo


def test_prefix_reuse_same_session(dense_setup):
    cfg, model, params = dense_setup
    eng = make_engine(model, params)
    r1 = eng.generate(list(range(8)), session_id="sess",
                      sampling=SamplingParams(max_new_tokens=4))
    assert eng.metrics.prefix_hits == 0
    r2 = eng.generate(list(range(8, 12)), session_id="sess",
                      sampling=SamplingParams(max_new_tokens=4))
    assert r2.finished
    assert eng.metrics.prefix_hits == 1
    assert r2.prefix_reused_tokens > 0


def test_kv_registry_drop_hint_evicts(dense_setup):
    cfg, model, params = dense_setup
    reg = KVRegistry()
    eng = make_engine(model, params, kv_registry=reg, instance_id="llm:0")
    eng.generate(list(range(8)), session_id="s0",
                 sampling=SamplingParams(max_new_tokens=3))
    assert eng.pool.session("s0") is not None
    reg.release("s0")                      # session over -> drop hint
    assert eng.pool.session("s0") is None


def test_kv_migration_between_engines(dense_setup):
    """The paper's K,V migration: session cache moves across instances."""
    cfg, model, params = dense_setup
    reg = KVRegistry()
    e0 = make_engine(model, params, kv_registry=reg, instance_id="llm:0")
    e1 = make_engine(model, params, kv_registry=reg, instance_id="llm:1")
    e0.generate(list(range(10)), session_id="s0",
                sampling=SamplingParams(max_new_tokens=3))
    payload = e0.pool.export_session("s0")
    assert payload is not None
    assert e1.pool.import_session("s0", payload)
    tokens = reg.migrate("s0", "llm:0", "llm:1")
    assert e0.pool.session("s0") is None       # migrate_out hook freed pages
    # follow-up on the new instance reuses the migrated prefix
    r = e1.generate(list(range(10, 14)), session_id="s0",
                    sampling=SamplingParams(max_new_tokens=3))
    assert r.finished and e1.metrics.prefix_hits == 1


def test_ssm_engine_state_cache():
    cfg = get_smoke_config("mamba2_130m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    eng = InferenceEngine(model, params, max_batch=2, max_seq=64)
    assert isinstance(eng.pool, StateCachePool)
    r = eng.generate(list(range(12)), session_id="s",
                     sampling=SamplingParams(max_new_tokens=5))
    assert r.finished and len(r.generated) == 5
    assert eng.pool.load("s") is not None      # O(1) state stored


def test_paged_pool_allocation_and_eviction():
    cfg = get_smoke_config("qwen3_0_6b")
    pool = PagedKVPool(cfg, n_pages=8, page_size=16)
    sp = pool.allocate("a", 40, now=1.0)       # 3 pages
    assert len(sp.pages) == 3
    pool.allocate("b", 60, now=2.0)            # 4 pages
    assert pool.free_pages() == 1
    # "a" is LRU and unpinned -> evicted to make room
    sp_c = pool.allocate("c", 30, now=3.0)
    assert sp_c is not None
    assert pool.session("a").pages == []


def test_paged_pool_pin_blocks_eviction():
    cfg = get_smoke_config("qwen3_0_6b")
    pool = PagedKVPool(cfg, n_pages=4, page_size=16)
    pool.allocate("a", 64, now=1.0)            # all 4 pages
    pool.on_hint("a", "retain")
    assert pool.allocate("b", 32, now=2.0) is None   # pinned: cannot evict
    pool.on_hint("a", "drop")
    assert pool.allocate("b", 32, now=3.0) is not None


def test_priority_admission_order(dense_setup):
    cfg, model, params = dense_setup
    eng = make_engine(model, params, max_batch=1)
    lo = Request.make(list(range(6)), priority=0.0, now=0.0,
                      sampling=SamplingParams(max_new_tokens=2))
    hi = Request.make(list(range(6)), priority=5.0, now=1.0,
                      sampling=SamplingParams(max_new_tokens=2))
    eng.submit(lo)
    eng.submit(hi)
    eng.run_until_idle()
    assert hi.finished_at <= lo.finished_at    # high priority admitted first


# ------------------------------------------------------- chunked prefill
def test_chunked_prefill_matches_monolithic(dense_setup):
    """Chunked prefill (prompt fed through masked decode sub-steps) must
    produce the same greedy generation AND the same session KV cache as the
    legacy monolithic prefill.  The prompt length is an exact bucket so the
    monolithic path has no pad tokens — on any other length its left-padded
    bucket leaks pad K/V into attention, which is exactly what the chunked
    path removes."""
    cfg, model, params = dense_setup
    prompt = list(range(1, 17))          # == minimum bucket, no padding
    mono = make_engine(model, params, prefill_chunk=0)
    r_mono = mono.generate(prompt, session_id="m",
                           sampling=SamplingParams(max_new_tokens=4))
    chunk = make_engine(model, params, prefill_chunk=4)
    r_chunk = chunk.generate(prompt, session_id="c",
                             sampling=SamplingParams(max_new_tokens=4))
    assert r_chunk.generated == r_mono.generated
    km, vm, tm = mono.pool.gather_contiguous("m", mono.max_seq)
    kc, vc, tc = chunk.pool.gather_contiguous("c", chunk.max_seq)
    # the final sampled token is returned but never fed back into the cache
    assert tm == tc == len(prompt) + 4 - 1
    np.testing.assert_allclose(np.asarray(kc[:, :tc]), np.asarray(km[:, :tm]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(vc[:, :tc]), np.asarray(vm[:, :tm]),
                               rtol=2e-4, atol=2e-4)


def test_windowed_chunked_prefill_matches_per_token():
    """Sliding-window regression: a fused chunk write can clobber ring
    slots that earlier in-chunk queries still need, so windowed chunk
    attention must run against the pre-write cache + the chunk itself.
    Ground truth is the per-token masked-decode path (exact ring
    semantics), with a prompt longer than the window and not bucket-sized
    so the divergence cannot hide."""
    cfg = get_smoke_config("starcoder2_15b")     # dense + sliding window
    assert cfg.sliding_window
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    prompt = [int(t) for t in
              np.random.default_rng(3).integers(1, cfg.vocab_size, 100)]
    sp = SamplingParams(max_new_tokens=6)
    fused = InferenceEngine(model, params, max_batch=2, max_seq=128,
                            prefill_chunk=8)
    assert fused._decode_chunk is not None
    r_fused = fused.generate(prompt, sampling=sp)
    per_tok = InferenceEngine(model, params, max_batch=2, max_seq=128,
                              prefill_chunk=8)
    per_tok._decode_chunk = None                 # masked per-token fallback
    r_tok = per_tok.generate(prompt, sampling=sp)
    assert r_fused.generated == r_tok.generated


def test_chunked_prefill_interleaves_decode(dense_setup):
    """A long prompt admitted mid-decode must not stall the active slot:
    with chunk size C, the decoding request keeps producing one token per
    step while the newcomer's prompt is consumed C tokens per step."""
    cfg, model, params = dense_setup
    eng = make_engine(model, params, max_batch=2, prefill_chunk=8)
    a = Request.make(list(range(4)), sampling=SamplingParams(max_new_tokens=40))
    eng.submit(a)
    eng.step()                           # admit a; consume its short prompt
    while len(a.generated) < 2:
        eng.step()
    tokens_before = len(a.generated)
    long_prompt = list(range(60))        # needs ceil(60/8) = 8 chunked steps
    b = Request.make(long_prompt, sampling=SamplingParams(max_new_tokens=2))
    eng.submit(b)
    for _ in range(4):
        eng.step()
    # a advanced one token per step even while b's prompt was in flight
    assert len(a.generated) >= tokens_before + 4
    eng.run_until_idle()
    assert a.finished and b.finished


# ---------------------------------------------------- admission control
def test_wait_queue_heap_order_and_bound():
    mk = lambda pri, t: Request.make([1], priority=pri, now=t)
    q = WaitQueue(maxsize=3)
    r_lo, r_hi, r_mid = mk(0.0, 0.0), mk(5.0, 1.0), mk(1.0, 2.0)
    for r in (r_lo, r_hi, r_mid):
        q.push(r)
    with pytest.raises(EngineOverloaded):
        q.push(mk(9.0, 3.0))
    assert q.rejected == 1 and q.saturation() == 1.0
    assert [q.pop_next() for _ in range(3)] == [r_hi, r_mid, r_lo]
    assert q.pop_next() is None and q.saturation() == 0.0


def test_engine_bounded_queue_rejects(dense_setup):
    cfg, model, params = dense_setup
    eng = make_engine(model, params, max_queue=2)
    eng.submit(Request.make([1, 2], sampling=SamplingParams(max_new_tokens=2)))
    eng.submit(Request.make([3, 4], sampling=SamplingParams(max_new_tokens=2)))
    with pytest.raises(EngineOverloaded):
        eng.submit(Request.make([5, 6]))
    assert eng.telemetry()["admission_rejects"] == 1
    assert eng.telemetry()["queue_saturation"] == 1.0
    eng.run_until_idle()                 # the admitted two still complete
    assert eng.metrics.completed == 2


def test_rejected_async_submit_leaves_no_callback(dense_setup):
    """A queue-full submit_async must not leave an orphaned callback entry
    (the completion it waits for will never come)."""
    cfg, model, params = dense_setup
    eng = make_engine(model, params, max_queue=1)
    eng.submit(Request.make([1]))
    fired = []
    with pytest.raises(EngineOverloaded):
        eng.submit_async(Request.make([2]), on_done=fired.append)
    assert not eng._callbacks


# --------------------------------------------------- completion delivery
def test_finished_bound_never_drops_pending_callbacks(dense_setup):
    """Regression (dropped completions): bounding the finished list used to
    delete the oldest entries even when their async callers still awaited a
    callback — the NALAR future hung forever.  Fire-or-keep: callback-
    bearing requests survive the trim; callback-less ones are evicted."""
    cfg, model, params = dense_setup
    eng = make_engine(model, params, finished_cap=6)
    fired = []
    awaited = Request.make(list(range(4)),
                           sampling=SamplingParams(max_new_tokens=2))
    eng.submit_async(awaited, on_done=fired.append)
    eng.run_until_idle()
    # sync traffic overflows the finished list well past the cap
    for i in range(10):
        eng.generate([i + 1, i + 2],
                     sampling=SamplingParams(max_new_tokens=2))
    assert len(eng._finished) <= 2 * eng.finished_cap
    assert eng.drain_completions() >= 1
    assert fired == [awaited]            # the awaited completion survived
    assert not eng._callbacks


# ----------------------------------------------------------- TTFT stamps
def test_ttft_stamped_when_first_token_exists(dense_setup):
    """Regression (TTFT accounting): the prefill path used to stamp
    first_token_at at admission time; the resumed path stamped it one step
    late — so a one-token resumed request never got a stamp at all."""
    cfg, model, params = dense_setup
    eng = make_engine(model, params)
    r1 = eng.generate(list(range(8)), session_id="t",
                      sampling=SamplingParams(max_new_tokens=4))
    assert r1.submitted_wall <= r1.first_token_at <= r1.finished_at
    # resumed follow-up generating exactly ONE token: pre-fix this path
    # finished with first_token_at == -1
    r2 = eng.generate(list(range(8, 12)), session_id="t",
                      sampling=SamplingParams(max_new_tokens=1))
    assert r2.prefix_reused_tokens > 0
    assert r2.first_token_at > 0
    assert r2.submitted_wall <= r2.first_token_at <= r2.finished_at


# ----------------------------------------------------- per-request sampling
def test_stochastic_sampling_independent_of_batch_composition(dense_setup):
    """Regression (per-request sampling): a stochastic request's samples
    must come from its own PRNG stream — batching it with other requests
    (which used to burn draws from a shared stream) must not change its
    output."""
    cfg, model, params = dense_setup
    sp = SamplingParams(temperature=0.7, top_k=8, max_new_tokens=5, seed=123)
    prompt = list(range(2, 12))

    eng_solo = make_engine(model, params, max_batch=1)
    solo = eng_solo.generate(prompt, sampling=sp).generated

    eng_batch = make_engine(model, params, max_batch=4)
    rng = np.random.default_rng(7)
    others = [Request.make(rng.integers(0, cfg.vocab_size, size=6),
                           sampling=SamplingParams(temperature=0.9,
                                                   max_new_tokens=5))
              for _ in range(3)]
    target = Request.make(prompt, sampling=sp)
    for r in others[:2] + [target] + others[2:]:
        eng_batch.submit(r)
    eng_batch.run_until_idle()
    assert target.generated == solo


def test_custom_eos_token_stops_generation(dense_setup):
    """Each slot is sampled with its own SamplingParams: a request whose
    eos_token equals its first greedy token stops after one token while a
    default-params batch-mate keeps generating."""
    cfg, model, params = dense_setup
    prompt = list(range(3, 9))
    probe = make_engine(model, params).generate(
        prompt, sampling=SamplingParams(max_new_tokens=1))
    eos = probe.generated[0]
    eng = make_engine(model, params)
    stopper = Request.make(prompt, sampling=SamplingParams(
        max_new_tokens=8, eos_token=eos))
    friend = Request.make(list(range(20, 26)),
                          sampling=SamplingParams(max_new_tokens=8))
    eng.submit(stopper)
    eng.submit(friend)
    eng.run_until_idle()
    assert stopper.generated == [eos]
    assert len(friend.generated) == 8


# ------------------------------------------------- pending-prompt hygiene
def test_vacated_slot_clears_pending_prompt(dense_setup):
    """A recycled slot must never inherit a previous request's unconsumed
    prompt tokens: abort mid-prefill, then verify a fresh request on the
    same slot generates exactly what it generates on a fresh engine."""
    cfg, model, params = dense_setup
    eng = make_engine(model, params, max_batch=1, prefill_chunk=4)
    long_req = Request.make(list(range(40)),
                            sampling=SamplingParams(max_new_tokens=2))
    eng.submit(long_req)
    eng.step()                           # prompt partially consumed
    assert eng._pending_prompt           # mid-prefill
    eng.abort_all()
    assert not eng._pending_prompt and eng.slots == [None]
    fresh_prompt = list(range(50, 58))
    r = eng.generate(fresh_prompt, sampling=SamplingParams(max_new_tokens=3))
    ref = make_engine(model, params, max_batch=1, prefill_chunk=4).generate(
        fresh_prompt, sampling=SamplingParams(max_new_tokens=3))
    assert r.generated == ref.generated
    assert not eng._pending_prompt


def test_resumed_suffix_capped_against_cache_capacity(dense_setup):
    """A warm suffix that would overflow the slot cache mid-prompt is not
    resumed: admission falls back to a (bounded) cold rebuild instead of
    running past the ring."""
    cfg, model, params = dense_setup
    eng = make_engine(model, params, max_seq=96)
    r1 = eng.generate(list(range(40)), session_id="cap",
                      sampling=SamplingParams(max_new_tokens=8))
    assert r1.finished
    hits_before = eng.metrics.prefix_hits
    suffix = list(range(40, 90))         # 47 cached + 50 > 95: cannot resume
    full = list(range(90))               # bounded cold rebuild still fits
    r2 = Request.make(suffix, session_id="cap", fallback_prompt=full,
                      sampling=SamplingParams(max_new_tokens=4))
    eng.submit(r2)
    eng.run_until_idle()
    assert r2.finished and len(r2.generated) == 4
    assert r2.prefix_reused_tokens == 0            # resume was refused
    assert eng.metrics.prefix_hits == hits_before
    assert not eng._pending_prompt


def test_paged_kernel_reads_engine_pool(dense_setup):
    """The Pallas paged-decode kernel consumes the engine pool's page
    tables directly (vLLM-style): kernel(pool pages, page table) must match
    dense attention over the pool's materialized cache."""
    import jax.numpy as jnp
    from repro.kernels.paged_attention.ops import paged_decode_attention
    from repro.kernels.paged_attention.ref import decode_ring_ref

    cfg, model, params = dense_setup
    eng = make_engine(model, params)
    eng.generate(list(range(20)), session_id="pk",
                 sampling=SamplingParams(max_new_tokens=4))
    pool = eng.pool
    sp = pool.session("pk")
    assert sp is not None and sp.tokens > 0
    max_pages = len(sp.pages)
    pt = jnp.asarray(pool.page_table("pk", max_pages))[None]   # [1, P]
    lens = jnp.asarray([sp.tokens], jnp.int32)

    layer = 0
    n_rep = cfg.n_heads // cfg.n_kv_heads
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (1, cfg.n_heads, cfg.head_dim_), jnp.float32)
    out = paged_decode_attention(
        q, pool.k[layer].astype(jnp.float32),
        pool.v[layer].astype(jnp.float32), pt, lens,
        scale=cfg.head_dim_ ** -0.5, n_rep=n_rep)

    k, v, tokens = pool.gather_contiguous("pk", eng.max_seq)
    ref = decode_ring_ref(q[:, None], k[layer][None].astype(jnp.float32),
                          v[layer][None].astype(jnp.float32),
                          jnp.asarray([tokens - 1]),
                          scale=cfg.head_dim_ ** -0.5, n_rep=n_rep)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# ----------------------------------------------- cross-session prefix sharing
def _gather_bytes(engine, sid):
    k, v, tokens = engine.pool.gather_contiguous(sid, engine.max_seq)
    return np.asarray(k[:, :tokens]), np.asarray(v[:, :tokens]), tokens


@pytest.mark.parametrize("arch", ["qwen3_0_6b", "granite_moe_1b_a400m"])
def test_prefix_hit_matches_cold_prefill_chunked(arch):
    """A cold session whose system prompt is resident (written by another
    session) prefills only its novel suffix — and the result is *bitwise*
    equal to a full cold prefill: same greedy tokens, same cache bytes.
    The suffix re-enters the same chunked-prefill program at the same chunk
    boundary the cold path would reach, so even float bits agree."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sysp = list(range(1, 65))                    # 64 tokens == one page
    suf_donor = [(100 + i) % cfg.vocab_size for i in range(8)]
    suf = [(200 + i) % cfg.vocab_size for i in range(8)]
    sp = SamplingParams(max_new_tokens=4)

    cold = InferenceEngine(model, params, max_batch=2, max_seq=128,
                           prefill_chunk=8)
    r_cold = cold.generate(sysp + suf, session_id="x", sampling=sp)

    shared = InferenceEngine(model, params, max_batch=2, max_seq=128,
                             prefill_chunk=8)
    shared.generate(sysp + suf_donor, session_id="donor", sampling=sp)
    pt0 = shared.metrics.prefill_tokens
    r_hit = shared.generate(sysp + suf, session_id="x", sampling=sp)

    assert shared.metrics.shared_prefix_hits == 1
    assert shared.metrics.shared_prefix_tokens == 64
    # the hit admission never re-prefilled the shared 64 tokens
    assert shared.metrics.prefill_tokens - pt0 < len(sysp)
    assert r_hit.prefix_reused_tokens == 64
    assert r_hit.generated == r_cold.generated
    kc, vc, tc = _gather_bytes(cold, "x")
    ks, vs, ts = _gather_bytes(shared, "x")
    assert tc == ts
    np.testing.assert_array_equal(ks, kc)
    np.testing.assert_array_equal(vs, vc)


def test_prefix_hit_matches_cold_prefill_monolithic(dense_setup):
    """Same equivalence on the legacy monolithic-prefill path.  The prompt
    is exactly one bucket so the cold path has no pad positions and the
    comparison is bitwise."""
    cfg, model, params = dense_setup
    sysp = list(range(1, 49))                    # 48 tokens = 3 pages of 16
    suf_donor = [100 + i for i in range(16)]
    suf = [200 + i for i in range(16)]           # prompt 64 == bucket
    sp = SamplingParams(max_new_tokens=4)

    cold = InferenceEngine(model, params, max_batch=2, max_seq=128,
                           prefill_chunk=0, page_size=16)
    r_cold = cold.generate(sysp + suf, session_id="x", sampling=sp)

    shared = InferenceEngine(model, params, max_batch=2, max_seq=128,
                             prefill_chunk=0, page_size=16)
    shared.generate(sysp + suf_donor, session_id="donor", sampling=sp)
    r_hit = shared.generate(sysp + suf, session_id="x", sampling=sp)

    assert shared.metrics.shared_prefix_hits == 1
    assert shared.metrics.shared_prefix_tokens == 48
    assert r_hit.generated == r_cold.generated
    kc, vc, tc = _gather_bytes(cold, "x")
    ks, vs, ts = _gather_bytes(shared, "x")
    assert tc == ts
    np.testing.assert_array_equal(ks, kc)
    np.testing.assert_array_equal(vs, vc)


def test_prefix_hit_partial_tail_page(dense_setup):
    """A new session re-sending a donor's *exact* prompt shares into the
    donor's partial tail page (common-prefix match inside the block) and
    prefills only the final position; greedy output still matches a cold
    run."""
    cfg, model, params = dense_setup
    prompt = list(range(1, 73))                  # 72 tokens, page 64
    sp = SamplingParams(max_new_tokens=4)

    cold = InferenceEngine(model, params, max_batch=2, max_seq=128,
                           prefill_chunk=8)
    r_cold = cold.generate(prompt, session_id="x", sampling=sp)

    shared = InferenceEngine(model, params, max_batch=2, max_seq=128,
                             prefill_chunk=8)
    shared.generate(prompt, session_id="donor", sampling=sp)
    r_hit = shared.generate(prompt, session_id="x", sampling=sp)

    assert shared.metrics.shared_prefix_hits == 1
    # ids[:-1] = 71 tokens: one full page (64) + 7 inside the donor's
    # partial tail page
    assert shared.metrics.shared_prefix_tokens == 71
    assert r_hit.generated == r_cold.generated
    kc, vc, tc = _gather_bytes(cold, "x")
    ks, vs, ts = _gather_bytes(shared, "x")
    assert tc == ts
    np.testing.assert_allclose(ks, kc, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(vs, vc, rtol=2e-4, atol=2e-4)


def test_prefix_share_cow_keeps_donor_bytes(dense_setup):
    """Copy-on-write isolation at the engine level: a sharer that diverges
    and generates past the shared prefix never mutates the donor's cache."""
    cfg, model, params = dense_setup
    sysp = list(range(1, 65))
    sp = SamplingParams(max_new_tokens=6)
    eng = make_engine(model, params, max_batch=2, prefill_chunk=8)
    eng.generate(sysp + [100, 101], session_id="donor", sampling=sp)
    kd0, vd0, td0 = _gather_bytes(eng, "donor")

    eng.generate(sysp + [200, 201, 202], session_id="sharer", sampling=sp)
    assert eng.metrics.shared_prefix_hits == 1
    kd1, vd1, td1 = _gather_bytes(eng, "donor")
    assert td0 == td1
    np.testing.assert_array_equal(kd1, kd0)
    np.testing.assert_array_equal(vd1, vd0)
    eng.pool.check_invariants()


def test_prefix_sharing_off_is_cold(dense_setup):
    """The kill switch: with prefix_sharing=False nothing is indexed and a
    same-prompt second session pays the full prefill."""
    cfg, model, params = dense_setup
    sysp = list(range(1, 65))
    sp = SamplingParams(max_new_tokens=3)
    eng = make_engine(model, params, prefill_chunk=8, prefix_sharing=False)
    eng.generate(sysp + [100], session_id="a", sampling=sp)
    pt0 = eng.metrics.prefill_tokens
    r = eng.generate(sysp + [200], session_id="b", sampling=sp)
    assert eng.metrics.shared_prefix_hits == 0
    assert r.prefix_reused_tokens == 0
    assert eng.metrics.prefill_tokens - pt0 == len(sysp) + 1
