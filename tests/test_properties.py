"""Property-based tests (hypothesis) on system invariants.

When hypothesis is not installed, a deterministic random-sampling fallback
(tests/_hypothesis_fallback.py) stands in so these still run everywhere.
"""

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import NodeStore, Telemetry
from repro.core.future import extract_dependencies
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.models.moe import _capacity, _dispatch_masks, _route
from repro.configs.base import ModelConfig

SETTINGS = dict(max_examples=25, deadline=None)


# ----------------------------------------------------------- rglru algebra
@given(st.integers(1, 3), st.integers(1, 48), st.integers(1, 16),
       st.integers(0, 10_000))
@settings(**SETTINGS)
def test_rglru_scan_matches_loop(B, S, W, seed):
    """associative-scan recurrence == naive Python loop for any shape."""
    rng = np.random.default_rng(seed)
    a = rng.uniform(0.5, 1.0, (B, S, W)).astype(np.float32)
    b = rng.standard_normal((B, S, W)).astype(np.float32) * 0.2
    out = np.asarray(rglru_scan_ref(jnp.asarray(a), jnp.asarray(b)))
    h = np.zeros((B, W), np.float32)
    for t in range(S):
        h = a[:, t] * h + b[:, t]
        np.testing.assert_allclose(out[:, t], h, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------ moe dispatch
def _moe_cfg(E, k):
    return ModelConfig(arch_id="prop", family="moe", n_experts=E, top_k=k,
                       d_expert=8, d_model=16, capacity_factor=1.25)


@given(st.integers(4, 64), st.sampled_from([4, 8, 16]),
       st.integers(1, 4), st.integers(0, 10_000))
@settings(**SETTINGS)
def test_moe_dispatch_invariants(G, E, k, seed):
    """Capacity never exceeded; each kept (token,choice) appears exactly once;
    combine weights match kept gates."""
    k = min(k, E)
    cfg = _moe_cfg(E, k)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((G, 16)).astype(np.float32))
    router = jnp.asarray(rng.standard_normal((16, E)).astype(np.float32))
    gates, idx, probs = _route(x, router, cfg)
    C = _capacity(G, cfg)
    dispatch, combine, counts = _dispatch_masks(idx, gates, G, C, cfg)
    d = np.asarray(dispatch, np.float32)
    # each expert buffer slot holds at most one token
    assert (d.sum(axis=0) <= 1.0 + 1e-6).all()
    # a token occupies at most k slots total
    assert (d.sum(axis=(1, 2)) <= k + 1e-6).all()
    # counts respect capacity
    assert (np.asarray(counts) <= C).all()
    # combine weight per token <= 1 (gates renormalized over top-k)
    assert (np.asarray(combine).sum(axis=(1, 2)) <= 1.0 + 1e-5).all()


@given(st.integers(8, 64), st.integers(0, 1000))
@settings(**SETTINGS)
def test_moe_gather_equals_einsum_when_no_drop(G, seed):
    """With generous capacity both dispatch impls compute the same output."""
    from repro.models.moe import moe_block, init_moe_layer
    cfg = ModelConfig(arch_id="prop", family="moe", n_experts=4, top_k=2,
                      d_expert=16, d_model=8, capacity_factor=4.0,
                      dtype="float32")
    rng = jax.random.PRNGKey(seed)
    p = init_moe_layer(rng, cfg)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (1, G, 8), jnp.float32)
    y1, _, c1 = moe_block(x, p, cfg, impl="einsum")
    y2, _, c2 = moe_block(x, p, cfg, impl="gather")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


# -------------------------------------------------------------- node store
@given(st.lists(st.tuples(st.sampled_from("abc"), st.integers(0, 100)),
                min_size=1, max_size=30))
@settings(**SETTINGS)
def test_store_last_write_wins(writes):
    s = NodeStore("n0")
    last = {}
    for f, v in writes:
        s.hset("k", f, v)
        last[f] = v
    assert s.hgetall("k") == last


@given(st.integers(1, 50))
@settings(**SETTINGS)
def test_store_version_monotone(n):
    s = NodeStore("n0")
    versions = []
    for i in range(n):
        s.hset("k", "f", i)
        versions.append(s.version("k"))
    assert versions == sorted(versions)
    assert len(set(versions)) == n


# --------------------------------------------------------------- telemetry
@given(st.lists(st.floats(0.001, 100.0), min_size=1, max_size=200))
@settings(**SETTINGS)
def test_percentiles_monotone_and_bounded(latencies):
    t = Telemetry()
    for i, lat in enumerate(latencies):
        rid = f"r{i}"
        t.start_request(rid, "s", 0.0)
        t.end_request(rid, lat)
    p50, p95, p99 = t.percentile(50), t.percentile(95), t.percentile(99)
    assert p50 <= p95 <= p99
    assert min(latencies) - 1e-9 <= p50 and p99 <= max(latencies) + 1e-9
    s = t.summary()
    assert s["n"] == len(latencies)


# --------------------------------------------------------- dep extraction
@given(st.recursive(
    st.integers() | st.text(max_size=3),
    lambda children: st.lists(children, max_size=3)
    | st.dictionaries(st.text(max_size=2), children, max_size=3),
    max_leaves=10))
@settings(**SETTINGS)
def test_extract_dependencies_ignores_plain_data(obj):
    assert extract_dependencies((obj,), {}) == []
