"""Property-based invariant suite for the shared-prefix ``PagedKVPool``.

The prefix index aliases pages across sessions (refcounts + copy-on-write),
which is exactly the kind of mutation machinery that corrupts caches
silently unless the invariants are locked in: no page is ever double-owned
or double-freed, refcounts equal the number of page-list references, page
accounting balances (free + live == n_pages), and — the one that matters to
users — no session ever reads bytes another session wrote after divergence.

The oracle is deterministic content: position ``i`` of a session whose
``token_ids[i] == t`` always holds ``f(t, i)``, the same function for every
session.  That models the real engine property that K/V at a position is a
pure function of the token prefix, and makes byte-leak detection exact: a
session's ``gather_contiguous`` must equal ``f`` over its own ids after
*every* operation, no matter how pages are shared, COW'd, evicted,
exported, or imported underneath it.

When hypothesis is not installed, the deterministic random-sampling
fallback (tests/_hypothesis_fallback.py) stands in.
"""

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs.base import ModelConfig
from repro.serving.kv_cache import PagedKVPool

# ≥ 200 randomized interleavings in CI across the two schedule-driven
# properties (the acceptance budget for this suite)
INTERLEAVE_SETTINGS = dict(max_examples=120, deadline=None)
SMALL_SETTINGS = dict(max_examples=25, deadline=None)

L, HKV, DH = 2, 2, 4
P = 4           # page_size: tiny so schedules cross page boundaries often
N_PAGES = 12    # tight so eviction/alloc-failure paths are exercised


def _cfg() -> ModelConfig:
    return ModelConfig(arch_id="kvprop", family="dense", n_layers=L,
                       d_model=HKV * DH, n_heads=HKV, n_kv_heads=HKV,
                       dtype="float32")


def make_pool(n_pages: int = N_PAGES) -> PagedKVPool:
    return PagedKVPool(_cfg(), n_pages=n_pages, page_size=P,
                       dtype=np.float32)


def content(ids, offset: float = 0.0) -> np.ndarray:
    """Deterministic K (or V, via offset) for a token sequence: position i
    holds f(ids[i], i), identical across sessions — the property real
    prefix caches rely on."""
    S = len(ids)
    base = np.asarray([(t * 31 + i * 7) % 1000 for i, t in enumerate(ids)],
                      np.float32)
    lay = np.arange(L, dtype=np.float32).reshape(L, 1, 1, 1)
    out = base.reshape(1, S, 1, 1) + lay * 10_000.0
    out = np.broadcast_to(out, (L, S, HKV, DH)).copy()
    return out + offset


def write(pool: PagedKVPool, sid: str, ids, now: float) -> bool:
    k = content(ids)
    v = content(ids, offset=0.5)
    return pool.write_session(sid, k, v, len(ids), now=now, token_ids=ids)


def assert_no_leakage(pool: PagedKVPool, oracle) -> None:
    """Every live session's visible bytes == f over its own token ids."""
    for sid, ids in oracle.items():
        sp = pool.session(sid)
        if sp is None or not sp.pages:
            continue
        got = pool.gather_contiguous(sid, max_seq=N_PAGES * P)
        assert got is not None
        k, v, tokens = got
        assert tokens == len(ids)
        np.testing.assert_array_equal(np.asarray(k[:, :tokens]),
                                      content(ids),
                                      err_msg=f"session {sid} K bytes leaked")
        np.testing.assert_array_equal(np.asarray(v[:, :tokens]),
                                      content(ids, offset=0.5),
                                      err_msg=f"session {sid} V bytes leaked")


# ----------------------------------------------------- random interleavings
@given(st.integers(0, 10_000), st.integers(8, 26))
@settings(**INTERLEAVE_SETTINGS)
def test_random_interleaving_preserves_invariants(seed, n_ops):
    """Randomized allocate/write/share/COW/release/evict schedules: the
    pool's accounting invariants hold and no session's bytes ever change
    under another session's mutations."""
    rng = np.random.default_rng(seed)
    pool = make_pool()
    oracle = {}          # sid -> token ids the pool must reproduce
    now = 0.0
    for step in range(n_ops):
        now += 1.0
        op = rng.choice(["write", "rewrite", "share", "acquire", "release",
                         "hint"], p=[0.3, 0.15, 0.2, 0.15, 0.1, 0.1])
        sids = sorted(oracle)
        if op == "write" or not sids:
            sid = f"s{rng.integers(0, 6)}"
            ids = [int(t) for t in rng.integers(0, 50, rng.integers(1, 17))]
            if write(pool, sid, ids, now):
                oracle[sid] = ids
            else:
                # failed writes must roll back: session state unchanged
                sp = pool.session(sid)
                if sid in oracle:
                    assert sp is not None and sp.tokens == len(oracle[sid])
        elif op == "rewrite":
            # append/diverge on an existing session — the COW trigger
            sid = sids[rng.integers(0, len(sids))]
            old = oracle[sid]
            cut = int(rng.integers(0, len(old) + 1))
            ids = old[:cut] + [int(t) for t in
                               rng.integers(50, 99, rng.integers(1, 9))]
            if write(pool, sid, ids, now):
                oracle[sid] = ids
        elif op == "share":
            # new session re-deriving a donor's prefix (plus its own tail):
            # the write path must dedup into the donor's indexed pages
            donor = oracle[sids[rng.integers(0, len(sids))]]
            cut = int(rng.integers(1, len(donor) + 1))
            ids = donor[:cut] + [int(t) for t in
                                 rng.integers(50, 99, rng.integers(0, 5))]
            sid = f"s{rng.integers(6, 10)}"
            if write(pool, sid, ids, now):
                oracle[sid] = ids
        elif op == "acquire":
            donor = oracle[sids[rng.integers(0, len(sids))]]
            sid = f"a{rng.integers(0, 4)}"
            if pool.session(sid) is None:
                matched = pool.acquire_prefix(sid, donor, now=now)
                if matched > 0:
                    sp = pool.session(sid)
                    assert sp is not None and sp.tokens == matched
                    # adopted bytes must be the donor prefix, not garbage
                    oracle[sid] = donor[:matched]
                else:
                    assert pool.session(sid) is None
        elif op == "release":
            sid = sids[rng.integers(0, len(sids))]
            pool.release(sid)
            oracle.pop(sid, None)
        elif op == "hint":
            sid = sids[rng.integers(0, len(sids))]
            hint = ["retain", "drop", "release", "migrate_out"][
                rng.integers(0, 4)]
            pool.on_hint(sid, hint)
            if hint in ("release", "migrate_out"):
                oracle.pop(sid, None)
            elif hint == "drop":
                # un-pins only; pages stay until evicted
                pass
        pool.check_invariants()
        # sessions evicted under pressure leave an empty page list; the
        # oracle only checks sessions that still hold pages
        for sid in list(oracle):
            sp = pool.session(sid)
            if sp is None or not sp.pages:
                oracle.pop(sid, None)
        assert_no_leakage(pool, oracle)
    # teardown must balance the books completely
    for sid in list(oracle):
        pool.release(sid)
    pool.check_invariants()


@given(st.integers(0, 10_000))
@settings(**INTERLEAVE_SETTINGS)
def test_export_import_random_roundtrip(seed):
    """Randomized export/import between two pools: imported sessions read
    back the exporter's bytes, dedup against the local index never mixes
    sessions, and both pools' invariants hold."""
    rng = np.random.default_rng(seed)
    a, b = make_pool(), make_pool()
    oracle_a, oracle_b = {}, {}
    now = 0.0
    for step in range(10):
        now += 1.0
        # grow a donor population in pool a (shared prefixes on purpose)
        sid = f"s{rng.integers(0, 4)}"
        if oracle_a and rng.random() < 0.5:
            donor = oracle_a[sorted(oracle_a)[rng.integers(0, len(oracle_a))]]
            cut = int(rng.integers(1, len(donor) + 1))
            ids = donor[:cut] + [int(t) for t in
                                 rng.integers(50, 99, rng.integers(0, 6))]
        else:
            ids = [int(t) for t in rng.integers(0, 50, rng.integers(1, 15))]
        if write(a, sid, ids, now):
            oracle_a[sid] = ids
        # ship a random resident session a -> b
        if oracle_a and rng.random() < 0.7:
            src = sorted(oracle_a)[rng.integers(0, len(oracle_a))]
            payload = a.export_session(src)
            if payload is not None:
                if b.import_session(f"m:{src}", payload, now=now):
                    oracle_b[f"m:{src}"] = list(oracle_a[src])
        a.check_invariants()
        b.check_invariants()
        for oracle, pool in ((oracle_a, a), (oracle_b, b)):
            for s in list(oracle):
                sp = pool.session(s)
                if sp is None or not sp.pages:
                    oracle.pop(s, None)
        assert_no_leakage(a, oracle_a)
        assert_no_leakage(b, oracle_b)


# ------------------------------------------------------------ targeted COW
def test_cow_preserves_donor_bytes():
    """A sharer diverging inside a shared page gets a fresh page; the
    donor's bytes never move."""
    pool = make_pool()
    donor = list(range(10))                      # 2.5 pages
    assert write(pool, "donor", donor, 1.0)
    donor_pages = list(pool.session("donor").pages)

    sharer = donor[:6] + [90, 91, 92]            # diverges inside page 1
    assert write(pool, "sharer", sharer, 2.0)
    sp = pool.session("sharer")
    assert sp.pages[0] == donor_pages[0]         # full page 0 shared
    assert sp.pages[1] != donor_pages[1]         # divergent page COW'd
    assert pool.stats["dedup_pages"] >= 1
    pool.check_invariants()
    assert_no_leakage(pool, {"donor": donor, "sharer": sharer})

    # rewrite the sharer entirely: donor still untouched
    assert write(pool, "sharer", [70, 71, 72], 3.0)
    pool.check_invariants()
    assert_no_leakage(pool, {"donor": donor, "sharer": [70, 71, 72]})


def test_refcounts_pin_shared_pages_against_eviction():
    """A page referenced by two sessions survives the release of either
    one, and eviction never reclaims a page while any owner remains."""
    pool = make_pool(n_pages=4)
    assert write(pool, "a", list(range(8)), 1.0)         # 2 pages
    assert pool.acquire_prefix("b", list(range(8)), now=2.0) == 8
    shared = list(pool.session("a").pages)
    pool.release("a")
    pool.check_invariants()
    # b still owns the pages: bytes intact, pages not freed
    assert pool.session("b").pages == shared
    assert_no_leakage(pool, {"b": list(range(8))})
    # allocation pressure cannot evict b's in-use pages while... b is live
    # but unpinned: eviction MAY reclaim b wholesale (refcount drops to 0
    # via the eviction path) — never partially
    sp = pool.allocate("c", 16, now=3.0)                 # needs all 4 pages
    assert sp is not None
    bb = pool.session("b")
    assert bb is None or bb.pages == []                  # all-or-nothing
    pool.check_invariants()


def test_acquire_refused_for_resident_session():
    pool = make_pool()
    assert write(pool, "a", list(range(8)), 1.0)
    assert pool.acquire_prefix("a", list(range(8)), now=2.0) == 0


def test_opaque_write_is_not_indexed():
    """Writes without token provenance must never enter the prefix index
    (their bytes cannot be verified against any token sequence)."""
    pool = make_pool()
    ids = list(range(8))
    k = content(ids)
    v = content(ids, offset=0.5)
    assert pool.write_session("op", k, v, len(ids), now=1.0)   # no token_ids
    assert pool.match_prefix(ids) == 0
    assert pool.acquire_prefix("x", ids, now=2.0) == 0
    pool.check_invariants()


def test_import_dedups_against_resident_prefix():
    """Importing a payload whose prefix is already indexed locally adopts
    the resident pages instead of copying them."""
    a, b = make_pool(), make_pool()
    ids = list(range(12))                        # 3 full pages
    assert write(a, "s", ids, 1.0)
    assert write(b, "local", ids, 1.0)           # same prefix resident in b
    payload = a.export_session("s")
    dd0 = b.stats["dedup_pages"]
    assert b.import_session("moved", payload, now=2.0)
    assert b.stats["dedup_pages"] - dd0 == 3     # all full pages adopted
    sp_l, sp_m = b.session("local"), b.session("moved")
    assert sp_l.pages == sp_m.pages              # physically shared
    b.check_invariants()
    assert_no_leakage(b, {"local": ids, "moved": ids})


def test_export_import_legacy_tuple_payload():
    """The pre-index (k, v, tokens) payload still imports (opaque)."""
    a, b = make_pool(), make_pool()
    ids = list(range(6))
    assert write(a, "s", ids, 1.0)
    d = a.export_session("s")
    legacy = (d["k"], d["v"], d["tokens"])
    assert b.import_session("s", legacy, now=2.0)
    assert_no_leakage(b, {"s": ids})
    b.check_invariants()


# ------------------------------------------- paged-native in-place appends
def append_inplace(pool: PagedKVPool, sid: str, new_ids, oracle, now: float,
                   ) -> bool:
    """Emulate the engine's paged decode write: ``begin_append`` → scatter
    K/V for the new positions straight into pool pages → ``commit_append``.

    Asserts the contract the data plane relies on: after ``begin_append``,
    every page the scatter will touch has refcount exactly 1 (a shared page
    must have been COW-privatized, never written in place)."""
    old = oracle.get(sid, [])
    n = len(new_ids)
    if not pool.begin_append(sid, n, now=now):
        return False
    sp = pool.session(sid)
    assert sp is not None and sp.tokens == len(old)
    first_b, last_b = sp.tokens // P, (sp.tokens + n - 1) // P
    for b in range(first_b, last_b + 1):
        assert pool._ref.get(sp.pages[b], 0) == 1, (
            f"in-place write target page {sp.pages[b]} (block {b}) is "
            f"shared: refcount {pool._ref.get(sp.pages[b], 0)}")
    full = old + list(new_ids)
    k, v = content(full), content(full, offset=0.5)
    for t in range(sp.tokens, sp.tokens + n):
        page, off = sp.pages[t // P], t % P
        pool.k = pool.k.at[:, page, off].set(k[:, t])
        pool.v = pool.v.at[:, page, off].set(v[:, t])
    pool.commit_append(sid, n, token_ids=list(new_ids), now=now)
    oracle[sid] = full
    return True


@given(st.integers(0, 10_000), st.integers(8, 22))
@settings(**INTERLEAVE_SETTINGS)
def test_inplace_append_interleavings(seed, n_ops):
    """Randomized schedules mixing in-place decode appends with writes,
    prefix adoption, and releases: no append ever mutates a shared page
    (asserted inside :func:`append_inplace`), every session still reads
    exactly f over its own ids, and accounting stays balanced."""
    rng = np.random.default_rng(seed)
    pool = make_pool()
    oracle = {}
    now = 0.0
    for step in range(n_ops):
        now += 1.0
        op = rng.choice(["write", "append", "share", "acquire", "release"],
                        p=[0.25, 0.35, 0.15, 0.15, 0.1])
        sids = sorted(oracle)
        if op == "write" or not sids:
            sid = f"s{rng.integers(0, 5)}"
            ids = [int(t) for t in rng.integers(0, 50, rng.integers(1, 14))]
            if write(pool, sid, ids, now):
                oracle[sid] = ids
        elif op == "append":
            # the paged decode step: 1-4 new tokens straight into pages
            sid = sids[rng.integers(0, len(sids))]
            new = [int(t) for t in rng.integers(50, 99, rng.integers(1, 5))]
            append_inplace(pool, sid, new, oracle, now)
        elif op == "share":
            donor = oracle[sids[rng.integers(0, len(sids))]]
            cut = int(rng.integers(1, len(donor) + 1))
            ids = donor[:cut] + [int(t) for t in
                                 rng.integers(50, 99, rng.integers(0, 4))]
            sid = f"s{rng.integers(5, 9)}"
            if write(pool, sid, ids, now):
                oracle[sid] = ids
        elif op == "acquire":
            donor = oracle[sids[rng.integers(0, len(sids))]]
            sid = f"a{rng.integers(0, 3)}"
            if pool.session(sid) is None:
                matched = pool.acquire_prefix(sid, donor, now=now)
                if matched > 0:
                    oracle[sid] = donor[:matched]
        elif op == "release":
            sid = sids[rng.integers(0, len(sids))]
            pool.release(sid)
            oracle.pop(sid, None)
        pool.check_invariants()
        for sid in list(oracle):
            sp = pool.session(sid)
            if sp is None or not sp.pages:
                oracle.pop(sid, None)
        assert_no_leakage(pool, oracle)
    for sid in list(oracle):
        pool.release(sid)
    pool.check_invariants()
    assert pool.free_pages() == N_PAGES


def test_inplace_append_privatizes_adopted_tail():
    """A session decoding onto an adopted shared prefix: ``begin_append``
    must COW the partially-filled shared tail page before the in-place
    write, leaving the donor's bytes untouched."""
    pool = make_pool()
    oracle = {}
    donor = list(range(10))                       # 2.5 pages
    assert write(pool, "donor", donor, 1.0)
    oracle["donor"] = donor
    assert pool.acquire_prefix("dec", donor, now=2.0) == 10
    oracle["dec"] = donor[:]
    donor_pages = list(pool.session("donor").pages)
    assert pool.session("dec").pages == donor_pages      # fully aliased
    cow0 = pool.stats["cow_copies"]

    assert append_inplace(pool, "dec", [90, 91], oracle, 3.0)
    dp = pool.session("dec").pages
    assert dp[0] == donor_pages[0] and dp[1] == donor_pages[1]
    assert dp[2] != donor_pages[2]                # shared tail was COW'd
    assert pool.stats["cow_copies"] > cow0
    pool.check_invariants()
    assert_no_leakage(pool, oracle)               # donor bytes intact


def test_commit_append_rekeys_index_for_sharing():
    """Pages completed by in-place appends re-enter the prefix index: a
    later session deriving the extended transcript adopts them instead of
    recomputing."""
    pool = make_pool()
    oracle = {}
    base = list(range(6))
    assert write(pool, "s", base, 1.0)
    oracle["s"] = base
    assert append_inplace(pool, "s", [60, 61, 62], oracle, 2.0)   # 9 tokens
    full = oracle["s"]
    assert pool.match_prefix(full) >= 8           # both full pages indexed
    assert pool.acquire_prefix("adopt", full, now=3.0) >= 8
    sp_s, sp_a = pool.session("s"), pool.session("adopt")
    assert sp_a.pages[0] == sp_s.pages[0] and sp_a.pages[1] == sp_s.pages[1]
    oracle["adopt"] = full[:pool.session("adopt").tokens]
    pool.check_invariants()
    assert_no_leakage(pool, oracle)


def test_begin_append_all_or_nothing_on_exhaustion():
    """If the pool cannot provide capacity pages, ``begin_append`` fails
    without touching the session (no partial privatization, no leak)."""
    pool = make_pool(n_pages=3)
    oracle = {}
    ids = list(range(12))                         # exactly 3 pages
    assert write(pool, "s", ids, 1.0)
    oracle["s"] = ids
    pool.protect("s")                             # eviction can't help
    pages_before = list(pool.session("s").pages)
    assert not pool.begin_append("s", 2, now=2.0)
    assert pool.session("s").pages == pages_before
    assert pool.session("s").tokens == 12
    pool.check_invariants()
    assert_no_leakage(pool, oracle)
    pool.unprotect("s")


def test_protected_session_survives_allocation_pressure():
    """Pages of a protected (actively-decoding) session are never evicted
    out from under the engine slot writing into them."""
    pool = make_pool(n_pages=4)
    assert write(pool, "hot", list(range(8)), 1.0)       # 2 pages
    pool.protect("hot")
    # needs 3 pages but only 2 are free: eviction may not touch "hot"
    assert pool.allocate("cold", 12, now=2.0) is None
    assert pool.session("hot") is not None
    assert_no_leakage(pool, {"hot": list(range(8))})
    pool.unprotect("hot")
    # once unprotected the same pressure may reclaim it
    assert pool.allocate("cold", 12, now=3.0) is not None
    pool.check_invariants()


def test_free_page_accounting_balances_after_churn():
    """free + live == n_pages through a full allocate/share/release cycle,
    and a fully drained pool returns to all-free."""
    pool = make_pool()
    assert write(pool, "a", list(range(9)), 1.0)
    assert pool.acquire_prefix("b", list(range(9)), now=2.0) > 0
    assert write(pool, "c", list(range(9))[:5] + [77, 78], 3.0)
    pool.check_invariants()
    for sid in ("a", "b", "c"):
        pool.release(sid)
    pool.check_invariants()
    assert pool.free_pages() == N_PAGES
