"""Minimal stand-in for ``hypothesis`` when it is not installed.

The repo's property tests (tests/test_properties.py) are written against the
real hypothesis API.  This shim implements just the surface they use —
``given``, ``settings`` and a handful of strategies — backed by a seeded
``random.Random`` so the tests still run (as deterministic randomized tests,
without shrinking) in environments where the extra dependency is missing.
"""

from __future__ import annotations

import random
import string
from typing import Any, Callable, List


class Strategy:
    def __init__(self, draw: Callable[[random.Random, int], Any]) -> None:
        self._draw = draw

    def example(self, rng: random.Random, depth: int = 0) -> Any:
        return self._draw(rng, depth)

    def __or__(self, other: "Strategy") -> "Strategy":
        return Strategy(lambda rng, d: (self if rng.random() < 0.5 else other)
                        .example(rng, d))


class strategies:
    """Namespace mirroring ``hypothesis.strategies``."""

    @staticmethod
    def integers(min_value: int = -(2 ** 31), max_value: int = 2 ** 31) -> Strategy:
        return Strategy(lambda rng, d: rng.randint(min_value, max_value))

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0) -> Strategy:
        return Strategy(lambda rng, d: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(seq) -> Strategy:
        items = list(seq)
        return Strategy(lambda rng, d: rng.choice(items))

    @staticmethod
    def text(max_size: int = 8) -> Strategy:
        alphabet = string.ascii_letters + string.digits
        return Strategy(lambda rng, d: "".join(
            rng.choice(alphabet) for _ in range(rng.randint(0, max_size))))

    @staticmethod
    def lists(elements: Strategy, min_size: int = 0,
              max_size: int = 8) -> Strategy:
        return Strategy(lambda rng, d: [
            elements.example(rng, d + 1)
            for _ in range(rng.randint(min_size, max_size))])

    @staticmethod
    def tuples(*elements: Strategy) -> Strategy:
        return Strategy(lambda rng, d: tuple(e.example(rng, d + 1)
                                             for e in elements))

    @staticmethod
    def dictionaries(keys: Strategy, values: Strategy,
                     max_size: int = 8) -> Strategy:
        def draw(rng: random.Random, d: int) -> dict:
            return {keys.example(rng, d + 1): values.example(rng, d + 1)
                    for _ in range(rng.randint(0, max_size))}
        return Strategy(draw)

    @staticmethod
    def recursive(base: Strategy, extend: Callable[[Strategy], Strategy],
                  max_leaves: int = 10) -> Strategy:
        # depth-bounded recursion instead of hypothesis's leaf accounting
        max_depth = max(1, max_leaves // 3)

        def draw(rng: random.Random, d: int) -> Any:
            if d >= max_depth or rng.random() < 0.4:
                return base.example(rng, d + 1)
            return extend(ref).example(rng, d + 1)

        ref = Strategy(draw)
        return ref


def settings(max_examples: int = 25, **_ignored) -> Callable:
    def deco(fn: Callable) -> Callable:
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strats: Strategy) -> Callable:
    def deco(fn: Callable) -> Callable:
        n = getattr(fn, "_fallback_max_examples", 25)

        def wrapper() -> None:
            rng = random.Random(f"fallback:{fn.__name__}")
            for _ in range(n):
                args: List[Any] = [s.example(rng) for s in strats]
                fn(*args)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
