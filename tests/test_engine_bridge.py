"""Runtime <-> InferenceEngine bridge: futures execute on the real engine.

Covers the tentpole contract:
 * a stub call on an engine-backed agent resolves its future with real
   engine output (GenerationResult);
 * two calls in one session reuse prefix KV — the engine's prefill-token
   telemetry shows the second call skipped the shared prefix;
 * simulate=True behaviour is unchanged (emulated agents still run in
   virtual time; engine agents are rejected on a SimKernel runtime).
"""

import time

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import (AgentSpec, Directives, FixedLatency, NalarRuntime,
                        deployment, emulated)
from repro.core.runtime import current_runtime
from repro.models import build_model
from repro.serving import (EngineOverloaded, GenerationResult,
                           InferenceEngine, Request, SamplingParams,
                           register_engine_agent, register_engine_pool)


@pytest.fixture(scope="module")
def model_setup():
    cfg = get_smoke_config("qwen3_0_6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def make_engine_runtime(model, params, max_new_tokens=4):
    rt = NalarRuntime(simulate=False)
    engine = InferenceEngine(model, params, max_batch=4, max_seq=128)
    register_engine_agent(
        rt, "llm", engine,
        sampling=SamplingParams(max_new_tokens=max_new_tokens))
    return rt, engine


def test_future_resolves_with_engine_output(model_setup):
    cfg, model, params = model_setup
    rt, engine = make_engine_runtime(model, params)

    def driver():
        fut = current_runtime().stub("llm").generate("hello engine world")
        assert not fut.available     # async: submission returns immediately
        return fut.value(timeout=300)

    out = deployment.main(driver, runtime=rt)
    assert isinstance(out, GenerationResult)
    assert len(out.tokens) == 4
    assert all(0 <= t < cfg.vocab_size for t in out.tokens)
    assert engine.metrics.completed == 1
    # the future executed on the engine's NALAR instance identity
    assert out.engine_id == rt.instances_of_type("llm")[0]
    rt.shutdown()


def test_same_session_calls_reuse_prefix_kv(model_setup):
    cfg, model, params = model_setup
    rt, engine = make_engine_runtime(model, params)

    def driver():
        llm = current_runtime().stub("llm")
        r1 = llm.generate("the quick brown fox jumps over").value(timeout=300)
        t_after_first = engine.metrics.prefill_tokens
        r2 = llm.generate("and keeps running").value(timeout=300)
        return r1, r2, t_after_first

    r1, r2, t_after_first = deployment.main(driver, runtime=rt)
    # first call prefilled its prompt; second call resumed the session cache
    assert r1.prefix_reused_tokens == 0
    assert r2.prefix_reused_tokens > 0
    assert engine.metrics.prefix_hits == 1
    # prefill-token telemetry did NOT grow on the warm call: the full
    # context (first prompt + generation + suffix) was never re-prefilled
    assert engine.metrics.prefill_tokens == t_after_first
    # second call sent only the new suffix (3 words), not the transcript
    assert r2.prompt_tokens == 3
    # agent-layer KV registry made (and recorded) the reuse decision
    assert rt.kv_registry.stats["reuse_hits"] >= 1
    # managed state carries the session transcript
    bridge = rt.engine_backends["llm"]
    sid = next(iter(rt.sessions._sessions))
    transcript = bridge.transcript.tokens(sid)
    assert len(transcript) == (r1.prompt_tokens + len(r1.tokens)
                               + r2.prompt_tokens + len(r2.tokens))
    rt.shutdown()


def test_concurrent_futures_share_engine_batch(model_setup):
    """Engine-backed instances are not head-of-line blocked: futures from
    different sessions are in flight on one instance at once."""
    cfg, model, params = model_setup
    rt, engine = make_engine_runtime(model, params, max_new_tokens=3)
    results = []

    def driver(i):
        return current_runtime().stub("llm") \
            .generate(f"query number {i}").value(timeout=300)

    rt.start()
    for i in range(6):       # six requests -> six independent sessions
        rt.submit_request(driver, i,
                          on_done=lambda out, err: results.append((out, err)))
    rt.run()
    assert len(results) == 6
    assert all(err is None for _, err in results)
    assert all(isinstance(out, GenerationResult) for out, _ in results)
    assert engine.metrics.completed == 6
    rt.shutdown()


def test_engine_submit_async_and_poll(model_setup):
    """The engine's raw async surface: submit with a callback, poll the
    finished list (no NALAR runtime involved)."""
    cfg, model, params = model_setup
    from repro.serving import Request
    engine = InferenceEngine(model, params, max_batch=2, max_seq=64)
    done = []
    req = Request.make(list(range(5)),
                       sampling=SamplingParams(max_new_tokens=3))
    engine.submit_async(req, on_done=done.append)
    engine.run_until_idle()
    assert req.finished
    # callbacks have not fired yet; poll_finished surfaces the request
    polled = engine.poll_finished()
    assert polled == [req] and done == []
    assert engine.poll_finished() == []          # drained
    # drain_completions after poll finds nothing left to fire
    assert engine.drain_completions() == 0


def test_concurrent_same_session_calls_stay_ordered(model_setup):
    """Same-session calls issued concurrently are serialized by the bridge:
    each later call sees the previous call's transcript (no racy context)."""
    cfg, model, params = model_setup
    rt, engine = make_engine_runtime(model, params)

    def fanout():
        llm = current_runtime().stub("llm")
        futs = [llm.generate(f"concurrent turn {i}") for i in range(3)]
        return [f.value(timeout=300) for f in futs]

    outs = deployment.main(fanout, runtime=rt)
    assert len(outs) == 3
    # calls 2 and 3 were warm continuations of the serialized session
    assert sum(o.prefix_reused_tokens > 0 for o in outs) == 2
    assert [o.prompt_tokens for o in outs] == [3, 3, 3]   # suffixes only
    # transcript is exactly the concatenation of (new tokens + generation)
    bridge = rt.engine_backends["llm"]
    sid = next(iter(rt.sessions._sessions))
    assert len(bridge.transcript.tokens(sid)) == sum(
        o.prompt_tokens + len(o.tokens) for o in outs)
    rt.shutdown()


def test_encode_failure_fails_only_that_future(model_setup):
    """A bad input poisons its own future, not batch-mates submitted
    alongside it."""
    cfg, model, params = model_setup
    rt = NalarRuntime(simulate=False)
    engine = InferenceEngine(model, params, max_batch=4, max_seq=128)

    def encode(q):
        if "poison" in str(q):
            raise ValueError("unencodable input")
        from repro.serving import hash_tokenize
        return hash_tokenize(q, cfg.vocab_size)

    register_engine_agent(rt, "llm", engine, encode=encode,
                          sampling=SamplingParams(max_new_tokens=3))

    def fanout():
        llm = current_runtime().stub("llm")
        futs = [llm.generate("fine one"), llm.generate("poison pill"),
                llm.generate("fine two")]
        out, errs = [], []
        for f in futs:
            try:
                out.append(f.value(timeout=300))
            except ValueError as e:
                errs.append(str(e))
        return out, errs

    out, errs = deployment.main(fanout, runtime=rt)
    assert len(out) == 2 and all(isinstance(o, GenerationResult) for o in out)
    assert errs == ["unencodable input"]
    rt.shutdown()


def test_queue_full_fails_future_as_retryable_error(model_setup):
    """Admission control: a full bounded wait queue rejects the submission
    and the future fails with EngineOverloaded (retryable) instead of the
    request queueing unboundedly.  With no retry budget the failure is
    surfaced to the caller as-is."""
    cfg, model, params = model_setup
    rt = NalarRuntime(simulate=False)
    engine = InferenceEngine(model, params, max_batch=1, max_seq=64,
                             max_queue=1)
    register_engine_agent(rt, "llm", engine,
                          sampling=SamplingParams(max_new_tokens=2))
    iid = rt.instances_of_type("llm")[0]
    rt.router.shed_watermark = None      # single replica: nothing to shed to
    # fill the bounded queue directly so the next bridge submission rejects
    engine.queue.push(Request.make([1, 2, 3]))

    def driver():
        return current_runtime().stub("llm").generate("over capacity") \
            .value(timeout=60)

    with pytest.raises(EngineOverloaded):
        deployment.main(driver, runtime=rt)
    assert engine.queue.rejected >= 1
    assert rt.controller_of(iid).inst.metrics.failed == 1
    rt.shutdown()


def test_queue_full_retry_ladder_reroutes_to_sibling(model_setup):
    """The full ladder: queue-full on the pinned replica -> retryable
    failure -> in-place retry (still full) -> budget exhausted -> escalate
    -> global RetryPolicy reroutes the future to the surviving sibling,
    which completes it."""
    cfg, model, params = model_setup
    rt = NalarRuntime(simulate=False)
    eng_a = InferenceEngine(model, params, max_batch=1, max_seq=64,
                            max_queue=1)
    eng_b = InferenceEngine(model, params, max_batch=2, max_seq=64)
    register_engine_pool(rt, "llm", [eng_a, eng_b],
                         sampling=SamplingParams(max_new_tokens=2))
    rt.apply_directives("llm", {"max_retries": 1, "retry_backoff": 0.01})
    iid_a, iid_b = rt.instances_of_type("llm")
    rt.router.shed_watermark = None      # force the ladder, not the shed
    # saturate A's queue with a request that will never drain during the
    # test (the pump only steps while bridge work is pending)
    eng_a.queue.push(Request.make(list(range(8)),
                                  sampling=SamplingParams(max_new_tokens=60)))
    sid = rt.sessions.new_session(0.0, 0.0).session_id
    rt.router.pin(sid, "llm", iid_a)     # route the call at the full replica
    out, errs = [], []

    def driver():
        return current_runtime().stub("llm").generate("needs a reroute") \
            .value(timeout=120)

    rt.start()
    rt.submit_request(driver, session=sid,
                      on_done=lambda o, e: (out.append(o), errs.append(e)))
    rt.run()
    assert errs == [None]
    assert isinstance(out[0], GenerationResult)
    assert out[0].engine_id == iid_b     # rerouted off the saturated replica
    assert eng_a.queue.rejected >= 2     # first attempt + in-place retry
    assert eng_b.metrics.completed >= 1
    rt.shutdown()


def test_router_sheds_from_saturated_replica(model_setup):
    """Backpressure before collapse: with the shed watermark active the
    Router routes a new call away from the saturated replica instead of
    letting it hit the full queue at all."""
    cfg, model, params = model_setup
    rt = NalarRuntime(simulate=False)
    eng_a = InferenceEngine(model, params, max_batch=1, max_seq=64,
                            max_queue=1)
    eng_b = InferenceEngine(model, params, max_batch=2, max_seq=64)
    register_engine_pool(rt, "llm", [eng_a, eng_b],
                         sampling=SamplingParams(max_new_tokens=2))
    iid_a, iid_b = rt.instances_of_type("llm")
    eng_a.queue.push(Request.make([1, 2, 3]))    # A at 1/1: saturated
    assert eng_a.saturation() >= rt.router.shed_watermark
    sid = rt.sessions.new_session(0.0, 0.0).session_id
    rt.router.pin(sid, "llm", iid_a)

    def driver():
        return current_runtime().stub("llm").generate("shed me") \
            .value(timeout=60)

    rt.start()
    res = {}
    rt.submit_request(driver, session=sid,
                      on_done=lambda o, e: res.update(out=o, err=e))
    rt.run()
    assert res["err"] is None
    assert res["out"].engine_id == iid_b     # pin overridden by the shed
    assert eng_a.queue.rejected == 0         # never even hit the full queue
    rt.shutdown()


def test_engine_metrics_reach_instance_view(model_setup):
    """EngineMetrics -> bridge -> metrics mirror -> InstanceView: the
    global controller's view carries the data-plane queue watermark."""
    cfg, model, params = model_setup
    rt = NalarRuntime(simulate=False)
    engine = InferenceEngine(model, params, max_batch=2, max_seq=64,
                             max_queue=4)
    register_engine_agent(rt, "llm", engine,
                          sampling=SamplingParams(max_new_tokens=2))
    iid = rt.instances_of_type("llm")[0]
    for i in range(3):
        engine.queue.push(Request.make([i + 1]))
    rt.controller_of(iid)._publish_metrics()
    view = rt.global_controller.collect_view(full=True)
    iv = view.instances[iid]
    assert iv.engine_queue == 3
    assert iv.engine_saturation == pytest.approx(0.75)
    rt.shutdown()


def test_simulate_true_behavior_unchanged():
    """Virtual-time emulated execution is untouched by the bridge: same
    deterministic result and virtual-clock latency as the seed runtime."""
    ends = []
    for _ in range(2):
        rt = NalarRuntime(simulate=True)
        rt.register_agent(AgentSpec(
            name="tool",
            methods={"run": emulated(FixedLatency(0.5),
                                     lambda x: x * 2)},
            directives=Directives(max_instances=1, resources={"CPU": 1})))

        def driver():
            return current_runtime().stub("tool").run(21).value()

        out = deployment.main(driver, runtime=rt)
        assert out == 42
        ends.append(rt.kernel.now())
        rt.shutdown()
    assert ends[0] == ends[1]            # deterministic virtual time
    assert ends[0] >= 0.5                # latency model still charged


def test_engine_agent_rejected_on_sim_kernel(model_setup):
    cfg, model, params = model_setup
    rt = NalarRuntime(simulate=True)
    engine = InferenceEngine(model, params, max_batch=2, max_seq=64)
    with pytest.raises(RuntimeError, match="simulate=False"):
        register_engine_agent(rt, "llm", engine)
    rt.shutdown()
