"""Training substrate: optimizer math, loss descent, grad accumulation
equivalence, checkpoint round-trip, data-pipeline determinism/sharding."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.training import (AdamW, DataConfig, Syntheticcorpus, checkpoint,
                            constant_schedule, cosine_schedule, global_norm,
                            make_grad_accum_step, make_train_step, train)


def test_adamw_first_step_is_lr_sized():
    opt = AdamW(learning_rate=constant_schedule(0.1), weight_decay=0.0,
                grad_clip=None)
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    grads = {"w": jnp.full((4,), 0.5)}
    new, state = opt.update(grads, state, params)
    # bias-corrected mhat/sqrt(vhat) == 1 on the first step
    np.testing.assert_allclose(np.asarray(new["w"]), 0.9 * np.ones(4),
                               rtol=1e-5)


def test_grad_clip_bounds_update():
    opt = AdamW(learning_rate=constant_schedule(0.1), grad_clip=1.0,
                weight_decay=0.0)
    params = {"w": jnp.zeros((1000,))}
    state = opt.init(params)
    grads = {"w": jnp.full((1000,), 100.0)}
    _, state2 = opt.update(grads, state, params)
    # post-clip gradient norm is 1.0 -> mu magnitude bounded
    assert float(jnp.abs(state2.mu["w"]).max()) <= 0.1 * 100.0 / 100.0 + 1e-3


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup_steps=10, total_steps=100, min_ratio=0.1)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(lr(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)


def test_loss_decreases_dense():
    model = build_model(get_smoke_config("qwen3_0_6b"))
    _, res = train(model, steps=30, batch_size=8, seq_len=64, peak_lr=1e-3,
                   warmup=5)
    assert res.last_loss < res.first_loss - 0.3


def test_loss_decreases_ssm():
    model = build_model(get_smoke_config("mamba2_130m").replace(ssm_chunk=16))
    _, res = train(model, steps=25, batch_size=8, seq_len=64, peak_lr=1e-3,
                   warmup=5)
    assert res.last_loss < res.first_loss - 0.2


def test_grad_accum_matches_full_batch():
    cfg = get_smoke_config("qwen3_0_6b").replace(dtype="float32")
    model = build_model(cfg)
    opt = AdamW(learning_rate=constant_schedule(1e-3), grad_clip=None)
    params = model.init(jax.random.PRNGKey(0))
    state = opt.init(params)
    rng = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(rng, (8, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(rng, (8, 32), 0, cfg.vocab_size)}
    full = jax.jit(make_train_step(model, opt))
    accum = jax.jit(make_grad_accum_step(model, opt, n_micro=4))
    p1, _, m1 = full(params, state, batch)
    p2, _, m2 = accum(params, state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    assert max(jax.tree_util.tree_leaves(d)) < 1e-4


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_smoke_config("granite_moe_1b_a400m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    path = os.path.join(tmp_path, "m.ckpt")
    n = checkpoint.save(path, params)
    assert n > 0
    restored = checkpoint.restore(path, jax.eval_shape(lambda: params))
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_structure_mismatch_fails(tmp_path):
    path = os.path.join(tmp_path, "m.ckpt")
    checkpoint.save(path, {"a": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        checkpoint.restore(path, {"a": jnp.zeros((2,)), "b": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        checkpoint.restore(path, {"a": jnp.zeros((3,))})


def test_data_deterministic_and_sharded():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8, seed=3)
    c1, c2 = Syntheticcorpus(cfg), Syntheticcorpus(cfg)
    b1 = c1.batch(step=5)
    b2 = c2.batch(step=5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # shards partition the batch and differ from each other
    s0 = c1.batch(step=5, shard=0, n_shards=2)
    s1 = c1.batch(step=5, shard=1, n_shards=2)
    assert s0["tokens"].shape == (4, 16)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_global_norm():
    t = {"a": jnp.full((3,), 2.0), "b": jnp.zeros((5,))}
    assert float(global_norm(t)) == pytest.approx((12.0) ** 0.5)
