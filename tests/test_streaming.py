"""Token-streaming data plane: incremental futures end to end.

Covers the tentpole contract:
 * ``Future`` grows an append-only chunk log — ``partial()`` /
   ``wait_streamed()`` / ``iter_chunks()`` compose with materialize /
   fail / cancel / ``reset_for_retry`` (retry truncates the log back to
   the attempt boundary, exactly-once);
 * run-id + stream-owner double fencing: a hedged loser and a superseded
   attempt can never interleave stale tokens into the winner's chunk log;
 * the engine emits per-slot chunks incrementally and their concatenation
   is byte-identical to the completed generation;
 * ``stream_min_tokens`` unparks a consumer on partial availability, so a
   classifier starts before its upstream resolves;
 * streamed and completion-only drivers produce byte-identical outputs
   (greedy decode) through the real engine pool;
 * TTFT is stamped from the first accepted chunk and surfaces in
   ``Telemetry.deadline_outcomes()``;
 * ``EngineBridge.drain()`` with partially-streamed in-flight requests
   fails leftovers fast — blocked chunk iterators raise, never hang;
 * the OpenAI-compatible SSE endpoint delivers incrementally with final
   text byte-identical to the non-streaming response (real TCP client).
"""

import threading
import time

import jax
import pytest

from repro.configs import get_smoke_config
from repro.core import (AgentSpec, Directives, FixedLatency, NalarRuntime,
                        deployment, emulated)
from repro.core.future import (Future, FutureMetadata, InstanceDied,
                               resolve_args)
from repro.core.runtime import current_runtime
from repro.models import build_model
from repro.serving import (InferenceEngine, Request, SamplingParams,
                           register_engine_agent)
from repro.workloads.router import (add_stream_classifier, classify_tokens,
                                    build_pool_runtime,
                                    completion_routed_driver,
                                    streamed_routed_driver)


@pytest.fixture(scope="module")
def model_setup():
    cfg = get_smoke_config("qwen3_0_6b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def pool_rt():
    rt = build_pool_runtime(replicas=2, max_batch=4, max_new_tokens=16,
                            seed=0)
    add_stream_classifier(rt, latency=0.01, k=4)
    rt.start()
    yield rt
    rt.shutdown()


def make_rt():
    """Real-time kernel, no engines — chunk events are plain wall-clock
    waits, so the unit tests can drive futures from arbitrary threads."""
    return NalarRuntime(simulate=False)


def mk_future(rt):
    f = Future(rt, FutureMetadata())
    rt.futures.add(f)
    return f


# ------------------------------------------------------------ chunk-log unit
def test_append_partial_order_and_state():
    rt = make_rt()
    f = mk_future(rt)
    assert not f.streaming and f.streamed() == 0 and f.partial() == []
    assert f.append_chunk([1, 2])
    assert f.append_chunk([3])
    assert f.streaming and f.streamed() == 3 and f.partial() == [1, 2, 3]
    f.materialize("v", now=0.0)
    assert not f.streaming                 # STREAMING is a RUNNING sub-state
    assert f.partial() == [1, 2, 3]        # log survives materialization
    assert not f.append_chunk([4])         # terminal: appends rejected
    assert f.partial() == [1, 2, 3]


def test_wait_streamed_wakes_on_chunks_and_on_terminal():
    rt = make_rt()
    f = mk_future(rt)
    threading.Timer(0.05, lambda: f.append_chunk([7, 8])).start()
    assert f.wait_streamed(2, timeout=10.0) >= 2
    # terminal resolution wakes a waiter that will never get n tokens
    threading.Timer(0.05, lambda: f.fail(RuntimeError("boom"), 0.0)).start()
    got = f.wait_streamed(99, timeout=10.0)
    assert got == 2 and f.available


def test_iter_chunks_drains_seals_and_terminates():
    rt = make_rt()
    f = mk_future(rt)
    f.append_chunk([1])
    f.append_chunk([2, 3])
    f.seal_stream([1, 2, 3, 4, 5])         # completion appends unstreamed tail
    f.materialize("done", now=0.0)
    got = list(f.iter_chunks(timeout=5.0))
    assert [t for c in got for t in c] == [1, 2, 3, 4, 5]
    assert f.partial() == [1, 2, 3, 4, 5]


def test_iter_chunks_raises_on_midstream_failure():
    rt = make_rt()
    f = mk_future(rt)
    f.append_chunk([1])
    seen, errs = [], []

    def consume():
        try:
            for c in f.iter_chunks(timeout=10.0):
                seen.append(list(c))
        except RuntimeError as e:
            errs.append(e)
    t = threading.Thread(target=consume)
    t.start()
    time.sleep(0.05)
    f.fail(RuntimeError("engine died"), now=0.0)
    t.join(timeout=10.0)
    assert not t.is_alive(), "iterator hung across a failure"
    assert seen == [[1]] and len(errs) == 1


def test_iter_chunks_timeout_on_stalled_stream():
    rt = make_rt()
    f = mk_future(rt)
    it = f.iter_chunks(timeout=0.05)
    with pytest.raises(TimeoutError):
        next(it)


# --------------------------------------------------- fencing (satellite 1)
def test_retry_truncates_log_and_fences_stale_appends():
    rt = make_rt()
    f = mk_future(rt)
    stale_run = f._run_id
    assert f.append_chunk([9, 9], expect_run=stale_run)
    assert f.reset_for_retry(1.0)
    # the attempt boundary: log truncated, retry streams from scratch
    assert f.partial() == [] and f.streamed() == 0 and not f.streaming
    # zombie producer of the superseded attempt: fenced out
    assert not f.append_chunk([9], expect_run=stale_run)
    assert f.append_chunk([1, 2], expect_run=f._run_id)
    assert f.partial() == [1, 2]


def test_hedge_loser_cannot_interleave_with_stream_owner():
    rt = make_rt()
    f = mk_future(rt)
    run = f._run_id
    assert f.append_chunk([1], expect_run=run, owner="engine-A")
    # hedge duplicate shares the run id — only the owner fence stops it
    assert not f.append_chunk([9], expect_run=run, owner="engine-B")
    assert f.append_chunk([2], expect_run=run, owner="engine-A")
    assert f.partial() == [1, 2]
    # winner A seals: pure tail append, no truncation
    f.seal_stream([1, 2, 3], owner="engine-A", expect_run=run)
    assert f.partial() == [1, 2, 3]


def test_seal_by_winner_replaces_losers_claimed_stream():
    rt = make_rt()
    f = mk_future(rt)
    run = f._run_id
    # the loser won the race to first append and claimed the stream
    assert f.append_chunk([9, 9], expect_run=run, owner="engine-B")
    gen_before = f._chunk_gen
    # hedge winner completes first: seal truncates the foreign prefix and
    # replaces it wholesale so consumers assemble exactly the winning value
    f.seal_stream([1, 2, 3], owner="engine-A", expect_run=run)
    assert f.partial() == [1, 2, 3]
    assert f._chunk_gen == gen_before + 1   # live iterators rewind
    f.materialize("w", now=0.0)
    assert [t for c in f.iter_chunks(timeout=5.0) for t in c] == [1, 2, 3]


def test_live_iterator_rewinds_across_retry():
    rt = make_rt()
    f = mk_future(rt)
    got = []

    def consume():
        for c in f.iter_chunks(timeout=10.0):
            got.append(list(c))
    t = threading.Thread(target=consume)
    f.append_chunk([9, 9])                 # doomed first attempt
    t.start()
    time.sleep(0.05)
    assert f.reset_for_retry(1.0)
    f.append_chunk([1, 2])                 # the retry re-streams
    f.append_chunk([3])
    f.seal_stream([1, 2, 3])
    f.materialize("v", now=2.0)
    t.join(timeout=10.0)
    assert not t.is_alive()
    # the rewind re-delivered the fresh attempt from index 0
    assert got[0] == [9, 9] and got[-2:] == [[1, 2], [3]]
    assert f.partial() == [1, 2, 3]


def test_resolve_args_substitutes_partial_for_streaming_dep():
    rt = make_rt()
    f = mk_future(rt)
    f.append_chunk([5, 6, 7])
    args, kwargs = resolve_args((f, "x"), {"k": 1}, stream_min=2)
    assert args == ([5, 6, 7], "x") and kwargs == {"k": 1}
    f.materialize("full", now=0.0)
    args, _ = resolve_args((f,), {})       # resolved dep: value as usual
    assert args == ("full",)


# ------------------------------------------------- controller partial wakeup
def test_stream_min_tokens_unparks_consumer_before_dep_resolves():
    rt = make_rt()
    rt.register_agent(AgentSpec(
        name="classifier",
        methods={"classify": emulated(
            FixedLatency(0.01), lambda toks: f"n={len(list(toks))}")},
        directives=Directives(max_instances=2, resources={"CPU": 1}),
    ), instances=1)

    def driver():
        r = current_runtime()
        src = mk_future(r)
        fut = r.stub("classifier").classify(
            src, _hint={"stream_min_tokens": 3})
        time.sleep(0.2)
        assert not fut.available, "consumer ran with no streamed input"
        src.append_chunk([1, 2])
        time.sleep(0.2)
        assert not fut.available, "consumer ran below stream_min_tokens"
        src.append_chunk([3])
        out = fut.value(timeout=30.0)
        # the classifier consumed the partial snapshot while the upstream
        # was still unresolved — that is the inter-step pipelining claim
        assert not src.available
        src.materialize("full", now=r.kernel.now())
        return out

    assert deployment.main(driver, runtime=rt) == "n=3"


def test_classify_tokens_partial_and_result_agree():
    class R:
        tokens = [4, 1, 3, 2, 9, 9, 9]
    assert classify_tokens(R(), k=4) == classify_tokens([4, 1, 3, 2], k=4)
    assert classify_tokens([2, 2], k=4) == "chat"     # even sum
    assert classify_tokens([2, 3], k=4) == "code"     # odd sum


# --------------------------------------------------------------- engine layer
def test_engine_emits_incremental_chunks(model_setup):
    cfg, model, params = model_setup
    engine = InferenceEngine(model, params, max_batch=2, max_seq=64)
    chunks, done = [], []
    req = Request.make(list(range(5)),
                       sampling=SamplingParams(max_new_tokens=4))
    engine.submit_async(req, on_done=done.append,
                        on_chunk=lambda r, c: chunks.append(list(c)))
    for _ in range(200):
        if done:
            break
        engine.step()
        engine.drain_completions()
    assert done == [req] and len(req.generated) == 4
    # incremental: one chunk per decode step, not one final blob
    assert len(chunks) >= 2
    assert [t for c in chunks for t in c] == list(req.generated)
    assert req.streamed == len(req.generated)


def test_engine_without_chunk_callback_unchanged(model_setup):
    cfg, model, params = model_setup
    engine = InferenceEngine(model, params, max_batch=2, max_seq=64)
    done = []
    req = Request.make(list(range(4)),
                       sampling=SamplingParams(max_new_tokens=3))
    engine.submit_async(req, on_done=done.append)
    engine.run_until_idle()
    engine.drain_completions()
    assert done == [req] and len(req.generated) == 3


# ------------------------------------------------------------ pool end-to-end
def _run_request(rt, driver, *args, timeout=240.0):
    box, evt = {}, threading.Event()

    def cb(out, err):
        box["out"], box["err"] = out, err
        evt.set()
    rt.submit_request(driver, *args, on_done=cb)
    assert evt.wait(timeout), "request did not complete"
    if box["err"] is not None:
        raise box["err"]
    return box["out"]


def test_streamed_and_completion_drivers_byte_identical(pool_rt):
    q = "byte identical probe query"
    comp = _run_request(pool_rt, completion_routed_driver, q, 12, 4)
    strm = _run_request(pool_rt, streamed_routed_driver, q, 12, 4, 4)
    assert comp == strm                     # branch + draft + refine tokens
    assert len(comp["draft"]) == 12 and len(comp["refine"]) == 4


def test_chunks_concatenate_to_completion_value_and_ttft(pool_rt):
    def driver():
        r = current_runtime()
        fut = r.stub("llm").generate("chunk concat probe",
                                     _hint={"out_tokens": 8})
        got = [list(c) for c in fut.iter_chunks(timeout=120.0)]
        v = fut.value()
        return got, [int(t) for t in v.tokens]

    got, toks = _run_request(pool_rt, driver)
    assert [t for c in got for t in c] == toks and len(toks) == 8
    assert len(got) >= 2                    # streamed, not one sealed blob
    dl = pool_rt.telemetry.deadline_outcomes()
    # satellite: TTFT stamped from the first accepted chunk append
    assert dl["ttft_n"] >= 1
    assert 0 < dl["ttft_p50"] <= dl["ttft_p99"]


# ------------------------------------------------- drain mid-stream (sat. 3)
def test_drain_fails_partially_streamed_requests_fast(model_setup):
    cfg, model, params = model_setup
    rt = NalarRuntime(simulate=False)
    engine = InferenceEngine(model, params, max_batch=2, max_seq=256)
    register_engine_agent(rt, "llm", engine,
                          sampling=SamplingParams(max_new_tokens=192))
    bridge = rt.engine_backends["llm"]
    rt.start()
    box, started = {}, threading.Event()

    def driver():
        r = current_runtime()
        fut = r.stub("llm").generate("long streaming request",
                                     _hint={"out_tokens": 192})
        box["fut"] = fut
        started.set()
        try:
            for _ in fut.iter_chunks(timeout=60.0):
                pass
            return "completed"
        except InstanceDied:
            return "iterator-raised"

    rt.submit_request(driver, on_done=lambda out, err: box.update(
        out=out, err=err, done=True))
    assert started.wait(120.0)
    fut = box["fut"]
    fut.wait_streamed(1, timeout=120.0)     # request is now mid-stream
    t0 = time.monotonic()
    failed = bridge.drain(timeout=0.2)
    assert failed == 1                      # the leftover was failed fast
    deadline = time.monotonic() + 30.0
    while "done" not in box and time.monotonic() < deadline:
        time.sleep(0.02)
    assert box.get("done"), "consumer hung after drain"
    assert box["err"] is None and box["out"] == "iterator-raised"
    assert time.monotonic() - t0 < 10.0
    with pytest.raises(InstanceDied):
        fut.value()
    rt.shutdown()


# ----------------------------------------------------------- HTTP front end
def test_openai_endpoint_streams_and_matches_nonstreaming():
    from repro.launch.serve import selftest
    # ephemeral port, real TCP client; asserts >1 incremental content
    # event, monotonic seqs, and streamed == non-streamed final text
    selftest(replicas=1, max_new=8)
