"""Per-architecture smoke tests (deliverable f): every assigned arch, as a
reduced same-family variant, runs one forward/train step on CPU with shape
asserts + no-NaN checks.  Also prefill/decode parity against the training
forward for representative families."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import build_model
from repro.training import AdamW, constant_schedule, make_train_step


def _batch(cfg, B=2, S=16, rng=None):
    rng = jax.random.PRNGKey(0) if rng is None else rng
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            rng, (B, cfg.n_image_tokens, cfg.d_model), cfg.jnp_dtype)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            rng, (B, cfg.encoder_seq, cfg.d_model), cfg.jnp_dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits, aux = model.forward(params, batch)
    exp_S = S + (cfg.n_image_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    opt = AdamW(learning_rate=constant_schedule(1e-3))
    step = jax.jit(make_train_step(model, opt))
    new_params, _, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda acc, x: acc + float(jnp.sum(jnp.abs(x[0].astype(jnp.float32)
                                                   - x[1].astype(jnp.float32)))),
        jax.tree_util.tree_map(lambda a, b: (a, b), params, new_params),
        0.0, is_leaf=lambda x: isinstance(x, tuple))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    B, S = 2, 16
    batch = _batch(cfg, B, S, jax.random.PRNGKey(1))
    logits, cache = model.prefill(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = model.decode_step(params, tok, cache)
        assert logits.shape == (B, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    prefix = cfg.n_image_tokens if cfg.family == "vlm" else 0
    assert int(np.asarray(cache["pos"])[0]) == S + prefix + 3


@pytest.mark.parametrize("arch", ["qwen3_0_6b", "mamba2_130m",
                                  "recurrentgemma_9b", "whisper_medium"])
def test_prefill_decode_matches_forward(arch):
    """Teacher-forced parity: decode at position t must equal the training
    forward's logits at t (f32, no sliding window wraparound)."""
    cfg = get_smoke_config(arch).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    B, S, extra = 2, 16, 4
    rng = jax.random.PRNGKey(3)
    toks = jax.random.randint(rng, (B, S + extra), 0, cfg.vocab_size)
    batch_full = _batch(cfg, B, S + extra, rng)
    batch_full["tokens"] = toks
    full_logits, _ = model.forward(params, batch_full)

    batch_pre = dict(batch_full)
    batch_pre["tokens"] = toks[:, :S]
    logits, cache = model.prefill(params, batch_pre, pad_cache_to=S + extra)
    offset = cfg.n_image_tokens if cfg.family == "vlm" else 0
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits[:, offset + S - 1]),
                               rtol=2e-3, atol=2e-3)
    for t in range(extra):
        logits, cache = model.decode_step(params, toks[:, S + t], cache)
        np.testing.assert_allclose(
            np.asarray(logits),
            np.asarray(full_logits[:, offset + S + t]),
            rtol=2e-3, atol=2e-3)


def test_sliding_window_decode_matches_windowed_forward():
    cfg = get_smoke_config("starcoder2_15b").replace(dtype="float32",
                                                     sliding_window=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    B, S, extra = 1, 16, 6
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S + extra), 0,
                              cfg.vocab_size)
    full_logits, _ = model.forward(params, {"tokens": toks})
    logits, cache = model.prefill(params, {"tokens": toks[:, :S]},
                                  pad_cache_to=S + extra)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits[:, S - 1]),
                               rtol=2e-3, atol=2e-3)
    for t in range(extra):
        logits, cache = model.decode_step(params, toks[:, S + t], cache)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full_logits[:, S + t]),
                                   rtol=2e-3, atol=2e-3)


def test_exact_configs_match_assignment():
    """The full (non-smoke) configs carry the exact published shapes."""
    c = get_config("qwen3-0.6b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (28, 1024, 16, 8, 3072, 151936)
    assert c.qk_norm
    c = get_config("starcoder2-15b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 6144, 48, 4, 24576, 49152)
    c = get_config("qwen3-moe-235b-a22b")
    assert (c.n_layers, c.d_model, c.n_experts, c.top_k) == (94, 4096, 128, 8)
    c = get_config("recurrentgemma-9b")
    assert (c.n_layers, c.d_model, c.hybrid_period) == (38, 4096, 3)
    c = get_config("mamba2-130m")
    assert (c.n_layers, c.d_model, c.ssm_state) == (24, 768, 128)
    c = get_config("granite-moe-1b-a400m")
    assert (c.n_experts, c.top_k, c.d_expert, c.vocab_size) == (32, 8, 512, 49155)
    c = get_config("whisper-medium")
    assert (c.n_layers, c.n_encoder_layers, c.d_model, c.vocab_size) == (24, 24, 1024, 51865)
    c = get_config("phi-3-vision-4.2b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab_size) == (32, 3072, 8192, 32064)
    c = get_config("stablelm-1.6b")
    assert (c.n_layers, c.d_model, c.n_heads, c.rope_pct) == (24, 2048, 32, 0.25)
    c = get_config("qwen3-1.7b")
    assert (c.n_layers, c.d_model, c.d_ff) == (28, 2048, 6144)


def test_smoke_configs_are_reduced():
    for arch in ARCH_IDS:
        c = get_smoke_config(arch)
        assert c.n_layers <= 3 and c.d_model <= 512
        if c.n_experts:
            assert c.n_experts <= 4


@pytest.mark.parametrize("arch", ["qwen3_0_6b", "granite_moe_1b_a400m",
                                  "recurrentgemma_9b", "mamba2_130m"])
def test_remat_chunked_loss_matches_plain(arch):
    """The production memory path (remat + chunked attention + chunked CE)
    must compute the same loss and gradients as the plain path."""
    cfg = get_smoke_config(arch).replace(dtype="float32")
    plain = build_model(cfg, attention_impl="xla")
    prod = build_model(cfg, attention_impl="xla_chunked", remat=True)
    params = plain.init(jax.random.PRNGKey(6))
    batch = _batch(cfg, 2, 24, jax.random.PRNGKey(7))
    l1 = float(plain.loss_fn(params, batch))
    l2 = float(prod.loss_fn(params, batch))
    assert l1 == pytest.approx(l2, rel=1e-4)
    g1 = jax.grad(plain.loss_fn)(params, batch)
    g2 = jax.grad(prod.loss_fn)(params, batch)
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)
