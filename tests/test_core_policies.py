"""Policy-layer tests: Table 2 primitives + the §6.1/§6.2 policy library."""

import pytest

from repro.core import (AgentSpec, Directives, FixedLatency, LocalSchedule,
                        LognormalLatency, NalarRuntime, PolicyChain,
                        HoLMitigationPolicy, LoadBalancePolicy, LPTPolicy,
                        LPTSchedule, ResourceReassignmentPolicy, SRTFPolicy,
                        SRTFSchedule, default_policies, deployment, emulated)
from repro.core.policy import ActionSink, ClusterView, InstanceView
from repro.core.runtime import current_runtime


def make_view(**instances):
    view = ClusterView(now=10.0)
    for iid, (agent_type, qsize, busy, eta) in instances.items():
        iv = InstanceView(
            instance_id=iid, agent_type=agent_type, node="n0", qsize=qsize,
            busy=busy, busy_until=10.0 + eta if busy else 0.0,
            ema_service=0.5, completed=0, failed=0, alive=True,
            waiting_sessions=["s0"] if qsize else [])
        view.instances[iid] = iv
        view.by_type.setdefault(agent_type, []).append(iid)
    return view


def test_load_balance_weights_favor_idle():
    view = make_view(a0=("svc", 5, True, 10.0), a1=("svc", 0, False, 0.0))
    sink = ActionSink()
    LoadBalancePolicy().step(view, sink)
    (act,) = sink.actions
    assert act.kind == "route_weighted"
    w = dict(zip(act.payload["instances"], act.payload["weights"]))
    assert w["a1"] > w["a0"]


def test_hol_policy_migrates_waiting_session():
    view = make_view(a0=("svc", 3, True, 30.0), a1=("svc", 0, False, 0.0))
    sink = ActionSink()
    HoLMitigationPolicy(wait_threshold=0.1).step(view, sink)
    kinds = [a.kind for a in sink.actions]
    assert "migrate" in kinds
    mig = next(a for a in sink.actions if a.kind == "migrate")
    assert mig.payload["src"] == "a0" and mig.payload["dst"] == "a1"


def test_resource_reassignment_kills_cold_provisions_hot():
    view = make_view(hot0=("hot", 10, True, 20.0),
                     cold0=("cold", 0, False, 0.0),
                     cold1=("cold", 0, False, 0.0))
    sink = ActionSink()
    ResourceReassignmentPolicy(hot=4.0, cold=0.25, cooldown=0).step(view, sink)
    kinds = {a.kind for a in sink.actions}
    assert kinds == {"kill", "provision"}
    assert next(a for a in sink.actions
                if a.kind == "provision").payload["agent_type"] == "hot"


def test_srtf_schedule_orders_deeper_futures_first():
    class F:
        def __init__(self, depth, est, t):
            self.meta = type("M", (), {})()
            self.meta.work_hint = {"graph_depth": depth, "est_service": est}
            self.meta.created_at = t
            self.meta.priority = 0.0

    s = SRTFSchedule()
    futs = [F(0, 1.0, 0.0), F(2, 1.0, 1.0), F(1, 0.1, 2.0)]
    ordered = sorted(futs, key=lambda f: s.order_key(f, 0.0))
    assert [f.meta.work_hint["graph_depth"] for f in ordered] == [2, 1, 0]


def test_lpt_schedule_orders_retries_first():
    class F:
        def __init__(self, retry, est, t):
            self.meta = type("M", (), {})()
            self.meta.work_hint = {"retry": retry, "est_service": est}
            self.meta.created_at = t
            self.meta.priority = 0.0

    s = LPTSchedule()
    futs = [F(0, 5.0, 0.0), F(2, 1.0, 1.0), F(0, 9.0, 2.0)]
    ordered = sorted(futs, key=lambda f: s.order_key(f, 0.0))
    assert ordered[0].meta.work_hint["retry"] == 2
    assert ordered[1].meta.work_hint["est_service"] == 9.0


def test_policy_chain_is_composable_and_small():
    chain = default_policies()
    assert len(chain.policies) == 3     # the paper's three defaults


def test_global_controller_installs_schedule_end_to_end():
    rt = NalarRuntime(simulate=True, nodes={"n0": {"CPU": 8}},
                      policy=SRTFPolicy(), control_interval=0.05)
    rt.register_agent(AgentSpec(
        name="svc",
        methods={"run": emulated(FixedLatency(0.2), lambda x: x)},
        directives=Directives(resources={"CPU": 1})), instances=1)

    def driver():
        rt_ = current_runtime()
        fs = [rt_.stub("svc").run(i, _hint={"graph_depth": i}) for i in range(4)]
        rt_.kernel.sleep(1.0)
        return [f.value() for f in fs]

    out = deployment.main(driver, runtime=rt)
    assert sorted(out) == [0, 1, 2, 3]
    ctrl = rt.controller_of(rt.instances_of_type("svc")[0])
    assert ctrl.schedule_policy.name == "srtf"   # installed via node store


def test_hol_migration_improves_tail_latency():
    """The paper's central claim in miniature: with a long-running request
    hogging one instance, HoL mitigation migrates queued sessions to the
    idle instance, cutting tail latency."""

    def run(policy) -> float:
        rt = NalarRuntime(simulate=True,
                          nodes={"n0": {"CPU": 8}, "n1": {"CPU": 8}},
                          policy=policy, control_interval=0.1, seed=7)
        rt.register_agent(AgentSpec(
            name="llm",
            methods={"gen": emulated(LognormalLatency(0.4, 0.0), lambda x: x)},
            directives=Directives(max_instances=2, resources={"CPU": 1})),
            instances=2)
        inst0 = rt.instances_of_type("llm")[0]

        def long_driver():
            rt_ = current_runtime()
            rt_.router.pin(*_ctx_session(rt_), "llm", inst0) if False else None
            f = rt_.stub("llm").gen("long", _hint={"est_service": 30.0})
            f.value()

        def short_driver():
            f = current_runtime().stub("llm").gen("short")
            f.value()

        rt.start()
        # a long request occupies instance 0 (fixed-latency model scaled up)
        rt._specs["llm"].methods["gen"].latency = FixedLatency(10.0)
        rt.submit_request(long_driver)
        rt.kernel.schedule(0.05, lambda: setattr(
            rt._specs["llm"].methods["gen"], "latency", FixedLatency(0.4)))
        # shorts arrive while instance 0 is blocked; least-queue routing may
        # still pick it because queue length lags
        for i in range(6):
            rt.submit_request(short_driver, delay=0.1 + 0.01 * i)
        rt.run()
        return rt.telemetry.percentile(95)

    def _ctx_session(rt_):
        return ("",)

    class NoOp(LoadBalancePolicy):
        def step(self, view, act):
            return

    p95_off = run(NoOp())
    p95_on = run(PolicyChain(HoLMitigationPolicy(wait_threshold=0.2)))
    assert p95_on <= p95_off    # mitigation can only help here


def test_kv_affinity_policy_pins_sessions_to_cache_home():
    from repro.core import KVAffinityPolicy
    view = make_view(a0=("svc", 0, False, 0.0), a1=("svc", 0, False, 0.0))
    view.kv_residency = {"s1": ("a1", 40), "s2": ("a0", 12)}
    sink = ActionSink()
    KVAffinityPolicy().step(view, sink)
    pins = {a.payload["session_id"]: a.payload["instance"]
            for a in sink.actions if a.kind == "route"}
    assert pins == {"s1": "a1", "s2": "a0"}


def test_kv_affinity_policy_migrates_away_from_overload():
    from repro.core import KVAffinityPolicy
    view = make_view(a0=("svc", 6, True, 30.0), a1=("svc", 0, False, 0.0))
    view.instances["a0"].waiting_sessions = ["s1"]
    # s1 has work queued behind the overload -> migrate it; s2's cache also
    # lives on a0 but it has nothing pending -> a physical replay would be
    # wasted, so it is only pinned
    view.kv_residency = {"s1": ("a0", 40), "s2": ("a0", 12)}
    sink = ActionSink()
    KVAffinityPolicy(imbalance_eta=1.0).step(view, sink)
    kinds = {a.payload["session_id"]: a.kind for a in sink.actions}
    assert kinds == {"s1": "migrate", "s2": "route"}
    mig = next(a for a in sink.actions if a.kind == "migrate")
    assert mig.payload == dict(session_id="s1", src="a0", dst="a1")


def test_collect_view_prunes_completed_sessions_from_waiting():
    """Regression: metrics mirrors are pushed asynchronously, so an
    instance's ``waiting_sessions`` can keep naming sessions whose futures
    have all completed.  Aggregation must prune them — otherwise policies
    (e.g. HoL mitigation) migrate sessions that no longer exist."""
    from repro.core.session import clear_context, set_context

    rt = NalarRuntime(simulate=True, nodes={"n0": {"CPU": 8}})
    rt.register_agent(AgentSpec(
        name="svc",
        methods={"run": emulated(FixedLatency(10.0), lambda x: x)},
        directives=Directives(resources={"CPU": 1})), instances=1)
    iid = rt.instances_of_type("svc")[0]

    # one genuinely unresolved future for session "s-live"
    set_context("s-live", "r0", "driver:r0")
    try:
        rt.stub("svc").run(1)
    finally:
        clear_context()

    # stale mirror claiming both a live and a long-finished session wait here
    rt.stores.get("n0").hset_many(f"metrics:{iid}", {
        "agent_type": "svc", "node": "n0", "qsize": 2, "busy": True,
        "busy_until": 50.0, "ema_service": 1.0, "completed": 3, "failed": 0,
        "alive": True, "waiting_sessions": ["s-done", "s-live"],
    })

    view = rt.global_controller.collect_view()
    assert view.instances[iid].waiting_sessions == ["s-live"]

    # the HoL policy therefore acts on the live session, never the dead one
    view.instances[iid].qsize = 3
    view.by_type.setdefault("svc", [iid])
    idle = InstanceView(
        instance_id="svc:idle", agent_type="svc", node="n0", qsize=0,
        busy=False, busy_until=0.0, ema_service=1.0, completed=0, failed=0,
        alive=True, waiting_sessions=[])
    view.instances["svc:idle"] = idle
    view.by_type["svc"].append("svc:idle")
    sink = ActionSink()
    HoLMitigationPolicy(wait_threshold=0.1).step(view, sink)
    migrated = [a.payload["session_id"] for a in sink.actions
                if a.kind == "migrate"]
    assert migrated == ["s-live"]
    rt.shutdown()


def test_instance_view_eta_charges_async_inflight_work():
    """Async (engine-backed) instances never publish busy_until; their ETA
    must still reflect in-flight futures so least-ETA policies see load."""
    empty = InstanceView(
        instance_id="e0", agent_type="llm", node="n0", qsize=0, busy=False,
        busy_until=0.0, ema_service=0.5, completed=0, failed=0, alive=True,
        waiting_sessions=[], inflight=0)
    loaded = InstanceView(
        instance_id="e1", agent_type="llm", node="n0", qsize=0, busy=True,
        busy_until=0.0, ema_service=0.5, completed=0, failed=0, alive=True,
        waiting_sessions=[], inflight=4)
    assert empty.eta(10.0) == 0.0
    assert loaded.eta(10.0) == pytest.approx(4 * 0.5)
