"""Fig. 10 — Global control-loop latency vs number of futures.

Emulated large deployment (the paper's §6.3 methodology): 64 CPU nodes /
128 agents (and 32/64), future-metadata mirrors populated in the node
stores, a global SRTF policy that ranks the *entire* future population.
We measure the real wall-clock of global loops: collect (metrics + future
mirrors) -> policy -> push.  Paper claims: ~76 ms at 1,024 futures/64 nodes,
<500 ms at 131K, node-count-independent policy time, >65% of time in policy
logic.

Two regimes per configuration:

* ``cold`` — the bootstrap round: the controller's first view is a full
  rebuild, O(total futures).  Reported as ``cold_collect_ms``.
* ``steady`` — every subsequent round collects *deltas* only
  (``NodeStore.scan_changed``), so cost scales with churn (``CHURN``
  mutations are applied between rounds), not with the population.  These
  rounds are what the paper's control loop runs forever, and what the
  sub-500 ms / sublinearity claims are checked against.

Measured on this reproduction (see BENCH_control_loop.json at the repo
root): at 131,072 futures / 64 nodes the steady-state loop totals ~75 ms
compute (collect ~4 ms for ~1.2K changed entries vs ~760 ms for the cold
full scan, policy ~70 ms ranking all 131K mirrors, push ~2 ms) + ~71 ms
modelled network RTT ≈ 147 ms — comfortably sub-500 ms, with >90% of
compute in policy logic, reproducing the paper's shape: collect is flat
in population while policy scales with it.
"""

from __future__ import annotations

from typing import Dict, List

from repro.core import (ActionSink, AgentSpec, ClusterView, Directives,
                        FixedLatency, NalarRuntime, Policy, SRTFSchedule,
                        emulated)

# the paper measures over-the-network state collection; the in-process
# store has no RTT, so we model the per-node fetch cost it reports
# (76ms/64nodes/1024 futures ≈ 1.2ms per node RTT-ish + payload).  With
# delta collection the payload term is charged per *collected* entry
# (== churn in steady state, == population on the cold round).
PER_NODE_FETCH_S = 1.1e-3
PER_FUTURE_PAYLOAD_S = 0.55e-6

#: mirror mutations applied between steady-state rounds (fixed, so a sweep
#: over population sizes shows whether collect scales with churn or with N)
CHURN = 1024

STEADY_ROUNDS = 5


class GlobalSRTFPolicy(Policy):
    """SRTF over the global future population (the §6.3 benchmark policy).

    Ranks every live future mirror by remaining work, boosts the sessions
    closest to completion, and installs SRTF queue ordering everywhere —
    deliberately O(total futures), because the paper's headline finding is
    that *policy logic*, not state collection, should dominate the loop.
    """

    name = "global_srtf"

    def __init__(self, boost_k: int = 8) -> None:
        self.boost_k = boost_k

    def step(self, view: ClusterView, act: ActionSink) -> None:
        remaining: Dict[str, int] = {}
        for m in view.futures.values():
            if m.get("state") in ("pending", "scheduled", "running"):
                sid = m.get("session", "")
                remaining[sid] = remaining.get(sid, 0) + 1
        for sid, _ in sorted(remaining.items(),
                             key=lambda kv: (kv[1], kv[0]))[:self.boost_k]:
            if sid:
                act.set_priority(sid, 10.0)
        for agent_type in view.by_type:
            act.install_schedule(agent_type, SRTFSchedule())


def build(n_nodes: int, n_agents: int) -> NalarRuntime:
    rt = NalarRuntime(
        simulate=True,
        nodes={f"n{i}": {"CPU": 64} for i in range(n_nodes)},
        policy=GlobalSRTFPolicy(), control_interval=1e9)
    # steady-state rounds must measure the delta path, not a mid-sweep
    # escape-hatch rebuild
    rt.global_controller.full_rebuild_interval = 0
    for a in range(n_agents):
        rt.register_agent(AgentSpec(
            name=f"agent{a}",
            methods={"run": emulated(FixedLatency(1.0), lambda: 1)},
            directives=Directives(max_instances=1, resources={"CPU": 0})),
            nodes=[f"n{a % n_nodes}"], instances=1)
    return rt


def _mirror(i: int, n: int, state: str = "scheduled") -> Dict:
    return {
        "state": state,
        "agent_type": f"agent{i % 8}",
        "session": f"s{i % 1024}",
        "executor": f"agent{i % 8}:n{i % n}/0",
        "consumers": [],
        "dependencies": [],
        "priority": 0.0,
        "created_at": 0.0,
        "attempt": 0,
    }


def populate_futures(rt: NalarRuntime, n_futures: int) -> None:
    stores = rt.stores.all_stores()
    n = len(stores)
    for i in range(n_futures):
        stores[i % n].hset_many(f"future:syn{i}", _mirror(i, n))


def apply_churn(rt: NalarRuntime, n_futures: int, round_idx: int,
                born_prev: List[str]) -> List[str]:
    """Mutate a fixed-size cohort of mirrors between rounds: state flips on
    existing futures plus a birth/death wave (new futures created, the
    previous wave's newborns resolved and deleted), modelling a serving
    cluster at a constant churn rate."""
    stores = rt.stores.all_stores()
    n = len(stores)
    base = (round_idx * CHURN) % max(1, n_futures)
    for j in range(CHURN):
        i = (base + j) % n_futures
        state = "running" if (round_idx + j) % 2 else "ready"
        stores[i % n].hset(f"future:syn{i}", "state", state)
    for key in born_prev:                      # last wave resolves + retires
        stores[hash(key) % n].delete(key)
    born = []
    for j in range(CHURN // 8):
        i = n_futures + round_idx * (CHURN // 8) + j
        key = f"future:new{i}"
        stores[hash(key) % n].hset_many(key, _mirror(i, n))
        born.append(key)
    return born


def run(quick: bool = True) -> List[Dict]:
    configs = ([(32, 64), (64, 128)])
    sizes = [1024, 8192, 32768, 131072] if not quick else [1024, 8192, 32768]
    rows = []
    for n_nodes, n_agents in configs:
        for n_futures in sizes:
            rt = build(n_nodes, n_agents)
            populate_futures(rt, n_futures)
            gc = rt.global_controller
            cold = gc.run_once()               # bootstrap: full view rebuild
            steady: List[Dict[str, float]] = []
            born: List[str] = []
            for r in range(STEADY_ROUNDS):
                born = apply_churn(rt, n_futures, r, born)
                steady.append(gc.run_once())

            def mean(k: str) -> float:
                return sum(b[k] for b in steady) / len(steady)

            n_collected = mean("n_collected")
            modeled_rtt = n_nodes * PER_NODE_FETCH_S \
                + n_collected * PER_FUTURE_PAYLOAD_S
            rows.append({
                "bench": "fig10_control_loop",
                "nodes": n_nodes, "agents": n_agents, "futures": n_futures,
                "churn": CHURN,
                "cold_collect_ms": 1e3 * cold["collect"],
                "collect_ms": 1e3 * mean("collect"),
                "policy_ms": 1e3 * mean("policy"),
                "push_ms": 1e3 * mean("push"),
                "compute_total_ms": 1e3 * mean("total"),
                "n_collected": n_collected,
                "modeled_network_ms": 1e3 * modeled_rtt,
                "loop_total_ms": 1e3 * (mean("total") + modeled_rtt),
            })
            rt.shutdown()
    return rows


def derive(rows: List[Dict]) -> List[str]:
    out = []
    biggest = max(rows, key=lambda r: (r["futures"], r["nodes"]))
    out.append(f"fig10,futures={biggest['futures']},loop_total_ms,"
               f"{biggest['loop_total_ms']:.1f}")
    out.append(f"fig10,claim,sub_500ms_at_max,"
               f"{int(biggest['loop_total_ms'] < 500)}")
    # >65% of loop compute in policy logic at the biggest size (paper §6.3)
    frac = biggest["policy_ms"] / max(1e-9, biggest["compute_total_ms"])
    out.append(f"fig10,futures={biggest['futures']},policy_frac,{frac:.2f}")
    out.append(f"fig10,claim,policy_dominates,{int(frac > 0.65)}")
    # collect sublinearity: fixed churn => steady collect should stay flat
    # while the population grows (the incremental-control-plane claim)
    for nodes in sorted({r["nodes"] for r in rows}):
        sub = sorted((r for r in rows if r["nodes"] == nodes),
                     key=lambda r: r["futures"])
        if len(sub) >= 2:
            lo, hi = sub[0], sub[-1]
            growth = hi["collect_ms"] / max(1e-9, lo["collect_ms"])
            pop_growth = hi["futures"] / lo["futures"]
            out.append(f"fig10,nodes={nodes},collect_growth_"
                       f"{lo['futures']}to{hi['futures']},{growth:.2f}")
            out.append(f"fig10,nodes={nodes},collect_sublinear,"
                       f"{int(growth < pop_growth / 4)}")
    # node-count independence: same futures, 32 vs 64 nodes
    for n_futures in sorted({r["futures"] for r in rows}):
        sub = {r["nodes"]: r for r in rows if r["futures"] == n_futures}
        if 32 in sub and 64 in sub and sub[32]["policy_ms"] > 0:
            ratio = sub[64]["policy_ms"] / sub[32]["policy_ms"]
            out.append(f"fig10,futures={n_futures},"
                       f"policy_time_64v32_ratio,{ratio:.2f}")
    return out
