"""Fig. 10 — Global control-loop latency vs number of futures.

Emulated large deployment (the paper's §6.3 methodology): 64 CPU nodes /
128 agents (and 32/64), future-metadata mirrors populated in the node
stores, SRTF policy installed.  We measure the real wall-clock of one
global loop: collect (metrics + future mirrors from every store) -> policy
-> push.  Paper claims: ~76 ms at 1,024 futures/64 nodes, <500 ms at 131K,
node-count-independent policy time, >65% of time in policy logic.
"""

from __future__ import annotations

import time
from typing import Dict, List

from repro.core import (AgentSpec, Directives, FixedLatency, NalarRuntime,
                        SRTFPolicy, emulated)

# the paper measures over-the-network state collection; the in-process
# store has no RTT, so we model the per-node fetch cost it reports
# (76ms/64nodes/1024 futures ≈ 1.2ms per node RTT-ish + payload)
PER_NODE_FETCH_S = 1.1e-3
PER_FUTURE_PAYLOAD_S = 0.55e-6


def build(n_nodes: int, n_agents: int) -> NalarRuntime:
    rt = NalarRuntime(
        simulate=True,
        nodes={f"n{i}": {"CPU": 64} for i in range(n_nodes)},
        policy=SRTFPolicy(), control_interval=1e9)
    for a in range(n_agents):
        rt.register_agent(AgentSpec(
            name=f"agent{a}",
            methods={"run": emulated(FixedLatency(1.0), lambda: 1)},
            directives=Directives(max_instances=1, resources={"CPU": 0})),
            nodes=[f"n{a % n_nodes}"], instances=1)
    return rt


def populate_futures(rt: NalarRuntime, n_futures: int) -> None:
    stores = rt.stores.all_stores()
    n = len(stores)
    for i in range(n_futures):
        stores[i % n].hset_many(f"future:syn{i}", {
            "state": "scheduled",
            "agent_type": f"agent{i % 8}",
            "session": f"s{i % 1024}",
            "executor": f"agent{i % 8}:n{i % n}/0",
            "consumers": [],
            "dependencies": [],
            "priority": 0.0,
            "created_at": 0.0,
        })


def run(quick: bool = True) -> List[Dict]:
    configs = ([(32, 64), (64, 128)])
    sizes = [1024, 8192, 32768, 131072] if not quick else [1024, 8192, 32768]
    rows = []
    for n_nodes, n_agents in configs:
        for n_futures in sizes:
            rt = build(n_nodes, n_agents)
            populate_futures(rt, n_futures)
            gc = rt.global_controller
            gc.run_once()                      # warm caches
            reps = 3
            best = None
            for _ in range(reps):
                b = gc.run_once()
                if best is None or b["total"] < best["total"]:
                    best = b
            modeled_rtt = n_nodes * PER_NODE_FETCH_S \
                + n_futures * PER_FUTURE_PAYLOAD_S
            rows.append({
                "bench": "fig10_control_loop",
                "nodes": n_nodes, "agents": n_agents, "futures": n_futures,
                "collect_ms": 1e3 * best["collect"],
                "policy_ms": 1e3 * best["policy"],
                "push_ms": 1e3 * best["push"],
                "compute_total_ms": 1e3 * best["total"],
                "modeled_network_ms": 1e3 * modeled_rtt,
                "loop_total_ms": 1e3 * (best["total"] + modeled_rtt),
            })
            rt.shutdown()
    return rows


def derive(rows: List[Dict]) -> List[str]:
    out = []
    biggest = max(rows, key=lambda r: r["futures"])
    out.append(f"fig10,futures={biggest['futures']},loop_total_ms,"
               f"{biggest['loop_total_ms']:.1f}")
    out.append(f"fig10,claim,sub_500ms_at_max,"
               f"{int(biggest['loop_total_ms'] < 500)}")
    # node-count independence: same futures, 32 vs 64 nodes
    for n_futures in sorted({r["futures"] for r in rows}):
        sub = {r["nodes"]: r for r in rows if r["futures"] == n_futures}
        if 32 in sub and 64 in sub and sub[32]["policy_ms"] > 0:
            ratio = sub[64]["policy_ms"] / sub[32]["policy_ms"]
            out.append(f"fig10,futures={n_futures},"
                       f"policy_time_64v32_ratio,{ratio:.2f}")
    return out
