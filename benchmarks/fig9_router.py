"""Fig. 9b — Router workflow under branch imbalance: average latency +
failure(timeout) rate vs RPS.  Paper claim: baselines collapse at 70-80
RPS; NALAR sustains <50 s average via dynamic resource reallocation."""

from __future__ import annotations

from typing import Dict, List

from repro.workloads import BASELINES, run_router, system_config


def run(quick: bool = True) -> List[Dict]:
    rates = [60.0, 95.0] if quick else [40.0, 60.0, 80.0, 95.0]
    duration = 24.0 if quick else 30.0
    rows = []
    for rps in rates:
        for name in ["nalar"] + BASELINES:
            r = run_router(system_config(name), rps=rps, duration=duration,
                           seed=13)
            r["bench"] = "fig9b_router"
            rows.append(r)
    return rows


def derive(rows: List[Dict]) -> List[str]:
    out = []
    top = max(r["rps"] for r in rows)
    sub = [r for r in rows if r["rps"] == top]
    nalar = next(r for r in sub if r["system"] == "nalar")
    worst_base = max(r.get("avg", float("inf")) for r in sub
                     if r["system"] != "nalar" and r.get("n", 0) > 0)
    out.append(f"fig9b,rps={top},nalar_avg_s,{nalar.get('avg', -1):.2f}")
    out.append(f"fig9b,rps={top},worst_baseline_avg_s,{worst_base:.2f}")
    for r in sub:
        out.append(f"fig9b,rps={top},{r['system']}_timeout_rate,"
                   f"{r['timeout_rate']:.3f}")
    return out
