"""Token-streaming data-plane benchmark — inter-step pipelining on real
engines, wall-clock time.

The workload is the streamed-router workflow (``workloads/router.py``): a
draft generation, a classifier that needs only the first few output tokens,
and a branch refinement issued once the classifier decides.  Two modes,
identical prompts / seed / greedy decode:

* ``completion`` — the baseline all-or-nothing future: the classifier
  parks until the draft fully resolves, so the critical path is
  ``draft + classify + refine`` laid end to end.
* ``streamed``   — the classifier declares ``stream_min_tokens`` and is
  dispatched as soon as that many tokens exist in the draft future's
  chunk log; classify and the refine generation overlap the draft's
  remaining decode steps.

Because decode is greedy, both modes must produce **byte-identical**
outputs (same branch decision, same draft tokens, same refine tokens) —
the benchmark asserts it.  The paper-claim check is the latency shape:
streamed p99 end-to-end beats completion-only, and TTFT (stamped by
telemetry at the first accepted chunk) sits well inside e2e.

    PYTHONPATH=src python benchmarks/streaming.py            # table
    PYTHONPATH=src python benchmarks/streaming.py --smoke    # CI assertions
    PYTHONPATH=src python -m benchmarks.run --only streaming
"""

from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.workloads.router import (add_stream_classifier,  # noqa: E402
                                    build_pool_runtime,
                                    completion_routed_driver,
                                    streamed_routed_driver)

OUT_TOKENS = 24      # draft length — long tail for the branch to overlap
STREAM_MIN = 6       # classifier starts once this many draft tokens exist
REFINE_TOKENS = 6
CLASSIFY_S = 0.02


def _warm(rt) -> None:
    """Compile prefill/decode shapes up front so JIT time does not pollute
    the mode comparison (same trick as benchmarks/pool_routing.py)."""
    from repro.serving import SamplingParams
    pool = rt.engine_backends["llm"]
    for iid in pool.instance_ids:
        engine = pool.bridge_of(iid).engine
        for b in (16, 32):
            sid = f"warmup:{iid}:{b}"
            engine.generate(list(range(b - 1)), session_id=sid,
                            sampling=SamplingParams(max_new_tokens=2))
            engine.pool.release(sid)
            if engine.kv_registry is not None:
                engine.kv_registry.release(sid)


def run_streaming(streamed: bool, *, requests: int = 6, gap: float = 0.25,
                  seed: int = 0, timeout_s: float = 300.0) -> Dict:
    rt = build_pool_runtime(replicas=2, max_batch=4,
                            max_new_tokens=OUT_TOKENS, seed=seed)
    add_stream_classifier(rt, latency=CLASSIFY_S, k=STREAM_MIN)
    _warm(rt)
    outputs: Dict[int, Dict] = {}
    errors: List[str] = []

    rt.start()
    for i in range(requests):
        def cb(out, err, i=i):
            if err is not None:
                errors.append(f"req{i}: {err!r}")
            else:
                outputs[i] = out
        q = f"stream bench query {i} with a little extra context"
        if streamed:
            rt.submit_request(streamed_routed_driver, q, OUT_TOKENS,
                              STREAM_MIN, REFINE_TOKENS,
                              delay=i * gap, deadline_s=timeout_s,
                              on_done=cb)
        else:
            rt.submit_request(completion_routed_driver, q, OUT_TOKENS,
                              REFINE_TOKENS, delay=i * gap,
                              deadline_s=timeout_s, on_done=cb)
    time.sleep(requests * gap + 0.5)     # let every arrival timer fire
    rt.run()

    summary = rt.telemetry.summary()
    dl = rt.telemetry.deadline_outcomes()
    row = {
        "bench": "streaming",
        "system": "streamed" if streamed else "completion",
        "requests": requests,
        "completed": len(outputs),
        "errors": len(errors),
        "p50_s": summary.get("p50", float("nan")),
        "p99_s": summary.get("p99", float("nan")),
        "ttft_p50_s": dl.get("ttft_p50", float("nan")),
        "ttft_p99_s": dl.get("ttft_p99", float("nan")),
        "outputs": {str(i): outputs[i] for i in sorted(outputs)},
        "error_detail": errors,
    }
    rt.shutdown()
    return row


def run(quick: bool = True) -> List[Dict]:
    n = 6 if quick else 16
    return [run_streaming(False, requests=n),
            run_streaming(True, requests=n)]


def _byte_identical(rows: List[Dict]) -> bool:
    by = {r["system"]: r for r in rows}
    a, b = by["completion"]["outputs"], by["streamed"]["outputs"]
    return a.keys() == b.keys() and all(a[k] == b[k] for k in a)


def derive(rows: List[Dict]) -> List[str]:
    by = {r["system"]: r for r in rows}
    out = []
    for mode, r in by.items():
        out.append(f"streaming,{mode},p50_s,{r['p50_s']:.3f}")
        out.append(f"streaming,{mode},p99_s,{r['p99_s']:.3f}")
        out.append(f"streaming,{mode},ttft_p50_s,{r['ttft_p50_s']:.3f}")
        out.append(f"streaming,{mode},ttft_p99_s,{r['ttft_p99_s']:.3f}")
    comp, strm = by.get("completion"), by.get("streamed")
    if comp and strm:
        out.append(f"streaming,claim,outputs_byte_identical,"
                   f"{int(_byte_identical(rows))}")
        out.append(f"streaming,claim,streamed_p99_lt_completion,"
                   f"{int(strm['p99_s'] < comp['p99_s'])}")
        out.append(f"streaming,claim,p99_cut_s,"
                   f"{comp['p99_s'] - strm['p99_s']:.3f}")
        out.append(f"streaming,claim,ttft_inside_e2e,"
                   f"{int(strm['ttft_p50_s'] < strm['p50_s'])}")
        out.append(f"streaming,claim,no_errors,"
                   f"{int(comp['errors'] == 0 and strm['errors'] == 0)}")
    return out


def write_record(rows: List[Dict], mode: str) -> None:
    by = {r["system"]: r for r in rows}
    comp, strm = by["completion"], by["streamed"]
    payload = {
        "bench": "streaming",
        "mode": mode,
        "out_tokens": OUT_TOKENS,
        "stream_min_tokens": STREAM_MIN,
        "p99_completion_s": round(comp["p99_s"], 4),
        "p99_streamed_s": round(strm["p99_s"], 4),
        "p99_cut_s": round(comp["p99_s"] - strm["p99_s"], 4),
        "ttft_p50_s": round(strm["ttft_p50_s"], 4),
        "ttft_p99_s": round(strm["ttft_p99_s"], 4),
        "outputs_byte_identical": _byte_identical(rows),
        "derived": derive(rows),
        "rows": rows,
    }
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_streaming.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=str)
        f.write("\n")


def main() -> None:
    smoke = "--smoke" in sys.argv
    rows = run(quick=True)
    for row in rows:
        slim = {k: v for k, v in row.items()
                if k not in ("outputs", "error_detail")}
        print(slim)
    for line in derive(rows):
        print(line)
    if not smoke:
        write_record(rows, "quick")
        return
    by = {r["system"]: r for r in rows}
    comp, strm = by["completion"], by["streamed"]
    assert comp["errors"] == 0 and strm["errors"] == 0, \
        (comp["error_detail"], strm["error_detail"])
    assert comp["completed"] == comp["requests"], "completion mode dropped work"
    assert strm["completed"] == strm["requests"], "streamed mode dropped work"
    assert _byte_identical(rows), \
        "streamed and completion modes must produce byte-identical outputs"
    assert strm["p99_s"] < comp["p99_s"], \
        (f"partial-output early start must cut p99: streamed "
         f"{strm['p99_s']:.3f}s vs completion {comp['p99_s']:.3f}s")
    assert strm["ttft_p50_s"] > 0, "TTFT must be stamped from chunk arrivals"
    assert strm["ttft_p50_s"] < strm["p50_s"], \
        "first streamed chunk must land well before e2e completion"
    print(f"streaming --smoke: OK (p99 completion={comp['p99_s']:.3f}s "
          f"streamed={strm['p99_s']:.3f}s, "
          f"ttft_p50={strm['ttft_p50_s']:.3f}s, outputs byte-identical)")


if __name__ == "__main__":
    main()
