"""Speculative decoding + model-tier routing — real engines, wall-clock.

Two claims ride the paged data plane this PR armed:

* **acceptance-weighted decode tokens/step** — identical greedy workload
  through a plain engine and one with a small-tier draft (the target's
  own first layer, truncated then *distilled* on the target's decisions:
  ~half the FLOPs, same tokenizer by construction).  The verifier runs
  all ``k+1`` positions in one fused ``decode_chunk_paged`` call, so
  every accepted draft token rides a step for free.  Claims: acceptance
  > 0, tokens/step >= 1.5x the baseline, and the emitted greedy stream is
  *byte-identical* to the non-speculative engine's (rejection rollback
  keeps the COW page bracket exact).

  Why the pair is briefly trained first: a random-*weight* target is a
  random hash of its context — no smaller model can predict its argmax
  (measured ~7 % agreement, pure noise floor), which says nothing about
  speculation because production targets are trained and their easy
  tokens are exactly what a draft recovers.  So the target takes a few
  hundred AdamW steps on a synthetic low-entropy corpus (modular
  arithmetic ramps standing in for templated agent traces), and the
  draft is distilled from the target's own greedy labels on that
  distribution (`serving.speculative.distill_draft`).  The engine
  machinery under test — fused verify, COW rollback, acceptance
  accounting — is identical either way; training only restores the
  low-entropy regime speculation exploits.  The greedy-identity check is
  training-independent (both engines share the same target params).

* **goodput-per-FLOP under tier routing** — a fig9-style two-phase mix of
  cheap and hard steps on a 3-replica pool, once with every replica on the
  large tier, once with a small-tier replica + ``TierRoutePolicy`` routing
  ``model_tier`` hints.  Cheap steps burn small-tier FLOPs instead of
  large-tier ones, so completed work per FLOP rises.

Numbers are CPU smoke-model scale — the *shape* (ratios, identity) is the
reproduced claim, not absolute latency.  Token-count ratios are
deterministic for greedy decoding, so the 1.5x budget holds across hosts.

    PYTHONPATH=src python -m benchmarks.spec_decode          # quick
    PYTHONPATH=src python benchmarks/spec_decode.py --smoke  # CI budget
    PYTHONPATH=src python -m benchmarks.run --only spec_decode
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.serving.batching import Request  # noqa: E402
from repro.serving.engine import InferenceEngine  # noqa: E402
from repro.serving.sampler import SamplingParams  # noqa: E402
from repro.serving.speculative import distill_draft, truncated_draft  # noqa: E402

TARGET = "qwen3_1_7b"          # large tier (the verify side)
SMALL = "qwen3_0_6b"           # small tier for the routing row
MAX_SEQ = 96
PAGE = 8
MAX_BATCH = 4
SPEC_K = 3
TRAIN_STEPS = 250

_MODELS: Dict[str, tuple] = {}
_TRAINED: Dict[str, tuple] = {}


def _model(arch):
    if arch not in _MODELS:
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _MODELS[arch] = (model, params)
    return _MODELS[arch]


def _ramps(key, B, S, V):
    """Low-entropy corpus: modular arithmetic ramps (random start/stride)
    — the smoke-scale stand-in for templated agent-trace text."""
    import jax.numpy as jnp
    k1, k2 = jax.random.split(key)
    start = jax.random.randint(k1, (B, 1), 1, V)
    stride = jax.random.randint(k2, (B, 1), 1, 17)
    pos = jnp.arange(S)[None, :]
    return ((start + stride * pos) % (V - 1) + 1).astype(jnp.int32)


def _trained_pair(arch):
    """Target trained on the ramp corpus + 1-layer draft distilled from
    the target's greedy labels (see module docstring for why)."""
    if arch in _TRAINED:
        return _TRAINED[arch]
    import jax.numpy as jnp
    from repro.training.optimizer import AdamW, constant_schedule
    model, params = _model(arch)
    V = model.cfg.vocab_size

    def ce(p, toks):
        out = model.forward(p, {"tokens": toks})
        lg = out[0] if isinstance(out, tuple) else out
        lp = jax.nn.log_softmax(lg[:, :-1].astype(jnp.float32), axis=-1)
        return -jnp.mean(jnp.take_along_axis(lp, toks[:, 1:, None], -1))

    opt = AdamW(learning_rate=constant_schedule(3e-3), weight_decay=0.0)
    state = opt.init(params)
    step = jax.jit(lambda p, st, t: opt.update(jax.grad(ce)(p, t), st, p))
    key = jax.random.PRNGKey(1)
    for _ in range(TRAIN_STEPS):
        key, sub = jax.random.split(key)
        params, state = step(params, state, _ramps(sub, 32, 48, V))

    draft, dparams = truncated_draft(model, params, 1)
    dparams = distill_draft(draft, dparams, model, params,
                            lambda k: _ramps(k, 32, 48, V),
                            steps=TRAIN_STEPS, seed=2)
    _TRAINED[arch] = (model, params, draft, dparams)
    return _TRAINED[arch]


def _flops_per_token(arch) -> float:
    """Dense decode FLOPs/token proxy: 2 x parameter count."""
    _, params = _model(arch)
    return 2.0 * sum(x.size for x in jax.tree_util.tree_leaves(params))


def _engine(arch, *, spec: bool) -> InferenceEngine:
    model, params, draft, dparams = _trained_pair(arch)
    kw = {}
    if spec:
        kw = dict(draft_model=draft, draft_params=dparams, spec_k=SPEC_K,
                  spec_min_accept=0.0)
    return InferenceEngine(model, params, max_batch=MAX_BATCH,
                           max_seq=MAX_SEQ, page_size=PAGE, prefill_chunk=8,
                           rng_seed=0, **kw)


def _decode_workload(eng: InferenceEngine, n_req: int, gen_len: int) -> Dict:
    rng = np.random.default_rng(0)
    V = eng.model.cfg.vocab_size
    sp = SamplingParams(temperature=0.0, max_new_tokens=gen_len)
    reqs = []
    for j in range(n_req):
        # held-out ramp prompts: same family as the corpus, fresh draws
        start, stride = int(rng.integers(1, V)), int(rng.integers(1, 17))
        prompt = [(start + stride * t) % (V - 1) + 1
                  for t in range(8 + j % 5)]
        r = Request.make(prompt, session_id=f"c{j}", sampling=sp)
        eng.submit(r)
        reqs.append(r)
    t0 = time.perf_counter()
    while eng.step():
        pass
    wall = time.perf_counter() - t0
    m = eng.metrics
    return {
        "sessions": {r.session_id: list(r.generated) for r in reqs},
        "decode_steps": m.decode_steps,
        "tokens_generated": m.tokens_generated,
        "tokens_per_step": m.decode_tokens_per_step,
        "spec_rounds": m.spec_rounds,
        "spec_proposed": m.spec_proposed,
        "spec_accepted": m.spec_accepted,
        "spec_acceptance": m.spec_acceptance,
        "tok_per_s": m.tokens_generated / max(wall, 1e-9),
    }


def _tier_workload(tiered: bool, n_req: int, out_small: int,
                   out_large: int) -> Dict:
    """Fig9-style two-phase cheap/hard mix on a 3-replica pool."""
    from repro.core import TierRoutePolicy
    from repro.workloads.router import build_pool_runtime, tiered_driver

    rt = build_pool_runtime(
        replicas=3, arch=TARGET, max_batch=MAX_BATCH, max_seq=MAX_SEQ,
        tiers=(["small", "large", "large"] if tiered else None),
        tier_archs={"small": SMALL},
        policy=TierRoutePolicy(), control_interval=0.05,
        kv_affinity=False, prefill_chunk=8)
    rt.start()
    rng = np.random.default_rng(1)
    for i in range(n_req):
        # phase 1 cheap-heavy, phase 2 hard-heavy (the trace's imbalance)
        hard = rng.random() < (0.7 if i >= n_req // 2 else 0.2)
        tier = "large" if hard else "small"
        out = out_large if hard else out_small
        rt.submit_request(tiered_driver, f"q{i} {'hard' if hard else 'easy'}",
                          tier, out)
    rt.run(max_time=180.0)
    backend = rt.engine_backends["llm"]
    per_replica = []
    flops = completed = tokens = 0.0
    for iid in sorted(backend.instance_ids):
        eng = backend.bridge_of(iid).engine
        arch = SMALL if (tiered and eng.tier == "small") else TARGET
        f = eng.metrics.tokens_generated * _flops_per_token(arch)
        flops += f
        completed += eng.metrics.completed
        tokens += eng.metrics.tokens_generated
        per_replica.append({"instance": iid, "tier": eng.tier, "arch": arch,
                            "completed": eng.metrics.completed,
                            "tokens": eng.metrics.tokens_generated})
    rt.shutdown()
    return {"completed": completed, "tokens": tokens, "flops": flops,
            "goodput_per_gflop": completed / max(flops / 1e9, 1e-12),
            "replicas": per_replica}


def run(quick: bool = True, smoke: bool = False) -> List[Dict]:
    n_req = 8 if (quick or smoke) else 24
    gen_len = 24 if (quick or smoke) else 48
    rows: List[Dict] = []

    base = _decode_workload(_engine(TARGET, spec=False), n_req, gen_len)
    spec = _decode_workload(_engine(TARGET, spec=True), n_req, gen_len)
    identical = base["sessions"] == spec["sessions"]
    for mode, m in (("baseline", base), ("speculative", spec)):
        r = {k: v for k, v in m.items() if k != "sessions"}
        rows.append({"bench": "spec_decode", "row": "decode", "arch": TARGET,
                     "mode": mode, "greedy_identical": identical, **r})

    tn = 12 if (quick or smoke) else 36
    single = _tier_workload(False, tn, out_small=4, out_large=8)
    tiered = _tier_workload(True, tn, out_small=4, out_large=8)
    for mode, m in (("single_tier", single), ("tiered", tiered)):
        rows.append({"bench": "spec_decode", "row": "tier_routing",
                     "mode": mode, **m})
    return rows


def _pick(rows, row, mode):
    return next(r for r in rows if r["row"] == row and r["mode"] == mode)


def derive(rows: List[Dict]) -> List[str]:
    base = _pick(rows, "decode", "baseline")
    spec = _pick(rows, "decode", "speculative")
    gain = spec["tokens_per_step"] / max(base["tokens_per_step"], 1e-9)
    out = [
        f"{TARGET}: speculative {spec['tokens_per_step']:.2f} tokens/step vs "
        f"baseline {base['tokens_per_step']:.2f} ({gain:.2f}x), acceptance "
        f"{spec['spec_acceptance']:.1%} over {spec['spec_rounds']} rounds, "
        f"greedy byte-identical={spec['greedy_identical']}",
    ]
    st = _pick(rows, "tier_routing", "single_tier")
    ti = _pick(rows, "tier_routing", "tiered")
    fgain = ti["goodput_per_gflop"] / max(st["goodput_per_gflop"], 1e-12)
    out.append(
        f"tier routing: {ti['goodput_per_gflop']:.2f} completions/GFLOP "
        f"(small+large) vs {st['goodput_per_gflop']:.2f} (all-large) — "
        f"{fgain:.2f}x goodput-per-FLOP at equal replica count")
    return out


def write_record(rows: List[Dict], mode: str) -> str:
    base = _pick(rows, "decode", "baseline")
    spec = _pick(rows, "decode", "speculative")
    st = _pick(rows, "tier_routing", "single_tier")
    ti = _pick(rows, "tier_routing", "tiered")
    checks = {
        "acceptance_positive": bool(spec["spec_acceptance"] > 0),
        "greedy_identical_to_baseline": bool(spec["greedy_identical"]),
        "tokens_per_step_above_one": bool(spec["tokens_per_step"] > 1.0),
        "tokens_per_step_1_5x": bool(
            spec["tokens_per_step"]
            >= 1.5 * base["tokens_per_step"]),
        "tier_goodput_per_flop_gain": bool(
            ti["goodput_per_gflop"] > st["goodput_per_gflop"]),
        "tiered_completed_all": bool(ti["completed"] >= st["completed"]),
    }
    payload = {"bench": "spec_decode", "mode": mode, "spec_k": SPEC_K,
               "target": TARGET,
               "draft": "1-layer truncated self-draft, distilled on target "
                        "greedy labels (ramp corpus)",
               "small_tier": SMALL, "checks": checks,
               "derived": derive(rows), "rows": rows}
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_spec_decode.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=str)
        f.write("\n")
    return path


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true")
    p.add_argument("--smoke", action="store_true",
                   help="CI budget: acceptance > 0, greedy identical to "
                        "baseline, tokens/step > 1, tier routing wins "
                        "goodput-per-FLOP")
    args = p.parse_args()
    rows = run(quick=not args.full, smoke=args.smoke)
    for line in derive(rows):
        print(line)
    path = write_record(rows, "smoke" if args.smoke else
                        ("quick" if not args.full else "full"))
    print(f"wrote {os.path.relpath(path)}")
    if args.smoke:
        with open(path) as f:
            checks = json.load(f)["checks"]
        bad = [name for name, ok in checks.items() if ok is False]
        assert not bad, f"spec-decode budget violated: {bad}"
        print("spec_decode --smoke: OK (acceptance > 0, greedy identical, "
              "tokens/step > 1.5x baseline, tier routing wins per FLOP)")


if __name__ == "__main__":
    main()
