"""Analytic FLOPs / HBM-traffic estimator for the roofline terms.

Why analytic: XLA:CPU's cost analysis counts while-loop bodies ONCE
(trip-count-unaware), so `compiled.cost_analysis()['flops']` under-reports
layer-scanned programs by ~L x.  We therefore derive the compute and memory
terms from a model-aware estimator (we wrote every model, so the op
inventory is exact at matmul granularity) and CROSS-CHECK against the raw
XLA number: raw x layer-trip-count must land within ~2x of the estimate
(asserted in tests/test_roofline.py).

Conventions: one matmul MAC = 2 FLOPs; backward = 2x forward (train = 3x);
attention uses the exact causal/windowed average KV length; MoE includes
the one-hot dispatch/combine einsum overhead (the "einsum" impl) or not
("gather") — the delta is one of the §Perf hillclimbs.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import InputShape, ModelConfig

MOE_GROUP = 2048


def _avg_kv(S: int, window) -> float:
    """Average attended KV length per query under causal (+window) masking."""
    if window is None or window >= S:
        return (S + 1) / 2.0
    W = window
    return (W * (W + 1) / 2.0 + (S - W) * W) / S


def _attn_flops_per_token(cfg: ModelConfig, kv_len: float) -> float:
    D, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    proj = 2 * D * H * Dh + 2 * 2 * D * Hkv * Dh + 2 * H * Dh * D
    attn = 2 * 2 * kv_len * H * Dh          # qk^T and pv
    return proj + attn


def _mlp_flops_per_token(cfg: ModelConfig, d_ff: int) -> float:
    mults = 3 if cfg.mlp_type == "swiglu" else 2
    return 2 * mults * cfg.d_model * d_ff


def _moe_flops_per_token(cfg: ModelConfig, group: int, impl: str) -> float:
    D, E, Fe, k = cfg.d_model, cfg.n_experts, cfg.d_expert, cfg.top_k
    router = 2 * D * E
    experts = 2 * 3 * D * Fe * k
    if impl == "einsum":
        # dispatch + combine one-hot matmuls: each costs 2*E*C*D per token
        # (with C = G*k*cf/E per group), i.e. the waste grows with group size
        dispatch = 2 * (2 * E * _cap(group, cfg) * D)
    else:
        dispatch = 0.0    # gather impl: index ops, no matmul FLOPs
    return router + experts + dispatch


def _cap(group: int, cfg: ModelConfig) -> int:
    c = int(group * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(4, -(-c // 4) * 4)


def _ssm_flops_per_token(cfg: ModelConfig, decode: bool) -> float:
    D, din, N, H, P = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                       cfg.n_ssm_heads, cfg.ssm_head_dim)
    K = cfg.ssm_conv
    X = 2 * din + 2 * N + H
    proj = 2 * D * X + 2 * din * D           # in_proj + out_proj
    conv = 2 * K * (din + 2 * N)
    if decode:
        ssd = 2 * H * P * N * 2               # state update + readout
    else:
        Q = cfg.ssm_chunk
        ssd = (2 * Q * N                      # chunk scores (shared heads)
               + 2 * Q * H * P                # intra apply
               + 2 * 2 * H * P * N)           # state build + inter readout
    return proj + conv + ssd


def _rglru_flops_per_token(cfg: ModelConfig) -> float:
    D = cfg.d_model
    W = cfg.rglru_width or D
    branches = 2 * 2 * D * W                  # rnn_in + gate_in
    gates = 2 * 2 * W * W                     # w_a, w_x
    conv = 2 * cfg.ssm_conv * W
    scan = 8 * W
    out = 2 * W * D
    return branches + gates + conv + scan + out


@dataclass
class Estimate:
    forward_flops: float          # global, one forward pass
    total_flops: float            # global, the lowered program (train=3x fwd)
    model_flops: float            # 6 N D (active params for MoE)
    hbm_bytes_per_device: float   # dominant HBM traffic, per device, per step
    tokens: int


def estimate(cfg: ModelConfig, shape: InputShape, *, n_devices: int = 256,
             model_shards: int = 16, moe_impl: str = "einsum") -> Estimate:
    decode = shape.kind == "decode"
    S = 1 if decode else shape.seq_len
    B = shape.global_batch
    if cfg.family == "vlm" and not decode:
        S = S + cfg.n_image_tokens
    tokens = B * S
    kv_len = (float(min(shape.seq_len, cfg.sliding_window or shape.seq_len))
              if decode else _avg_kv(S, cfg.sliding_window))

    per_tok = 0.0
    L = cfg.n_layers
    if cfg.family in ("dense", "moe", "vlm"):
        ffn = (_moe_flops_per_token(cfg, min(MOE_GROUP, tokens), moe_impl)
               if cfg.n_experts else _mlp_flops_per_token(cfg, cfg.d_ff))
        per_tok = L * (_attn_flops_per_token(cfg, kv_len) + ffn)
    elif cfg.family == "ssm":
        per_tok = L * _ssm_flops_per_token(cfg, decode)
    elif cfg.family == "hybrid":
        period = cfg.hybrid_period
        n_attn = L // period
        n_rec = L - n_attn
        w_kv = (float(min(shape.seq_len, cfg.sliding_window))
                if decode else _avg_kv(S, cfg.sliding_window))
        per_tok = (n_attn * (_attn_flops_per_token(cfg, w_kv)
                             + _mlp_flops_per_token(cfg, cfg.d_ff))
                   + n_rec * (_rglru_flops_per_token(cfg)
                              + _mlp_flops_per_token(cfg, cfg.d_ff)))
    elif cfg.family == "audio":
        Te = cfg.encoder_seq
        enc_tokens = B * Te
        enc_per_tok = cfg.n_encoder_layers * (
            _attn_flops_per_token(cfg, Te) + _mlp_flops_per_token(cfg, cfg.d_ff))
        dec_self_kv = float(shape.seq_len) if decode else _avg_kv(S, None)
        dec_per_tok = L * (_attn_flops_per_token(cfg, dec_self_kv)
                           + _attn_flops_per_token(cfg, Te)   # cross-attn
                           + _mlp_flops_per_token(cfg, cfg.d_ff))
        enc_total = 0.0 if decode else enc_tokens * enc_per_tok
        fwd = enc_total + tokens * (dec_per_tok + 2 * cfg.d_model * cfg.vocab_size)
        return _finish(cfg, shape, fwd, tokens, n_devices, model_shards)

    unembed = 2 * cfg.d_model * cfg.vocab_size
    fwd = tokens * (per_tok + unembed)
    return _finish(cfg, shape, fwd, tokens, n_devices, model_shards)


def _finish(cfg: ModelConfig, shape: InputShape, fwd: float, tokens: int,
            n_devices: int, model_shards: int) -> Estimate:
    train = shape.kind == "train"
    total = fwd * 3.0 if train else fwd
    n_active = cfg.param_count(active_only=True)
    model_flops = (6 if train else 2) * n_active * tokens

    # HBM traffic per device (napkin; coefficients documented in §Roofline)
    p_bytes = cfg.param_count() * 2.0
    if train:
        # fwd read + bwd read of (model-sharded) params + local opt update
        param_traffic = 2 * (p_bytes / model_shards) * 2 \
            + (p_bytes / n_devices) * 12
        act_traffic = tokens / n_devices * cfg.d_model * cfg.n_layers * 2 * 8 * 3
    else:
        param_traffic = p_bytes / model_shards
        act_traffic = tokens / n_devices * cfg.d_model * cfg.n_layers * 2 * 8
    cache_traffic = 0.0
    if shape.kind == "decode":
        cache_traffic = _cache_bytes(cfg, shape) / n_devices
    hbm = param_traffic + act_traffic + cache_traffic
    return Estimate(forward_flops=fwd, total_flops=total,
                    model_flops=model_flops, hbm_bytes_per_device=hbm,
                    tokens=tokens)


def _cache_bytes(cfg: ModelConfig, shape: InputShape) -> float:
    B = shape.global_batch
    S = min(shape.seq_len, cfg.sliding_window or shape.seq_len)
    if cfg.family == "ssm":
        st = cfg.n_layers * B * (cfg.n_ssm_heads * cfg.ssm_head_dim
                                 * cfg.ssm_state * 4
                                 + (cfg.ssm_conv - 1)
                                 * (cfg.d_inner + 2 * cfg.ssm_state) * 2)
        return float(st)
    if cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.hybrid_period
        n_rec = cfg.n_layers - n_attn
        W = cfg.rglru_width or cfg.d_model
        kv = n_attn * B * min(shape.seq_len, cfg.sliding_window) \
            * cfg.n_kv_heads * cfg.head_dim_ * 2 * 2
        st = n_rec * B * W * (4 + (cfg.ssm_conv - 1) * 2)
        return float(kv + st)
    kv = cfg.n_layers * B * S * cfg.n_kv_heads * cfg.head_dim_ * 2 * 2
    if cfg.family == "audio":
        kv += cfg.n_layers * B * cfg.encoder_seq * cfg.n_kv_heads \
            * cfg.head_dim_ * 2 * 2
    return float(kv)


def roofline_terms(est: Estimate, coll_bytes_per_device: float, *,
                   n_devices: int = 256,
                   peak_flops: float = 197e12, hbm_bw: float = 819e9,
                   ici_bw: float = 50e9) -> Dict[str, float]:
    compute_s = est.total_flops / (n_devices * peak_flops)
    memory_s = est.hbm_bytes_per_device / hbm_bw
    collective_s = coll_bytes_per_device / ici_bw
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    terms["model_flops_ratio"] = (est.model_flops / est.total_flops
                                  if est.total_flops else 0.0)
    return terms
