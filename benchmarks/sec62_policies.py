"""§6.2 — Adding new policies: SRTF (minimize JCT) and LPT (control
makespan), each ~12 lines against the policy interface.

Paper claims: SRTF reduces average JCT by ~2.4% (P95 +3.3%); LPT reduces
makespan by ~5.8% (P95 +2.6%).  Gains are modest by design — the point is
that operators can express them in a dozen lines (we assert the line count
of the policy classes too).
"""

from __future__ import annotations

import inspect
import statistics
from typing import Dict, List

from repro.core import (LPTPolicy, PolicyChain, SRTFPolicy,
                        LoadBalancePolicy)
from repro.workloads import run_financial, run_swe, system_config
from repro.workloads.baselines import NullPolicy, SystemConfig


def _cfg(policy, name: str) -> SystemConfig:
    return SystemConfig(name=name, policy=policy, sticky_sessions=False,
                        dynamic_resources=True, control_interval=0.25)


def _avg(runs: List[Dict], keys) -> Dict:
    return {k: statistics.mean(r[k] for r in runs) for k in keys}


def run(quick: bool = True) -> List[Dict]:
    rows = []
    n_sessions = 30 if quick else 60
    seeds = list(range(23, 31)) if quick else list(range(23, 35))
    # SRTF vs FCFS on the call-graph (financial) workload
    for name, policy in (("fcfs", PolicyChain(LoadBalancePolicy())),
                         ("srtf", PolicyChain(LoadBalancePolicy(),
                                              SRTFPolicy()))):
        runs = [run_financial(_cfg(policy, name), rps=2.0,
                              n_sessions=n_sessions, seed=s) for s in seeds]
        rows.append({"bench": "sec62_srtf", "policy": name,
                     **_avg(runs, ("avg", "p95", "p99"))})

    # LPT vs FCFS on the recursive (SWE) workload
    n_requests = 8 if quick else 16
    for name, policy in (("fcfs", PolicyChain(LoadBalancePolicy())),
                         ("lpt", PolicyChain(LoadBalancePolicy(),
                                             LPTPolicy()))):
        runs = [run_swe(_cfg(policy, name), n_requests=n_requests, seed=s)
                for s in seeds]
        rows.append({"bench": "sec62_lpt", "policy": name,
                     **_avg(runs, ("avg", "p95", "p99", "makespan"))})
    return rows


def derive(rows: List[Dict]) -> List[str]:
    out = []
    srtf = {r["policy"]: r for r in rows if r["bench"] == "sec62_srtf"}
    jct = 100 * (1 - srtf["srtf"]["avg"] / srtf["fcfs"]["avg"])
    out.append(f"sec62,srtf,avg_jct_improvement_pct,{jct:.1f}")
    lpt = {r["policy"]: r for r in rows if r["bench"] == "sec62_lpt"}
    mk = 100 * (1 - lpt["lpt"]["makespan"] / lpt["fcfs"]["makespan"])
    out.append(f"sec62,lpt,makespan_improvement_pct,{mk:.1f}")
    # expressiveness: both policies fit in <=15 lines of code
    for cls, name in ((SRTFPolicy, "srtf"), (LPTPolicy, "lpt")):
        n_lines = len(inspect.getsource(cls).strip().splitlines())
        out.append(f"sec62,{name},policy_loc,{n_lines}")
    return out
