"""§Roofline — three-term roofline per (arch x shape) from the dry-run.

Reads the per-combo JSON records produced by ``repro.launch.dryrun``
(collective bytes parsed from the post-SPMD HLO, loop-trip-corrected) and
combines them with the analytic compute/memory estimator.  Emits the table
EXPERIMENTS.md §Roofline embeds.

    PYTHONPATH=src python -m benchmarks.roofline [--results DIR] [--md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import canonical, get_config, get_shape  # noqa: E402
from repro.launch.dryrun import effective_config  # noqa: E402

from . import analytic  # noqa: E402


def load_records(results_dir: str, mesh_tag: str = "singlepod",
                 suffix: str = "") -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(
            results_dir, f"dryrun_*_{mesh_tag}{suffix}.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def analyse(rec: Dict) -> Optional[Dict]:
    if "skipped" in rec or "error" in rec:
        return None
    shape = get_shape(rec["shape"])
    cfg, _note = effective_config(rec["arch"], shape)
    n_dev = rec.get("n_devices", 256)
    model_shards = 16
    est = analytic.estimate(cfg, shape, n_devices=n_dev,
                            model_shards=model_shards,
                            moe_impl=rec.get("moe_impl") or "einsum")
    coll = rec["collective_bytes_per_device"]["total"]
    terms = analytic.roofline_terms(est, coll, n_devices=n_dev)
    # cross-check: raw XLA flops x outer loop trips vs analytic
    trips = rec.get("loop_trip_counts", [])
    raw = rec.get("flops_per_device_raw", 0.0) * n_dev
    raw_scaled = raw * (trips[0] if trips else 1)
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": "x".join(str(d) for d in rec["mesh"]),
        **{k: terms[k] for k in ("compute_s", "memory_s", "collective_s",
                                 "bottleneck", "model_flops_ratio")},
        "total_flops": est.total_flops,
        "model_flops": est.model_flops,
        "hbm_bytes_dev": est.hbm_bytes_per_device,
        "coll_bytes_dev": coll,
        "xla_raw_flops_scaled": raw_scaled,
        "xla_vs_analytic": raw_scaled / est.total_flops if est.total_flops else 0,
        "note": rec.get("note", ""),
    }


def table(rows: List[Dict], md: bool = False) -> str:
    cols = ["arch", "shape", "bottleneck", "compute_s", "memory_s",
            "collective_s", "model_flops_ratio", "xla_vs_analytic", "note"]
    lines = []
    if md:
        lines.append("| " + " | ".join(cols) + " |")
        lines.append("|" + "---|" * len(cols))
    for r in rows:
        vals = []
        for c in cols:
            v = r[c]
            vals.append(f"{v:.3e}" if isinstance(v, float) and "ratio" not in c
                        and "vs" not in c else
                        (f"{v:.3f}" if isinstance(v, float) else str(v)))
        lines.append(("| " + " | ".join(vals) + " |") if md
                     else ",".join(vals))
    return "\n".join(lines)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--results", default="benchmarks/results")
    p.add_argument("--mesh", default="singlepod")
    p.add_argument("--suffix", default="")
    p.add_argument("--md", action="store_true")
    args = p.parse_args()
    rows = [a for a in (analyse(r) for r in load_records(
        args.results, args.mesh, args.suffix)) if a is not None]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    if not args.md:
        print("arch,shape,bottleneck,compute_s,memory_s,collective_s,"
              "model_flops_ratio,xla_vs_analytic,note")
    print(table(rows, md=args.md))


if __name__ == "__main__":
    main()
