"""Straggler-hedging benchmark — deadline propagation + hedged dispatch
against an injected slow replica (fig. 9-style tail experiment).

The fault: one of four replicas of an ``llm`` agent pool runs its steps
**10x slower** (``repro.serving.chaos.slow_instance`` — the SimKernel-
deterministic straggler injection).  Least-ETA routing avoids the
straggler once its slowness is *observed*, but every request that lands
on it before then is trapped for the full degraded service time — that
is the tail the paper's hedging policy exists to cut.

Three configurations, identical workload and seed:

* ``hedge_off``  — slack deadlines, no HedgePolicy: trapped requests run
  the straggler to completion; p99 is the straggler's service time.
* ``hedge_on``   — slack deadlines + ``HedgePolicy``: once a future has
  been running ~2x the pool's typical service time, the global
  controller dispatches a duplicate to a below-watermark sibling;
  first completion wins, so trapped requests resolve at roughly
  (hedge delay + sibling service).  The policy's budget caps extra
  dispatches at ~10%.
* ``tight_deadline`` — no hedging, per-request deadlines shorter than
  the straggler's service time: trapped requests fail
  ``DeadlineExceeded`` (launch-time expiry for queued work; late
  completion otherwise) instead of silently blowing the tail, and the
  ``expired`` counter reaches the global controller's ``InstanceView``.

Deterministic (SimKernel + fixed seed), so the claim check is exact:

    PYTHONPATH=src python benchmarks/straggler_hedging.py            # table
    PYTHONPATH=src python benchmarks/straggler_hedging.py --smoke    # CI
    PYTHONPATH=src python -m benchmarks.run --only straggler_hedging
"""

from __future__ import annotations

import json
import os
import random
import sys
from typing import Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (AgentSpec, Directives, FixedLatency,  # noqa: E402
                        HedgePolicy, NalarRuntime, emulated)
from repro.core.policy import default_policies  # noqa: E402
from repro.core.runtime import current_runtime  # noqa: E402
from repro.serving.chaos import slow_instance  # noqa: E402

SERVICE_S = 0.25        # healthy per-call service time
STRAGGLER_FACTOR = 10.0  # the injected fault: one replica 10x slower
REPLICAS = 4


def _driver(query: str) -> str:
    rt = current_runtime()
    return rt.stub("llm").generate(
        query, _hint={"est_service": SERVICE_S}).value()


def run_straggler(hedging: bool, *, deadline_s: Optional[float] = 20.0,
                  requests: int = 48, window: float = 6.0,
                  seed: int = 11) -> Dict[str, float]:
    policies = default_policies()
    if hedging:
        policies.policies.append(HedgePolicy(
            factor=2.0, min_delay=2.0 * SERVICE_S, budget_frac=0.10,
            agent_types=("llm",)))
    rt = NalarRuntime(
        simulate=True,
        nodes={f"n{i}": {"GPU": 4} for i in range(REPLICAS)},
        policy=policies, control_interval=0.25, seed=seed)
    rt.router.mode = "least_eta"
    rt.register_agent(AgentSpec(
        name="llm",
        methods={"generate": emulated(FixedLatency(SERVICE_S),
                                      lambda q, **kw: f"gen({q})")},
        directives=Directives(max_instances=REPLICAS, min_instances=1,
                              resources={"GPU": 1})),
        instances=REPLICAS)
    victim = rt.instances_of_type("llm")[0]
    slow_instance(rt, victim, factor=STRAGGLER_FACTOR)

    rng = random.Random(seed)
    rt.start()
    t = 0.0
    for i in range(requests):
        t += rng.expovariate(requests / window)
        rt.submit_request(_driver, f"q{i}", delay=t, deadline_s=deadline_s)
    rt.run(max_time=window + 60.0)

    summary = rt.telemetry.summary()
    dl = rt.telemetry.deadline_outcomes()
    view = rt.global_controller.collect_view(full=True)
    view_expired = sum(iv.expired + iv.engine_expired
                      for iv in view.instances.values())
    inst_expired = sum(i.metrics.expired for i in rt._instances.values())
    recs = list(rt.telemetry.requests.values())
    completed = sum(1 for r in recs if r.finished_at >= 0 and not r.failed)
    out = {
        "bench": "straggler_hedging",
        "system": ("hedge_on" if hedging else
                   "hedge_off" if deadline_s is None or deadline_s > 5
                   else "tight_deadline"),
        "requests": len(recs),
        "completed": completed,
        "deadline_s": deadline_s if deadline_s is not None else -1.0,
        "deadline_missed": dl["deadline_missed"],
        "unfinished": dl["unfinished"],
        "p50_s": summary.get("p50", float("nan")),
        "p99_s": summary.get("p99", float("nan")),
        "max_s": summary.get("max", float("nan")),
        "hedges": rt.hedges_issued,
        "hedge_overhead": rt.hedges_issued / max(1, len(recs)),
        "expired": inst_expired,
        "expired_in_view": view_expired,
    }
    rt.shutdown()
    return out


def run(quick: bool = True) -> List[Dict]:
    n = 48 if quick else 192
    w = 6.0 if quick else 24.0
    return [
        run_straggler(False, requests=n, window=w),
        run_straggler(True, requests=n, window=w),
        # tight deadlines under a burst: arrivals compressed 4x so queue
        # wait alone blows the 1 s budget — exercises launch-time expiry
        # (controller drops queued work whose deadline already passed)
        # on top of trapped-on-straggler late completions
        run_straggler(False, deadline_s=1.0, requests=n, window=w / 4),
    ]


def derive(rows: List[Dict]) -> List[str]:
    by = {r["system"]: r for r in rows}
    out = []
    for mode, r in by.items():
        out.append(f"straggler,{mode},p99_s,{r['p99_s']:.3f}")
        out.append(f"straggler,{mode},hedge_overhead,"
                   f"{r['hedge_overhead']:.3f}")
        out.append(f"straggler,{mode},deadline_missed,"
                   f"{r['deadline_missed']}")
    on, off = by.get("hedge_on"), by.get("hedge_off")
    tight = by.get("tight_deadline")
    if on and off:
        ratio = off["p99_s"] / max(1e-9, on["p99_s"])
        out.append(f"straggler,claim,p99_cut_x,{ratio:.2f}")
        out.append(f"straggler,claim,p99_cut_ge_2x,{int(ratio >= 2.0)}")
        out.append(f"straggler,claim,overhead_le_10pct,"
                   f"{int(on['hedge_overhead'] <= 0.10)}")
        out.append(f"straggler,claim,no_misses_at_slack_deadlines,"
                   f"{int(on['deadline_missed'] == 0 and off['deadline_missed'] == 0)}")
    if tight:
        out.append(f"straggler,claim,tight_deadlines_enforced,"
                   f"{int(tight['deadline_missed'] > 0)}")
        out.append(f"straggler,claim,expired_visible_in_view,"
                   f"{int(tight['expired_in_view'] == tight['expired'])}")
    return out


def write_record(rows: List[Dict], mode: str) -> None:
    by = {r["system"]: r for r in rows}
    on, off = by["hedge_on"], by["hedge_off"]
    payload = {
        "bench": "straggler_hedging",
        "mode": mode,
        "straggler_factor": STRAGGLER_FACTOR,
        "p99_off_s": round(off["p99_s"], 4),
        "p99_on_s": round(on["p99_s"], 4),
        "p99_cut_x": round(off["p99_s"] / max(1e-9, on["p99_s"]), 2),
        "hedge_overhead": round(on["hedge_overhead"], 4),
        "deadline_missed_at_slack": on["deadline_missed"]
        + off["deadline_missed"],
        "derived": derive(rows),
        "rows": rows,
    }
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_straggler.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=str)
        f.write("\n")


def main() -> None:
    smoke = "--smoke" in sys.argv
    rows = run(quick=True)
    for row in rows:
        print(row)
    for line in derive(rows):
        print(line)
    if not smoke:
        write_record(rows, "quick")
        return
    by = {r["system"]: r for r in rows}
    on, off, tight = (by["hedge_on"], by["hedge_off"],
                      by["tight_deadline"])
    assert off["p99_s"] > on["p99_s"], \
        "hedging must cut p99 under an injected straggler"
    assert off["p99_s"] / on["p99_s"] >= 2.0, \
        f"p99 cut {off['p99_s'] / on['p99_s']:.2f}x < 2x"
    assert on["hedge_overhead"] <= 0.10, \
        f"hedge overhead {on['hedge_overhead']:.3f} > 10%"
    assert on["hedges"] >= 1, "hedging on must actually hedge"
    assert on["deadline_missed"] == 0 and off["deadline_missed"] == 0, \
        "slack deadlines must not be missed"
    assert tight["deadline_missed"] > 0, \
        "tight deadlines must be enforced against the straggler"
    assert tight["expired"] > 0, \
        "burst + tight deadlines must trigger launch-time expiry"
    assert tight["expired_in_view"] == tight["expired"], \
        "expired counters must reach the global controller's view"
    print(f"straggler_hedging --smoke: OK "
          f"(p99 off={off['p99_s']:.2f}s on={on['p99_s']:.2f}s, "
          f"{on['hedges']} hedges, "
          f"overhead={on['hedge_overhead']:.1%})")


if __name__ == "__main__":
    main()
