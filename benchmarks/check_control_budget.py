"""CI guard: the control loop must stay delta-shaped.

Reads ``BENCH_control_loop.json`` (written by ``benchmarks.run`` whenever
fig10 runs) and fails if, at the 32,768-future point:

* mean steady-state collect time exceeds ``BUDGET_MS`` — a hard ceiling a
  full O(N) mirror scan cannot meet, or
* collect time exceeds policy time — the paper's §6.3 finding (and this
  repo's regression canary): with incremental collection the loop spends
  its compute in policy logic, so collect > policy means someone
  re-introduced a full scan into the collect path.

Usage (after ``python -m benchmarks.run --only fig10``)::

    python benchmarks/check_control_budget.py [path/to/BENCH_control_loop.json]
"""

from __future__ import annotations

import json
import os
import sys

#: steady-state collect budget at 32K futures, quick mode.  Generous for CI
#: jitter (measured ~4-8 ms locally); a full scan costs ~10-20x more.
BUDGET_MS = 100.0
CHECK_FUTURES = 32768
#: slack on the collect<=policy comparison for CI timer noise
POLICY_SLACK = 1.25


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(__file__), "..", "BENCH_control_loop.json")
    with open(path) as f:
        data = json.load(f)
    rows = [r for r in data["rows"] if r["futures"] == CHECK_FUTURES]
    if not rows:
        print(f"FAIL: no {CHECK_FUTURES}-future rows in {path}")
        return 1
    failed = False
    for r in rows:
        tag = f"{r['futures']} futures / {r['nodes']} nodes"
        collect, policy = r["collect_ms"], r["policy_ms"]
        print(f"{tag}: collect {collect:.2f} ms, policy {policy:.2f} ms, "
              f"cold {r['cold_collect_ms']:.2f} ms "
              f"({r['n_collected']:.0f} entries/round)")
        if collect > BUDGET_MS:
            print(f"  FAIL: collect {collect:.2f} ms > budget {BUDGET_MS} ms")
            failed = True
        if collect > policy * POLICY_SLACK:
            print(f"  FAIL: collect {collect:.2f} ms > policy {policy:.2f} ms"
                  f" x{POLICY_SLACK} — did a full scan sneak back into"
                  " collect?")
            failed = True
    print("control-loop budget:", "FAIL" if failed else "OK")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
