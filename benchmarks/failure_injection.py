"""Failure-injection benchmark — replica death mid-run, retries on vs off.

The scenario the fault-tolerance subsystem exists for: a pool of worker
replicas serves session workflows (3 sequential calls per request) under
overload, and one replica is *hard-killed* at t = 50% of the arrival window
(``runtime.kill_instance(..., hard=True)`` — the fault-injection API).  The
dead replica's queued work re-routes, but its **in-flight** futures are lost:

* ``retries_off`` (``max_retries=0``, the pre-subsystem behaviour): every
  in-flight future fails with ``InstanceDied`` and its session's request is
  gone — goodput drops below 100%.
* ``retries_on`` (``max_retries=2``): the failure escalates to the global
  controller, whose ``RetryPolicy`` blacklists the dead replica and reroutes
  each future to a surviving one — goodput stays at 100%, at the cost of a
  modest p95 penalty for the retried tail.

Deterministic (SimKernel + fixed seed), so the claim check is exact:

    PYTHONPATH=src python benchmarks/failure_injection.py            # table
    PYTHONPATH=src python benchmarks/failure_injection.py --smoke    # CI
    PYTHONPATH=src python -m benchmarks.run --only failure_injection
"""

from __future__ import annotations

import os
import random
import sys
from typing import Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (AgentSpec, Directives, FixedLatency,  # noqa: E402
                        NalarRuntime, emulated)

SERVICE_S = 0.25        # per-call service time
TURNS = 3               # sequential calls per request
REPLICAS = 3


def run_failure_injection(retries_on: bool, *, sessions: int = 24,
                          arrival_window: float = 4.0,
                          seed: int = 7) -> Dict[str, float]:
    rt = NalarRuntime(
        simulate=True,
        nodes={f"n{i}": {"CPU": 16} for i in range(REPLICAS)},
        control_interval=0.5, seed=seed)
    rt.register_agent(AgentSpec(
        name="worker",
        methods={"step": emulated(FixedLatency(SERVICE_S),
                                  lambda x: x + 1)},
        directives=Directives(
            max_instances=REPLICAS, min_instances=1,
            max_retries=2 if retries_on else 0,
            retry_backoff=0.05,
            resources={"CPU": 1})),
        instances=REPLICAS)
    victim = rt.instances_of_type("worker")[0]

    def request_driver(x: int):
        v = x
        for _ in range(TURNS):
            v = rt.stub("worker").step(v).value()
        return v

    rng = random.Random(seed)
    t = 0.0
    rt.start()
    for i in range(sessions):
        t = arrival_window * (i + rng.random()) / sessions
        rt.submit_request(request_driver, i, delay=t)
    # the fault: one replica dies mid-run with work queued AND in flight
    t_kill = arrival_window * 0.5
    rt.kernel.schedule(t_kill, lambda: rt.kill_instance(victim, hard=True),
                       tag="fault-injection")
    rt.run()

    summary = rt.telemetry.summary()
    recs = list(rt.telemetry.requests.values())
    completed = sum(1 for r in recs if r.finished_at >= 0 and not r.failed)
    failed = sum(1 for r in recs if r.failed)
    retries = sum(i.metrics.retries for i in rt._instances.values())
    out = {
        "bench": "failure_injection",
        "system": "retries_on" if retries_on else "retries_off",
        "requests": len(recs),
        "completed": completed,
        "failed": failed,
        "goodput": completed / max(1, len(recs)),
        "p50_s": summary.get("p50", float("nan")),
        "p95_s": summary.get("p95", float("nan")),
        "retries": retries,
        "blacklisted": len(rt.blacklist),
    }
    rt.shutdown()
    return out


def run(quick: bool = True) -> List[Dict]:
    n = 24 if quick else 96
    return [run_failure_injection(False, sessions=n),
            run_failure_injection(True, sessions=n)]


def derive(rows: List[Dict]) -> List[str]:
    by = {r["system"]: r for r in rows}
    out = []
    for mode, r in by.items():
        out.append(f"failure,{mode},goodput,{r['goodput']:.3f}")
        out.append(f"failure,{mode},p95_s,{r['p95_s']:.3f}")
    on, off = by.get("retries_on"), by.get("retries_off")
    if on and off:
        out.append(f"failure,claim,retries_on_completes_all,"
                   f"{int(on['goodput'] == 1.0)}")
        out.append(f"failure,claim,retries_off_loses_inflight,"
                   f"{int(off['goodput'] < 1.0)}")
        out.append(f"failure,claim,dead_replica_blacklisted,"
                   f"{int(on['blacklisted'] >= 1)}")
    return out


def main() -> None:
    smoke = "--smoke" in sys.argv
    rows = run(quick=True)
    for row in rows:
        print(row)
    for line in derive(rows):
        print(line)
    if smoke:
        by = {r["system"]: r for r in rows}
        assert by["retries_on"]["goodput"] == 1.0, \
            "retries-on must complete 100% of requests across the kill"
        assert by["retries_off"]["goodput"] < 1.0, \
            "retries-off must lose the in-flight sessions"
        assert by["retries_on"]["retries"] >= 1
        assert by["retries_on"]["blacklisted"] >= 1
        print("failure_injection --smoke: OK "
              f"(on={by['retries_on']['goodput']:.2f}, "
              f"off={by['retries_off']['goodput']:.2f})")


if __name__ == "__main__":
    main()
