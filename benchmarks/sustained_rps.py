"""Open-loop sustained-load benchmark — real ``EnginePool``, wall-clock.

The abstract's headline serving claim is that NALAR "sustains 80 RPS where
baselines fail": the baseline failure mode is a data plane that (a) runs
monolithic full-prompt prefill, stalling every active decode slot for the
whole prefill, and (b) accepts unbounded queue growth, so past saturation
every request waits behind a growing queue until it times out.  This
benchmark drives a real two-replica ``EnginePool`` with open-loop Poisson
arrivals (arrivals never wait for completions — the honest way to measure
collapse) and measures both remedies separately:

* **prefill experiment** — mixed long-prompt/decode load at a fixed arrival
  rate, chunked prefill (``prefill_chunk`` tokens per step, piggybacked on
  the batched decode) vs the legacy monolithic bucket prefill.  The claim
  checked: chunked prefill strictly improves p99 TTFT — a long prompt no
  longer freezes the batch for its full prefill, so the tail (short
  requests that arrive during a long admission) collapses.

* **admission experiment** — a stepped arrival-rate ladder over a bounded
  (``max_queue`` + retry ladder + router shedding) vs unbounded admission
  config.  Goodput is completed-in-deadline requests per second of wall
  clock.  The claims checked: bounded admission still sustains goodput at
  (and beyond) the offered rate where the unbounded baseline collapses,
  and the unbounded collapse point is recorded.

* **prefix-sharing experiment** (``--prefix``) — a fleet of single-turn
  sessions that all open with the same system prompt, run with the
  cross-session KV prefix index on vs off.  The claims checked: prefill
  tokens drop by >= 50% (each replica pays the shared preamble once, every
  later admission prefills only its unique user suffix) and the generated
  outputs are identical token-for-token — sharing is an optimization, not
  an approximation.  Writes ``BENCH_prefix_sharing.json``.

Numbers are wall-clock on reduced CPU models, so the absolute RPS is far
below the paper's A100 figures — the *shape* (stall-free TTFT tail, and
goodput that saturates instead of collapsing) is the reproduced claim.

    PYTHONPATH=src python -m benchmarks.sustained_rps            # quick
    PYTHONPATH=src python benchmarks/sustained_rps.py --smoke    # CI budget
    PYTHONPATH=src python -m benchmarks.run --only sustained_rps
"""

from __future__ import annotations

import argparse
import json
import os
import random
import sys
import time
from typing import Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.workloads.router import build_pool_runtime  # noqa: E402


def _pct(sorted_vals: List[float], p: float) -> float:
    if not sorted_vals:
        return -1.0
    idx = min(len(sorted_vals) - 1,
              int(round(p / 100.0 * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


def _warm_compile(pool, *, long_words: int, max_seq: int) -> None:
    """Compile each replica's chunk/decode/prefill shapes up front so JIT
    time never pollutes the latency comparison."""
    from repro.serving import SamplingParams
    for iid in pool.instance_ids:
        engine = pool.bridge_of(iid).engine
        for n in (8, long_words):
            sid = f"warmup:{iid}:{n}"
            engine.generate(list(range(1, n + 1)), session_id=sid,
                            sampling=SamplingParams(max_new_tokens=2))
            engine.pool.release(sid)
            if engine.kv_registry is not None:
                engine.kv_registry.release(sid)


def run_condition(*, system: str, prefill_chunk: int, max_queue: int,
                  max_retries: int, rps: float, duration: float,
                  long_frac: float = 0.0, long_words: int = 840,
                  short_words: int = 8, out_short: int = 8, out_long: int = 3,
                  max_seq: int = 1024, replicas: int = 2, max_batch: int = 4,
                  timeout_s: float = 10.0, seed: int = 0) -> Dict:
    """One open-loop run; returns goodput + TTFT/E2E distributions."""
    records: List[Dict[str, float]] = []    # engine-side per-request stamps

    def decode(req):
        records.append({
            "ttft": req.first_token_at - req.submitted_wall,
            "engine_e2e": req.finished_at - req.submitted_wall,
            "prompt": int(len(req.prompt)),
            "generated": len(req.generated),
        })
        return len(req.generated)

    rt = build_pool_runtime(
        replicas=replicas, max_batch=max_batch, max_seq=max_seq,
        prefill_chunk=prefill_chunk, max_queue=max_queue,
        max_retries=max_retries, retry_backoff=0.02,
        control_interval=0.25, decode=decode, seed=seed)
    pool = rt.engine_backends["llm"]
    _warm_compile(pool, long_words=long_words, max_seq=max_seq)
    n_warm = len(records)

    rng = random.Random(seed)
    word_rng = random.Random(seed + 1)

    def mk_words(n: int) -> str:
        return " ".join(f"w{word_rng.randrange(10_000)}" for _ in range(n))

    plan = []                               # (arrival_t, words, out_tokens)
    t, k = 0.0, 0
    # deterministic long placement (every round(1/long_frac)-th arrival):
    # the comparison needs the same long/short interleave in every system
    long_every = max(1, round(1 / long_frac)) if long_frac > 0 else 0
    while t < duration:
        t += rng.expovariate(rps)
        if long_every and k % long_every == long_every // 2:
            plan.append((t, mk_words(long_words), out_long))
            # interference probe: an interactive request landing right
            # after every long admission.  This is the structural collision
            # the TTFT comparison measures — a monolithic prefill stalls
            # the probe for the whole prompt, chunked admits it next step.
            plan.append((t + 0.03, mk_words(short_words), out_short))
        else:
            plan.append((t, mk_words(short_words), out_short))
        k += 1
    plan.sort(key=lambda p: p[0])

    ok: List[str] = []
    timeouts: List[str] = []
    rejected: List[str] = []

    def turn_driver(words: str, out_tok: int):
        from repro.core.runtime import current_runtime
        rt_ = current_runtime()
        fut = rt_.stub("llm").generate(words, _hint={"out_tokens": out_tok})
        try:
            return fut.value(timeout=timeout_s)
        except BaseException:
            # deadline/shed: renounce the value so queued work is reclaimed
            rt_.cancel_future(fut)
            raise

    def on_done(out, err):
        if err is None:
            ok.append("ok")
        elif isinstance(err, TimeoutError):
            timeouts.append("t")
        else:
            rejected.append(type(err).__name__)

    t_begin = time.monotonic()
    rt.start()
    for arrival, words, out in plan:
        rt.submit_request(turn_driver, words, out, delay=arrival,
                          on_done=on_done)
    time.sleep(plan[-1][0] + 0.3)           # let every arrival timer fire
    rt.run()
    elapsed = time.monotonic() - t_begin

    records = records[n_warm:]
    ttft = sorted(r["ttft"] for r in records if r["ttft"] >= 0)
    # class split: the chunked-prefill claim is about the *interactive*
    # (decode-heavy) class — the requests a monolithic prefill stalls.  The
    # long class pays its own prefill either way (and pays more when it is
    # chunked); both classes are recorded.
    cut = max(short_words * 4, 32)
    ttft_short = sorted(r["ttft"] for r in records
                        if r["ttft"] >= 0 and r["prompt"] <= cut)
    ttft_long = sorted(r["ttft"] for r in records
                       if r["ttft"] >= 0 and r["prompt"] > cut)
    tel = dict(rt.telemetry.summary())
    pool_tel = pool.telemetry()
    row = {
        "bench": "sustained_rps",
        "system": system,
        "rps": rps,
        "offered": len(plan) / duration,
        "n": len(plan),
        "completed": len(ok),
        "timeouts": len(timeouts),
        "rejected_failures": len(rejected),
        "goodput_rps": len(ok) / max(elapsed, 1e-9),
        "elapsed_s": elapsed,
        "ttft_p50": _pct(ttft, 50), "ttft_p99": _pct(ttft, 99),
        "ttft_short_p50": _pct(ttft_short, 50),
        "ttft_short_p99": _pct(ttft_short, 99),
        "ttft_long_p50": _pct(ttft_long, 50),
        "ttft_long_p99": _pct(ttft_long, 99),
        "e2e_p50": tel.get("p50", -1), "e2e_p95": tel.get("p95", -1),
        "e2e_p99": tel.get("p99", -1),
        "admission_rejects": sum(
            r.get("admission_rejects", 0)
            for r in pool_tel["replicas"].values()),
        "prefill_chunk": prefill_chunk,
        "max_queue": max_queue,
    }
    rt.shutdown()
    return row


def _prefix_condition(*, prefix_sharing: bool, n_requests: int,
                      sys_words: int, user_words: int, replicas: int,
                      max_seq: int, seed: int) -> Dict:
    """Closed-loop run of ``n_requests`` single-turn sessions sharing one
    system prompt; returns prefill-token cost, hit stats, TTFT, and the
    per-session generated tokens (the equivalence evidence)."""
    records: List[Dict] = []

    def decode(req):
        records.append({
            "sid": req.session_id,
            "generated": [int(t) for t in req.generated],
            "ttft": req.first_token_at - req.submitted_wall,
        })
        return len(req.generated)

    rt = build_pool_runtime(
        replicas=replicas, max_batch=2, max_seq=max_seq,
        prefill_chunk=64, max_queue=0, max_retries=0,
        prefix_sharing=prefix_sharing, decode=decode, seed=seed)
    pool = rt.engine_backends["llm"]
    engines = [pool.bridge_of(i).engine for i in pool.instance_ids]
    _warm_compile(pool, long_words=sys_words + user_words, max_seq=max_seq)

    word_rng = random.Random(seed + 1)
    sys_prompt = " ".join(f"s{word_rng.randrange(10_000)}"
                          for _ in range(sys_words))
    prompts = [(f"user:{i}",
                sys_prompt + " " + " ".join(f"u{i}w{j}"
                                            for j in range(user_words)))
               for i in range(n_requests)]

    pt0 = sum(e.metrics.prefill_tokens for e in engines)

    def turn(text: str):
        from repro.core.runtime import current_runtime
        return current_runtime().stub("llm").generate(text).value(timeout=120)

    from repro.core import deployment
    t0 = time.monotonic()
    for sid, text in prompts:
        deployment.main(turn, text, runtime=rt, session=sid)
    elapsed = time.monotonic() - t0

    prefill_tokens = sum(e.metrics.prefill_tokens for e in engines) - pt0
    hits = sum(e.metrics.shared_prefix_hits for e in engines)
    hit_tokens = sum(e.metrics.shared_prefix_tokens for e in engines)
    cow = sum(e.pool.stats.get("cow_copies", 0) for e in engines
              if hasattr(e.pool, "stats"))
    ttft = sorted(r["ttft"] for r in records if r["ttft"] >= 0)
    row = {
        "bench": "sustained_rps",
        "system": "prefix_sharing_on" if prefix_sharing
                  else "prefix_sharing_off",
        "n": n_requests,
        "sys_tokens": sys_words,
        "prefill_tokens": int(prefill_tokens),
        "prefix_hits": int(hits),
        "prefix_hit_tokens": int(hit_tokens),
        "cow_copies": int(cow),
        "ttft_p50": _pct(ttft, 50), "ttft_p99": _pct(ttft, 99),
        "elapsed_s": elapsed,
        "outputs": {r["sid"]: r["generated"] for r in records},
    }
    rt.shutdown()
    return row


# ------------------------------------------------------------ experiments
def prefix_experiment(*, n_requests: int, sys_words: int, user_words: int,
                      replicas: int = 2, max_seq: int = 512,
                      seed: int = 0) -> List[Dict]:
    """Shared-system-prompt fleet, prefix index off vs on (same prompts,
    same weights, same routing) — the ROADMAP item 1 evidence."""
    rows = []
    for sharing in (False, True):
        rows.append(_prefix_condition(
            prefix_sharing=sharing, n_requests=n_requests,
            sys_words=sys_words, user_words=user_words,
            replicas=replicas, max_seq=max_seq, seed=seed))
    return rows


def prefill_experiment(*, rps: float, duration: float, long_frac: float,
                       long_words: int, seed: int = 0) -> List[Dict]:
    """Chunked vs monolithic prefill under mixed long-prompt/decode load.

    Single replica on purpose: with siblings available, least-ETA routing
    steers interactive traffic around a stalled replica, masking the data-
    plane property under test (the engine itself must not head-of-line
    block its batch).
    """
    rows = []
    for system, chunk in (("prefill_monolithic", 0),
                          ("prefill_chunked", 64)):
        row = run_condition(system=system, prefill_chunk=chunk, max_queue=0,
                            max_retries=0, rps=rps, duration=duration,
                            long_frac=long_frac, long_words=long_words,
                            max_seq=2048, replicas=1, max_batch=4, seed=seed)
        rows.append(row)
    return rows


def admission_experiment(*, ladder: List[float], duration: float,
                         max_queue: int, out_short: int,
                         timeout_s: float, seed: int = 0) -> List[Dict]:
    """Bounded vs unbounded admission over a stepped arrival-rate ladder.

    The bounded config sheds at the door (no retry budget): under
    *sustained* overload, retrying a queue-full rejection just re-enters
    the queue — unbounded queueing with extra steps — so the deadline-
    aware policy is to fail excess fast and keep admitted work inside its
    latency budget.  The retryable path through the ladder (backoff →
    reroute to a below-watermark sibling) is for transient spikes and is
    regression-tested in tests/test_engine_bridge.py.
    """
    rows = []
    for system, mq in (("admission_unbounded", 0),
                       ("admission_bounded", max_queue)):
        for rps in ladder:
            row = run_condition(
                system=system, prefill_chunk=8, max_queue=mq,
                max_retries=0, rps=rps, duration=duration,
                long_frac=0.0, short_words=8, out_short=out_short,
                max_seq=128, replicas=2, max_batch=2,
                timeout_s=timeout_s, seed=seed)
            rows.append(row)
    return rows


def _sustained(row: Dict) -> bool:
    return row["goodput_rps"] >= 0.85 * row["offered"]


def _collapsed(row: Dict) -> bool:
    return row["goodput_rps"] < 0.5 * row["offered"]


def analyze(rows: List[Dict]) -> Dict:
    by = {}
    for r in rows:
        by.setdefault(r["system"], []).append(r)
    out: Dict = {}
    mono = by.get("prefill_monolithic", [None])[0]
    chunk = by.get("prefill_chunked", [None])[0]
    if mono and chunk:
        # headline: p99 TTFT of the interactive (decode) class — the
        # traffic a monolithic prefill head-of-line-blocks.  The long
        # class is reported alongside: its own TTFT is *worse* chunked
        # (it pays its prefill in interleaved chunks), which is the
        # standard chunked-prefill trade.
        out["p99_ttft_monolithic_s"] = round(mono["ttft_short_p99"], 4)
        out["p99_ttft_chunked_s"] = round(chunk["ttft_short_p99"], 4)
        out["p99_ttft_long_monolithic_s"] = round(mono["ttft_long_p99"], 4)
        out["p99_ttft_long_chunked_s"] = round(chunk["ttft_long_p99"], 4)
        out["chunked_improves_p99_ttft"] = bool(
            0 <= chunk["ttft_short_p99"] < mono["ttft_short_p99"])
    p_off = by.get("prefix_sharing_off", [None])[0]
    p_on = by.get("prefix_sharing_on", [None])[0]
    if p_off and p_on:
        out["prefix_prefill_tokens_off"] = p_off["prefill_tokens"]
        out["prefix_prefill_tokens_on"] = p_on["prefill_tokens"]
        out["prefix_savings_frac"] = round(
            1.0 - p_on["prefill_tokens"] / max(1, p_off["prefill_tokens"]), 4)
        out["prefix_hit_rate"] = round(
            p_on["prefix_hits"] / max(1, p_on["n"]), 4)
        out["prefix_hit_tokens"] = p_on["prefix_hit_tokens"]
        out["prefix_p99_ttft_off_s"] = round(p_off["ttft_p99"], 4)
        out["prefix_p99_ttft_on_s"] = round(p_on["ttft_p99"], 4)
        # the equivalence claim: sharing changes cost, never tokens
        out["prefix_outputs_identical"] = bool(
            p_off["outputs"] == p_on["outputs"])
        out["prefix_meets_50pct_savings"] = bool(
            out["prefix_savings_frac"] >= 0.5)
    unb = sorted(by.get("admission_unbounded", []), key=lambda r: r["rps"])
    bnd = sorted(by.get("admission_bounded", []), key=lambda r: r["rps"])
    if unb and bnd:
        sustained_b = [r["offered"] for r in bnd if _sustained(r)]
        sustained_u = [r["offered"] for r in unb if _sustained(r)]
        collapse = next((r for r in unb if _collapsed(r)), None)
        out["bounded_max_sustained_rps"] = round(max(sustained_b), 2) \
            if sustained_b else 0.0
        out["unbounded_max_sustained_rps"] = round(max(sustained_u), 2) \
            if sustained_u else 0.0
        out["unbounded_collapse_rps"] = round(collapse["offered"], 2) \
            if collapse else None
        out["bounded_goodput_at_top_rps"] = round(bnd[-1]["goodput_rps"], 2)
        out["unbounded_goodput_at_top_rps"] = round(unb[-1]["goodput_rps"], 2)
        out["bounded_beats_unbounded_goodput"] = bool(
            bnd[-1]["goodput_rps"] > unb[-1]["goodput_rps"])
        if collapse is not None:
            at = next((r for r in bnd
                       if abs(r["rps"] - collapse["rps"]) < 1e-9), None)
            if at is not None:
                # at the offered rate where unbounded queueing collapsed,
                # bounded admission is capacity-bound, not queue-bound:
                # goodput stays at the engine's ceiling instead of sinking
                out["bounded_goodput_at_unbounded_collapse"] = round(
                    at["goodput_rps"], 2)
                out["unbounded_goodput_at_collapse"] = round(
                    collapse["goodput_rps"], 2)
                out["bounded_sustains_at_unbounded_collapse"] = bool(
                    at["goodput_rps"] > collapse["goodput_rps"]
                    and at["timeouts"] == 0)
    return out


def run(quick: bool = True, smoke: bool = False) -> List[Dict]:
    if smoke:
        pre = dict(rps=3.0, duration=8.0, long_frac=0.1, long_words=1400)
        adm = dict(ladder=[6.0, 60.0], duration=6.0, max_queue=3,
                   out_short=16, timeout_s=5.0)
    elif quick:
        pre = dict(rps=3.0, duration=15.0, long_frac=0.1, long_words=1400)
        adm = dict(ladder=[6.0, 12.0, 24.0, 48.0, 96.0], duration=6.0,
                   max_queue=3, out_short=16, timeout_s=8.0)
    else:
        pre = dict(rps=3.0, duration=30.0, long_frac=0.1, long_words=1400)
        adm = dict(ladder=[6.0, 12.0, 24.0, 48.0, 96.0, 192.0],
                   duration=12.0, max_queue=3, out_short=16, timeout_s=10.0)
    rows = prefill_experiment(**pre)
    rows += admission_experiment(**adm)
    return rows


def derive(rows: List[Dict]) -> List[str]:
    a = analyze(rows)
    out = []
    for k, v in a.items():
        out.append(f"sustained,{k},{v}")
    if "chunked_improves_p99_ttft" in a:
        out.append("sustained,claim,chunked_prefill_improves_p99_ttft,"
                   f"{int(bool(a['chunked_improves_p99_ttft']))}")
    if "bounded_beats_unbounded_goodput" in a:
        out.append("sustained,claim,bounded_admission_beats_unbounded,"
                   f"{int(bool(a['bounded_beats_unbounded_goodput']))}")
    if "prefix_outputs_identical" in a:
        out.append("sustained,claim,prefix_sharing_saves_half_the_prefill,"
                   f"{int(bool(a['prefix_meets_50pct_savings']))}")
        out.append("sustained,claim,prefix_sharing_outputs_identical,"
                   f"{int(bool(a['prefix_outputs_identical']))}")
    return out


def write_record(rows: List[Dict], mode: str,
                 name: str = "BENCH_sustained_rps.json") -> str:
    """Machine-readable record at the repo root (the acceptance artifact:
    chunked-vs-monolithic p99 TTFT + bounded-vs-unbounded goodput with the
    unbounded collapse point; ``--prefix`` writes the prefix-sharing
    savings/equivalence record instead)."""
    payload = {
        "bench": "sustained_rps",
        "mode": mode,
        "analysis": analyze(rows),
        "rows": [{k: v for k, v in r.items() if k != "outputs"}
                 for r in rows],
    }
    path = os.path.join(os.path.dirname(__file__), "..", name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=str)
        f.write("\n")
    return path


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="tiny CI run; asserts the paper-claim budget checks")
    p.add_argument("--full", action="store_true")
    p.add_argument("--prefix", action="store_true",
                   help="run only the shared-system-prompt prefix-sharing "
                        "experiment (writes BENCH_prefix_sharing.json)")
    args = p.parse_args()
    if args.prefix:
        if args.smoke:
            rows = prefix_experiment(n_requests=8, sys_words=96,
                                     user_words=6, max_seq=256)
        else:
            rows = prefix_experiment(n_requests=24, sys_words=320,
                                     user_words=8, max_seq=512)
    else:
        rows = run(quick=not args.full, smoke=args.smoke)
    for r in rows:
        print({k: (round(v, 4) if isinstance(v, float) else v)
               for k, v in r.items() if k != "outputs"})
    a = analyze(rows)
    for line in derive(rows):
        print(line)
    mode = "smoke" if args.smoke else ("full" if args.full else "quick")
    name = "BENCH_prefix_sharing.json" if args.prefix \
        else "BENCH_sustained_rps.json"
    path = write_record(rows, mode, name=name)
    print(f"wrote {os.path.normpath(path)}")
    if args.prefix and args.smoke:
        # CI budget checks — the prefix index must actually hit on a
        # shared-prompt fleet, and must never change what gets generated
        assert a.get("prefix_hit_rate", 0) > 0, (
            f"no prefix hits on a shared-system-prompt workload: {a}")
        assert a.get("prefix_outputs_identical"), (
            f"prefix sharing changed generated tokens (equivalence drift): "
            f"{a}")
        assert a.get("prefix_savings_frac", 0) > 0, (
            f"prefix sharing saved no prefill tokens: {a}")
        print("prefix-sharing smoke budget checks passed")
    elif args.smoke:
        # CI budget checks — regressions to monolithic-stall or unbounded-
        # queueing behaviour fail the job
        assert a.get("chunked_improves_p99_ttft"), (
            "chunked prefill no longer improves p99 TTFT over monolithic: "
            f"{a}")
        assert a.get("bounded_beats_unbounded_goodput"), (
            "bounded admission no longer beats unbounded queueing on "
            f"goodput at the top arrival rate: {a}")
        print("smoke budget checks passed")


if __name__ == "__main__":
    main()
