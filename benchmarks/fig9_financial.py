"""Fig. 9a — Financial Analyst workflow: latency distribution vs RPS,
NALAR vs baselines.  Paper claim: P95-P99 improves 34-74%; average improves
8-35% (dominated by long requests)."""

from __future__ import annotations

import statistics
from typing import Dict, List

from repro.workloads import BASELINES, run_financial, system_config


def run(quick: bool = True) -> List[Dict]:
    rates = [1.0, 2.0] if quick else [1.0, 2.0, 4.0]
    n_sessions = 40 if quick else 60
    seeds = list(range(11, 19)) if quick else list(range(11, 23))
    rows = []
    for rps in rates:
        for name in ["nalar"] + BASELINES:
            runs = [run_financial(system_config(name), rps=rps,
                                  n_sessions=n_sessions, seed=s)
                    for s in seeds]
            r = {k: statistics.mean(x[k] for x in runs)
                 for k in ("avg", "p50", "p95", "p99", "migrations")}
            r.update(bench="fig9a_financial", system=name, rps=rps,
                     n=sum(x["n"] for x in runs), seeds=len(seeds))
            rows.append(r)
    return rows


def derive(rows: List[Dict]) -> List[str]:
    """Per-rate avg/P95/P99 improvement of NALAR over the best baseline.

    Note (EXPERIMENTS.md §Claims): our P99 is dominated by the heavy
    requests' own service time, which no scheduler can shrink; the paper's
    34-74% P95-P99 band reflects queueing-collapse victims on their larger
    cluster.  The reproduced signal is avg/P95 + the migration mechanism.
    """
    out = []
    for rps in sorted({r["rps"] for r in rows}):
        sub = [r for r in rows if r["rps"] == rps]
        nalar = next(r for r in sub if r["system"] == "nalar")
        for pct in ("avg", "p95", "p99"):
            best = min(r[pct] for r in sub if r["system"] != "nalar")
            imp = 100 * (1 - nalar[pct] / best)
            out.append(f"fig9a,rps={rps},{pct}_improvement_pct,{imp:.1f}")
        out.append(f"fig9a,rps={rps},nalar_migrations,"
                   f"{nalar['migrations']:.0f}")
    return out
