"""Paged-native decode vs the gather data plane — real engines, wall-clock.

PR 7 retired the per-slot dense KV copy: the batched decode step consumes
page tables straight from the ``PagedKVPool`` and scatters new K/V into
pool pages (COW-aware), so admission adopts pages zero-copy and finish
needs no write-back.  This benchmark measures both halves of that claim on
a churn workload (short generations, continuous admissions — the regime
where the gather plane pays a full-context gather at every admission and a
write-back at every finish):

* **per-step time** — identical workload through a ``paged_decode=True``
  and a ``paged_decode=False`` engine; mean wall-clock per engine step
  (admission + decode + finish amortized in).  Claim: paged is no slower,
  and wins as churn rises because the O(max_seq) copies are gone.

* **max resident batch at fixed HBM** — analytic, from the engines' own
  array sizes: the gather plane holds each active session twice (dense
  slot cache + its pool pages), the paged plane holds pages only.  Claim:
  strictly more resident sessions per byte for every attention family.

Recurrent families (ssm/hybrid) have no pages; their PR-7 delta is the
fused in-jit chunk scan, so the differential there is fused vs the
per-token masked fallback, and the HBM columns are equal by construction.

Numbers are CPU smoke-model scale — the *shape* (paged no slower, strictly
denser) is the reproduced claim, not absolute latency.

    PYTHONPATH=src python -m benchmarks.paged_decode          # quick
    PYTHONPATH=src python benchmarks/paged_decode.py --smoke  # CI budget
    PYTHONPATH=src python -m benchmarks.run --only paged_decode
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.serving.batching import Request  # noqa: E402
from repro.serving.engine import InferenceEngine  # noqa: E402
from repro.serving.kv_cache import PagedKVPool  # noqa: E402
from repro.serving.sampler import SamplingParams  # noqa: E402

# ≥ a transformer, a windowed, and a recurrent config (the acceptance floor)
ARCHS = ["qwen3_0_6b", "starcoder2_15b", "mamba2_130m"]
HBM_BUDGET = 1 << 30          # fixed 1 GiB budget for the analytic column

MAX_SEQ = 64
PAGE = 8
MAX_BATCH = 4


def _engine(arch, plane: str) -> InferenceEngine:
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = InferenceEngine(model, params, max_batch=MAX_BATCH,
                          max_seq=MAX_SEQ, page_size=PAGE, prefill_chunk=8,
                          rng_seed=0, paged_decode=(plane == "paged"))
    if plane == "masked":
        eng._decode_chunk = None          # recurrent baseline: per-token path
    return eng


def _bytes_per_slot(eng: InferenceEngine) -> int:
    """HBM a resident max-seq session costs on this engine's data plane."""
    if not isinstance(eng.pool, PagedKVPool):
        # state pool: per-session state bytes, identical on both planes
        leaves = jax.tree_util.tree_leaves(eng.cache)
        return sum(x.nbytes for x in leaves) // eng.max_batch
    pool = eng.pool
    page_bytes = (pool.k.nbytes + pool.v.nbytes) // pool.k.shape[1]
    pages = pool.pages_needed(eng.max_seq) * page_bytes
    if eng._paged:
        return pages                      # the pool IS the decode cache
    slot = (eng.cache["k"].nbytes + eng.cache["v"].nbytes) // eng.max_batch
    return slot + pages                   # dense slot copy + stale pool pages


def _churn(eng: InferenceEngine, n_requests: int, gen_len: int) -> Dict:
    """Short generations, continuous admissions: keep the engine saturated
    with ``n_requests`` sequential sessions and time steady-state steps."""
    rng = np.random.default_rng(0)
    prompts = [[int(t) for t in rng.integers(1, 99, 12)]
               for _ in range(n_requests)]
    sp = SamplingParams(temperature=0.0, max_new_tokens=gen_len)

    def submit(j):
        eng.submit(Request.make(prompts[j], session_id=f"c{j}", sampling=sp))

    # warmup: compile every shape this workload hits
    for j in range(MAX_BATCH):
        submit(j)
    done = 0
    warm_deadline = time.perf_counter() + 300.0
    while done < MAX_BATCH:
        eng.step()
        done += eng.drain_completions()
        assert time.perf_counter() < warm_deadline, "warmup stalled"

    for j in range(MAX_BATCH, n_requests):
        submit(j)
    steps, done, tokens0 = 0, 0, eng.metrics.tokens_generated
    t0 = time.perf_counter()
    while done < n_requests - MAX_BATCH:
        eng.step()
        done += eng.drain_completions()
        steps += 1
        assert steps < 100_000, "churn workload did not converge"
    wall = time.perf_counter() - t0
    return {"per_step_ms": 1e3 * wall / max(1, steps),
            "tok_per_s": (eng.metrics.tokens_generated - tokens0) / wall,
            "steps": steps}


def run(quick: bool = True, smoke: bool = False) -> List[Dict]:
    n_req = 12 if (quick or smoke) else 48
    gen_len = 6 if (quick or smoke) else 16
    rows: List[Dict] = []
    for arch in ARCHS:
        recurrent = get_smoke_config(arch).family in ("ssm", "hybrid")
        planes = ("masked", "fused") if recurrent else ("gather", "paged")
        for plane in planes:
            eng = _engine(arch, plane)
            m = _churn(eng, n_req, gen_len)
            bps = _bytes_per_slot(eng)
            rows.append({"bench": "paged_decode", "arch": arch,
                         "plane": plane, **m,
                         "bytes_per_slot": bps,
                         "max_batch_at_1gib": HBM_BUDGET // bps})
    return rows


def derive(rows: List[Dict]) -> List[str]:
    out = []
    by = {(r["arch"], r["plane"]): r for r in rows}
    for arch in ARCHS:
        recurrent = get_smoke_config(arch).family in ("ssm", "hybrid")
        base, new = (("masked", "fused") if recurrent
                     else ("gather", "paged"))
        a, b = by[(arch, base)], by[(arch, new)]
        speed = a["per_step_ms"] / max(1e-9, b["per_step_ms"])
        out.append(f"{arch}: {new} {b['per_step_ms']:.2f}ms/step vs {base} "
                   f"{a['per_step_ms']:.2f} ({speed:.2f}x)")
        if recurrent:
            out.append(f"{arch}: state pool — HBM per slot equal by "
                       f"construction ({b['bytes_per_slot']} B)")
        else:
            out.append(
                f"{arch}: max resident batch @1GiB {b['max_batch_at_1gib']} "
                f"({new}) vs {a['max_batch_at_1gib']} ({base}) — "
                f"{b['bytes_per_slot']} vs {a['bytes_per_slot']} B/slot")
    return out


def write_record(rows: List[Dict], mode: str) -> str:
    by = {(r["arch"], r["plane"]): r for r in rows}
    checks = {}
    for arch in ARCHS:
        recurrent = get_smoke_config(arch).family in ("ssm", "hybrid")
        base, new = (("masked", "fused") if recurrent
                     else ("gather", "paged"))
        a, b = by[(arch, base)], by[(arch, new)]
        if recurrent:
            # no pages to retire: fused chunked admission replaces the
            # monolithic-prefill stall; the budget is bounded per-step cost
            # (its win — stall-free TTFT — is sustained_rps territory)
            checks[arch] = {
                "fused_step_within_tolerance": bool(
                    b["per_step_ms"] <= a["per_step_ms"] * 1.3),
                "strictly_higher_max_batch": None,
            }
        else:
            checks[arch] = {
                "paged_step_not_slower": bool(
                    b["per_step_ms"] <= a["per_step_ms"] * 1.05),
                "strictly_higher_max_batch": bool(
                    b["max_batch_at_1gib"] > a["max_batch_at_1gib"]),
            }
    payload = {"bench": "paged_decode", "mode": mode,
               "hbm_budget_bytes": HBM_BUDGET, "checks": checks,
               "derived": derive(rows), "rows": rows}
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_paged_decode.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=str)
        f.write("\n")
    return path


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true")
    p.add_argument("--smoke", action="store_true",
                   help="CI budget check: paged must be no slower per step "
                        "and strictly denser per HBM byte")
    args = p.parse_args()
    rows = run(quick=not args.full, smoke=args.smoke)
    for line in derive(rows):
        print(line)
    path = write_record(rows, "smoke" if args.smoke else
                        ("quick" if not args.full else "full"))
    print(f"wrote {os.path.relpath(path)}")
    if args.smoke:
        with open(path) as f:
            checks = json.load(f)["checks"]
        bad = [f"{arch}.{name}" for arch, cs in checks.items()
               for name, ok in cs.items() if ok is False]
        assert not bad, f"paged-decode budget violated: {bad}"
        print("paged_decode --smoke: OK (paged no slower per step, "
              "strictly higher max batch at fixed HBM)")


if __name__ == "__main__":
    main()
