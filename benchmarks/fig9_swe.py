"""Fig. 9c — Software-engineering workflow (recursive retries): end-to-end
speedup from dynamic reallocation.  Paper claim: up to 2.9x speedup; >2.1x
lower load imbalance than baselines."""

from __future__ import annotations

import statistics
from typing import Dict, List

from repro.workloads import BASELINES, run_swe, system_config


def run(quick: bool = True) -> List[Dict]:
    n_requests = 8 if quick else 16
    seeds = [17, 18, 19] if quick else [17, 18, 19, 20, 21]
    rows = []
    for name in ["nalar"] + BASELINES:
        runs = [run_swe(system_config(name), n_requests=n_requests, seed=s)
                for s in seeds]
        r = {k: statistics.mean(x[k] for x in runs)
             for k in ("avg", "p50", "p95", "p99", "makespan", "migrations")}
        r.update(bench="fig9c_swe", system=name,
                 n=sum(x["n"] for x in runs), seeds=len(seeds))
        rows.append(r)
    return rows


def derive(rows: List[Dict]) -> List[str]:
    nalar = next(r for r in rows if r["system"] == "nalar")
    out = []
    for r in rows:
        if r["system"] == "nalar":
            continue
        sp_avg = r["avg"] / nalar["avg"]
        sp_p99 = r["p99"] / nalar["p99"]
        out.append(f"fig9c,vs_{r['system']},avg_speedup_x,{sp_avg:.2f}")
        out.append(f"fig9c,vs_{r['system']},p99_speedup_x,{sp_p99:.2f}")
    return out
