"""Pooled-replica routing benchmark — real engines, wall-clock time.

Three routing configurations over the same 3-replica ``EnginePool`` under
skewed session load (a few hot sessions issue most of the follow-up turns):

* ``round_robin``   — spray turns across replicas, no cache affinity: every
                      turn of a session pays a full-context prefill wherever
                      it lands (the baseline-system behaviour).
* ``least_eta``     — load-aware spraying, still cache-blind.
* ``kv_affinity``   — a ``GlobalController`` policy (``KVAffinityPolicy``)
                      pins each session to the replica holding its K,V cache
                      via the Table 2 ``route`` primitive; follow-up turns
                      send only their new suffix.

The paper-claim check: the policy-driven configuration beats round-robin on
p95 turn latency, and its engines prefill far fewer tokens for the same
workload (the Fig. 9a mechanism, measured on real engines instead of the
latency emulator).

    PYTHONPATH=src python -m benchmarks.pool_routing
    PYTHONPATH=src python -m benchmarks.run --only pool_routing
"""

from __future__ import annotations

import random
import time
from typing import Dict, List

from repro.core import KVAffinityPolicy, PolicyChain
from repro.workloads.router import build_pool_runtime


def _warm_compile(pool, buckets=(16, 32, 64)) -> None:
    """Compile each replica's prefill buckets + decode step up front so JIT
    time does not pollute the latency comparison."""
    from repro.serving import SamplingParams
    for iid in pool.instance_ids:
        engine = pool.bridge_of(iid).engine
        for b in buckets:
            sid = f"warmup:{iid}:{b}"
            engine.generate(list(range(b - 1)), session_id=sid,
                            sampling=SamplingParams(max_new_tokens=2))
            engine.pool.release(sid)
            if engine.kv_registry is not None:
                engine.kv_registry.release(sid)


def run_pool_routing(mode: str, *, replicas: int = 3, hot_sessions: int = 2,
                     cold_sessions: int = 6, hot_turns: int = 6,
                     cold_turns: int = 2, rps: float = 8.0,
                     max_new_tokens: int = 4, seed: int = 0,
                     timeout_s: float = 300.0) -> Dict[str, float]:
    if mode == "kv_affinity":
        policy = KVAffinityPolicy(agent_types=["llm"])
        router_mode = "least_eta"
    else:
        policy = PolicyChain()          # no global actions
        router_mode = mode
    rt = build_pool_runtime(replicas=replicas, max_new_tokens=max_new_tokens,
                            router_mode=router_mode, kv_affinity=False,
                            policy=policy, control_interval=0.05, seed=seed)
    pool = rt.engine_backends["llm"]
    _warm_compile(pool)
    # counter baseline so warmup traffic doesn't pollute the comparison
    base = pool.telemetry()["replicas"]
    base_prefill = sum(r["prefill_tokens"] for r in base.values())
    base_completed = sum(r["completed"] for r in base.values())

    # skewed turn schedule: hot sessions carry most follow-ups
    rng = random.Random(seed)
    plan: List = []                     # (arrival_t, session_tag, turn_idx)
    t = 0.0
    sessions = ([("hot", i, hot_turns) for i in range(hot_sessions)]
                + [("cold", i, cold_turns) for i in range(cold_sessions)])
    turn_iters = [[(kind, i, k) for k in range(n)] for kind, i, n in sessions]
    pending = [it for it in turn_iters if it]
    while pending:
        t += rng.expovariate(rps)
        # hot sessions are 4x as likely to be the next arrival
        weights = [4.0 if it[0][0] == "hot" else 1.0 for it in pending]
        r = rng.random() * sum(weights)
        acc = 0.0
        for j, w in enumerate(weights):
            acc += w
            if r <= acc:
                break
        kind, i, k = pending[j].pop(0)
        if not pending[j]:
            pending.pop(j)
        plan.append((t, f"{kind}{i}", k))

    sids = {}
    for _, tag, _ in plan:
        if tag not in sids:
            sids[tag] = rt.sessions.new_session(rt.kernel.now(), 0.0).session_id

    def turn_driver(tag: str, k: int):
        q = f"{tag} follow up number {k} with some extra words of context"
        return rt.stub("llm").generate(
            q, _hint={"out_tokens": max_new_tokens}).value(timeout=timeout_s)

    rt.start()
    for arrival, tag, k in plan:
        rt.submit_request(turn_driver, tag, k, session=sids[tag],
                          delay=arrival)
    time.sleep(plan[-1][0] + 0.5)       # let every arrival timer fire
    rt.run()

    out = dict(rt.telemetry.summary())
    out["system"] = mode
    out["turns"] = len(plan)
    tel = pool.telemetry()
    out["prefill_tokens"] = sum(r["prefill_tokens"]
                                for r in tel["replicas"].values()) - base_prefill
    out["prefix_hits"] = sum(r["prefix_hits"] for r in tel["replicas"].values())
    out["completed"] = sum(r["completed"]
                           for r in tel["replicas"].values()) - base_completed
    out["replicas_used"] = sum(1 for r in tel["replicas"].values()
                               if r["completed"] > 0)
    out["reuse_hits"] = rt.kv_registry.stats["reuse_hits"]
    rt.shutdown()
    return out


def run(quick: bool = True) -> List[Dict]:
    kw: Dict = {} if not quick else dict(hot_sessions=2, cold_sessions=4,
                                         hot_turns=4, cold_turns=1)
    rows = []
    for mode in ("round_robin", "least_eta", "kv_affinity"):
        r = run_pool_routing(mode, **kw)
        r["bench"] = "pool_routing"
        rows.append(r)
    return rows


def derive(rows: List[Dict]) -> List[str]:
    by = {r["system"]: r for r in rows}
    out = []
    for mode, r in by.items():
        out.append(f"pool,{mode},p95_s,{r.get('p95', -1):.3f}")
        out.append(f"pool,{mode},prefill_tokens,{r.get('prefill_tokens', 0)}")
    rr, kv = by.get("round_robin"), by.get("kv_affinity")
    if rr and kv and rr.get("p95") and kv.get("p95"):
        out.append(f"pool,claim,kv_affinity_beats_round_robin_p95,"
                   f"{int(kv['p95'] < rr['p95'])}")
        out.append(f"pool,claim,kv_affinity_prefills_fewer_tokens,"
                   f"{int(kv['prefill_tokens'] < rr['prefill_tokens'])}")
    return out


if __name__ == "__main__":
    for row in run(quick=True):
        print(row)
    print()
