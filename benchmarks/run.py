"""Benchmark harness entry: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Prints ``name,metric,value`` CSV rows: raw measurements first, then each
benchmark's derived paper-claim checks.  ``--full`` runs paper-scale
workloads (slower); the default is a quick pass sized for CI.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from . import (failure_injection, fig9_financial, fig9_router,  # noqa: E402
               fig9_swe, fig10_control_loop, paged_decode, pool_routing,
               sec62_policies, spec_decode, straggler_hedging, streaming,
               sustained_rps, table4_two_level)

BENCHES = {
    "fig9a_financial": fig9_financial,
    "fig9b_router": fig9_router,
    "fig9c_swe": fig9_swe,
    "fig10_control_loop": fig10_control_loop,
    "table4_two_level": table4_two_level,
    "sec62_policies": sec62_policies,
    # real engines, wall-clock: replica-pool routing policy comparison
    "pool_routing": pool_routing,
    # replica killed mid-run: goodput/p95 with the retry ladder on vs off
    "failure_injection": failure_injection,
    # open-loop stepped-RPS load: chunked-vs-monolithic prefill TTFT and
    # bounded-vs-unbounded admission goodput (the abstract's 80-RPS claim)
    "sustained_rps": sustained_rps,
    # paged-native decode vs gather data plane: per-step time + max
    # resident batch at fixed HBM (churn workload, real engines)
    "paged_decode": paged_decode,
    # speculative decoding (self-draft, fused multi-token verify) +
    # model-tier routing: tokens/step gain and goodput-per-FLOP
    "spec_decode": spec_decode,
    # injected 10x-slow replica: hedged dispatch p99 cut vs hedging off,
    # hedge-budget overhead, deadline expiry under tight budgets
    "straggler_hedging": straggler_hedging,
    # incremental futures: classifier starts on the first streamed tokens;
    # streamed-vs-completion p99 + TTFT, byte-identical outputs
    "streaming": streaming,
}


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true")
    p.add_argument("--only", default=None)
    p.add_argument("--out", default="benchmarks/results")
    args = p.parse_args()

    os.makedirs(args.out, exist_ok=True)
    print("bench,metric,value")
    all_rows = {}
    for name, mod in BENCHES.items():
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        rows = mod.run(quick=not args.full)
        wall = time.perf_counter() - t0
        all_rows[name] = rows
        for r in rows:
            tag = "/".join(str(r[k]) for k in ("system", "policy", "rps",
                                               "futures", "nodes")
                           if k in r)
            for k, v in r.items():
                if k in ("n", "bench", "system", "policy") or not isinstance(
                        v, (int, float)):
                    continue
                val = f"{v:.4f}" if isinstance(v, float) else str(v)
                print(f"{name}[{tag}],{k},{val}")
        for line in mod.derive(rows):
            print(f"{name},derived,{line}")
        print(f"{name},wall_seconds,{wall:.1f}")
        with open(os.path.join(args.out, f"{name}.json"), "w") as f:
            json.dump(rows, f, indent=2, default=str)
    if "fig10_control_loop" in all_rows:
        write_control_loop_record(all_rows["fig10_control_loop"],
                                  full=args.full)
    if "sustained_rps" in all_rows:
        sustained_rps.write_record(all_rows["sustained_rps"],
                                   "full" if args.full else "quick")
    if "paged_decode" in all_rows:
        paged_decode.write_record(all_rows["paged_decode"],
                                  "full" if args.full else "quick")
    if "spec_decode" in all_rows:
        spec_decode.write_record(all_rows["spec_decode"],
                                 "full" if args.full else "quick")
    if "straggler_hedging" in all_rows:
        straggler_hedging.write_record(all_rows["straggler_hedging"],
                                       "full" if args.full else "quick")
    if "streaming" in all_rows:
        streaming.write_record(all_rows["streaming"],
                               "full" if args.full else "quick")
    print(f"done,benches,{len(all_rows)}")


def write_control_loop_record(rows, full: bool) -> None:
    """Machine-readable control-loop record at the repo root: the perf
    trajectory CI and future PRs check against (see
    benchmarks/check_control_budget.py)."""
    biggest = max(rows, key=lambda r: (r["futures"], r["nodes"]))
    payload = {
        "bench": "fig10_control_loop",
        "mode": "full" if full else "quick",
        "max_futures": biggest["futures"],
        "loop_total_ms_at_max": round(biggest["loop_total_ms"], 3),
        "sub_500ms_at_max": bool(biggest["loop_total_ms"] < 500),
        "policy_frac_at_max": round(
            biggest["policy_ms"] / max(1e-9, biggest["compute_total_ms"]), 4),
        "derived": fig10_control_loop.derive(rows),
        "rows": rows,
    }
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_control_loop.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=str)
        f.write("\n")


if __name__ == "__main__":
    main()
