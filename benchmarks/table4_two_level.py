"""Table 4 — one-level vs two-level control: per-future scheduling time.

One-level: a single central controller routes EVERY future itself — each
decision scans the cluster view, and futures queue behind each other at the
single decision thread (the paper's reported time includes that queueing
delay, which is why it grows superlinearly past 16K futures).

Two-level: the global controller only installs the policy; each of the 128
component-level controllers makes the per-future decision locally against
its own queue.  Per-future time is the local decision cost — independent of
the total future population.
"""

from __future__ import annotations

import time
from typing import Dict, List

from repro.core import SRTFSchedule
from repro.core.policy import ClusterView, InstanceView

N_AGENTS = 128
N_NODES = 64


def _view(n_instances: int) -> ClusterView:
    view = ClusterView(now=0.0)
    for i in range(n_instances):
        iv = InstanceView(
            instance_id=f"a{i % N_AGENTS}:n{i % N_NODES}/0",
            agent_type=f"a{i % N_AGENTS}", node=f"n{i % N_NODES}",
            qsize=i % 7, busy=bool(i % 2), busy_until=1.0, ema_service=0.4,
            completed=0, failed=0, alive=True, waiting_sessions=[])
        view.instances[iv.instance_id] = iv
        view.by_type.setdefault(iv.agent_type, []).append(iv.instance_id)
    return view


class _Fut:
    __slots__ = ("meta",)

    def __init__(self, i: int):
        self.meta = type("M", (), {})()
        self.meta.work_hint = {"graph_depth": i % 5, "est_service": 0.1 * (i % 9)}
        self.meta.created_at = float(i)
        self.meta.priority = 0.0
        self.meta.agent_type = f"a{i % N_AGENTS}"


def one_level_decision(view: ClusterView, fut) -> str:
    """Central routing: scan the agent type's instances for min ETA."""
    ivs = view.instances_of(fut.meta.agent_type)
    best = min(ivs, key=lambda iv: iv.eta(view.now))
    return best.instance_id


def two_level_decision(schedule: SRTFSchedule, local_queue, fut) -> str:
    """Local enforcement: order the (small) local queue with the installed
    policy; no cluster-wide state touched."""
    key = schedule.order_key(fut, 0.0)
    # insertion position in the local queue (bounded, e.g. 16 waiting)
    idx = sum(1 for f in local_queue if schedule.order_key(f, 0.0) < key)
    return idx and "q" or "head"


def run(quick: bool = True) -> List[Dict]:
    sizes = [1024, 2048, 4096, 8192, 16384, 32768, 65536, 131072]
    if quick:
        sizes = sizes[:6]
    view = _view(N_AGENTS)
    schedule = SRTFSchedule()
    local_queue = [_Fut(i) for i in range(16)]
    rows = []
    for n in sizes:
        futs = [_Fut(i) for i in range(n)]
        # ---- one level: all futures funnel through one decision thread;
        # per-token time = mean time-in-system (queueing + service)
        t0 = time.perf_counter()
        for f in futs:
            one_level_decision(view, f)
        elapsed = time.perf_counter() - t0
        per_decision = elapsed / n
        one_level_ms = 1e3 * per_decision * (n + 1) / 2.0   # mean queue wait
        # ---- two level: 128 concurrent local controllers, each deciding
        # against its own bounded queue; no population-wide queueing
        t0 = time.perf_counter()
        for f in futs[:4096]:
            two_level_decision(schedule, local_queue, f)
        local_per = (time.perf_counter() - t0) / min(n, 4096)
        two_level_ms = 1e3 * local_per
        rows.append({"bench": "table4", "futures": n,
                     "one_level_ms": one_level_ms,
                     "two_level_ms": two_level_ms})
    return rows


def derive(rows: List[Dict]) -> List[str]:
    out = []
    for r in rows:
        out.append(f"table4,futures={r['futures']},one_level_ms,"
                   f"{r['one_level_ms']:.2f}")
        out.append(f"table4,futures={r['futures']},two_level_ms,"
                   f"{r['two_level_ms']:.2f}")
    big = rows[-1]
    out.append(f"table4,futures={big['futures']},two_level_advantage_x,"
               f"{big['one_level_ms'] / max(big['two_level_ms'], 1e-9):.0f}")
    return out
