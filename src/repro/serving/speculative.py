"""Draft engine for speculative decoding on the paged data plane.

A small-tier model proposes ``k`` greedy tokens per scheduling round for
each decoding slot; the target engine verifies all ``k+1`` positions in one
ragged ``decode_chunk_paged`` call and accepts a prefix (see
``serving/sampler.speculative_verify``).  The draft runs on its own dense
``decode_chunk`` cache, slot-aligned with the target's batch slots, so
draft catch-up and proposal steps batch across slots exactly like the
target's chunked data plane.

Proposals are deterministic (argmax), i.e. the proposal distribution is a
point mass — the accept rule then reduces to "accept with probability
p(d)" and the residual resample stays unbiased, so no draft RNG and no
draft logits ever cross to the verifier.  The draft may be *any*
tokenizer-compatible config: an independently trained small tier, a
distilled shadow of the target, or a layer-truncated view of the target's
own parameters (``truncated_draft`` below — zero extra training, the
LayerSkip-style self-speculation baseline).

Per-slot state is a token ``stream`` (everything the target consumed plus
the draft's own proposals) and a ``consumed`` watermark (how much of the
stream is in the draft cache).  Rollback after a rejected tail is just
truncating the stream and rewinding ``cache["pos"]`` — attention masks by
position, so stale K/V past the watermark is unreachable.  That trick
requires a non-windowed attention draft (ring caches lose clobbered slots
on rewind), which ``wire_draft`` in ``serving.engine`` enforces.
"""

from __future__ import annotations

import dataclasses

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

_CHUNK = 16     # max draft catch-up feed width per call


class DraftEngine:
    """Slot-aligned greedy proposer over a dense ``decode_chunk`` cache."""

    def __init__(self, model, params, *, max_batch: int, max_seq: int):
        if model.cfg.sliding_window:
            raise ValueError("draft model must be non-windowed "
                             "(rollback rewinds cache positions)")
        if model.decode_chunk is None:
            raise ValueError("draft model has no fused decode_chunk")
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.cache = model.init_cache(max_batch, max_seq)
        self._stream: List[List[int]] = [[] for _ in range(max_batch)]
        self._consumed = [0] * max_batch

        def _step(params, toks, valid, cache):
            logits, cache = model.decode_chunk(params, toks, valid, cache)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        self._step = jax.jit(_step)

    # ------------------------------------------------------------- protocol
    def observe(self, slot: int, tokens: List[int]) -> None:
        """Extend the slot's stream with tokens the target consumed."""
        self._stream[slot].extend(int(t) for t in tokens)

    def rollback(self, slot: int, n_stream: int) -> None:
        """Truncate the slot's stream to its first ``n_stream`` tokens (the
        part the verifier kept); rewind the cache watermark to match."""
        del self._stream[slot][n_stream:]
        if self._consumed[slot] > n_stream:
            self._consumed[slot] = n_stream
            self.cache["pos"] = self.cache["pos"].at[slot].set(n_stream)

    def reset(self, slot: int) -> None:
        self._stream[slot] = []
        self._consumed[slot] = 0
        self.cache["pos"] = self.cache["pos"].at[slot].set(0)

    def propose(self, want: Dict[int, int]) -> Dict[int, List[int]]:
        """Propose ``want[slot]`` greedy tokens per slot, batched.

        Feeds each slot's unconsumed stream (catch-up), then extends it
        autoregressively; every iteration is one ragged ``decode_chunk``
        over all still-working slots.  The final proposal is appended to
        the stream but not fed — the verifier's outcome decides (via
        :meth:`rollback`) whether it survives."""
        props: Dict[int, List[int]] = {s: [] for s in want}
        for s, k in want.items():
            if self._consumed[s] >= len(self._stream[s]):
                # generation only happens off a fed position: the caller
                # must observe() the next consumed token before proposing
                raise ValueError(f"slot {s}: nothing pending to extend")
            if len(self._stream[s]) + k - 1 > self.max_seq:
                raise ValueError(f"slot {s}: stream would exceed draft "
                                 f"max_seq {self.max_seq}")
        while True:
            feeds = {}
            for s, k in want.items():
                if len(props[s]) >= k:
                    continue
                fs = self._stream[s][self._consumed[s]:]
                feeds[s] = fs[:_CHUNK]
            if not feeds:
                break
            width = max(len(f) for f in feeds.values())
            width = 1 << (width - 1).bit_length() if width > 1 else 1
            toks = np.zeros((self.max_batch, width), np.int32)
            valid = np.zeros((self.max_batch,), np.int32)
            for s, fs in feeds.items():
                toks[s, :len(fs)] = fs
                valid[s] = len(fs)
            greedy, self.cache = self._step(
                self.params, jnp.asarray(toks), jnp.asarray(valid),
                self.cache)
            greedy = np.asarray(greedy)
            for s, fs in feeds.items():
                self._consumed[s] += len(fs)
                if self._consumed[s] == len(self._stream[s]):
                    d = int(greedy[s, len(fs) - 1])
                    props[s].append(d)
                    self._stream[s].append(d)
        return props


def distill_draft(draft, dparams, target, tparams, data_fn, *,
                  steps: int = 250, lr: float = 3e-3, seed: int = 0):
    """Distill ``draft`` toward the target's greedy decisions: minimize
    cross-entropy between the draft's logits and ``argmax`` of the target's,
    over contexts drawn from ``data_fn(key) -> [B, S] int32`` (use the
    serving distribution — acceptance is an on-policy property).  This is
    the "distilled shadow" draft: unlike :func:`truncated_draft` alone it
    tracks what the target *does*, not just what its early layers compute,
    which is what closes the argmax-agreement gap that acceptance pays
    for.  Returns the trained draft params."""
    from ..training.optimizer import AdamW, constant_schedule

    tfwd = jax.jit(lambda t: _logits(target, tparams, t))

    def loss(dp, toks, labels):
        lp = jax.nn.log_softmax(
            _logits(draft, dp, toks).astype(jnp.float32), axis=-1)
        return -jnp.mean(jnp.take_along_axis(lp, labels[..., None], axis=-1))

    opt = AdamW(learning_rate=constant_schedule(lr), weight_decay=0.0)
    state = opt.init(dparams)
    step = jax.jit(lambda dp, st, toks, labels: opt.update(
        jax.grad(loss)(dp, toks, labels), st, dp))
    key = jax.random.PRNGKey(seed)
    for _ in range(steps):
        key, sub = jax.random.split(key)
        toks = data_fn(sub)
        dparams, state = step(dparams, state, toks,
                              jnp.argmax(tfwd(toks), axis=-1))
    return dparams


def _logits(model, params, toks):
    out = model.forward(params, {"tokens": toks})
    return out[0] if isinstance(out, tuple) else out


def truncated_draft(model, params, n_layers: int):
    """A layer-truncated self-draft: the target's own first ``n_layers``
    layers plus its embedding/unembedding and final norm, as an independent
    small-tier model (LayerSkip-style self-speculation — no training, same
    tokenizer by construction).  Returns ``(draft_model, draft_params)``."""
    from ..models.model import build_model
    cfg = dataclasses.replace(model.cfg, n_layers=n_layers)
    draft = build_model(cfg)
    dparams = dict(params)
    dparams["layers"] = jax.tree_util.tree_map(
        lambda x: x[:n_layers], params["layers"])
    return draft, dparams
