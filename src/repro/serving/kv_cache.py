"""Paged K,V cache pool with per-session page tables (TPU adaptation of
vLLM's PagedAttention + the paper's LMCache control hooks, §4.3.2).

Design (DESIGN.md §2): pages are sized to TPU-friendly multiples in the
KV-length dimension; the pool is one HBM-resident array per layer stack
[L, n_pages, page, Hkv, Dh].  Sessions own page lists; NALAR's KVRegistry
drives retention (`retain`), eviction (`drop`), offload (`far`) and
migration — the engine consults those hints instead of blind LRU, which is
exactly the paper's remedy for "generic eviction heuristics that discard
caches about to be reused".

The pool also exposes ``gather_contiguous`` to materialize a sequence's
cache into the dense per-slot layout the XLA decode path uses, and the page
table format the Pallas paged-attention kernel consumes.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig


@dataclass
class SessionPages:
    session_id: str
    pages: List[int] = field(default_factory=list)
    tokens: int = 0                  # valid tokens across pages
    pinned: bool = False             # retain hint from the global controller
    offloaded: bool = False          # "far memory" (host) residency
    last_used: float = 0.0


class PagedKVPool:
    """One pool per engine instance.

    The pool stores K and V as [L, n_pages, page_size, Hkv, Dh].  On real
    TPU hardware this lives in HBM; pages are the granularity of both
    eviction and session migration (the paper's K,V migration maps to
    copying a session's page list between instances' pools).
    """

    def __init__(self, cfg: ModelConfig, n_pages: int, page_size: int = 128,
                 dtype=None) -> None:
        if cfg.family == "ssm":
            raise ValueError("SSM caches are O(1); use StateCachePool")
        self.cfg = cfg
        self.page_size = page_size
        self.n_pages = n_pages
        shape = (cfg.n_layers, n_pages, page_size, cfg.n_kv_heads,
                 cfg.head_dim_)
        dt = dtype or cfg.jnp_dtype
        self.k = jnp.zeros(shape, dt)
        self.v = jnp.zeros(shape, dt)
        self._free: List[int] = list(range(n_pages))
        self._sessions: Dict[str, SessionPages] = {}
        self._lock = threading.RLock()

    # ---------------------------------------------------------- allocation
    def pages_needed(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def free_pages(self) -> int:
        with self._lock:
            return len(self._free)

    def allocate(self, session_id: str, tokens: int, now: float = 0.0,
                 evict: bool = True) -> Optional[SessionPages]:
        """Reserve pages for ``tokens`` new tokens of a session."""
        with self._lock:
            sp = self._sessions.setdefault(session_id,
                                           SessionPages(session_id))
            have = len(sp.pages) * self.page_size
            need_pages = self.pages_needed(max(0, sp.tokens + tokens - have))
            while len(self._free) < need_pages:
                if not evict or not self._evict_one(now):
                    return None
            for _ in range(need_pages):
                sp.pages.append(self._free.pop())
            sp.tokens += tokens
            sp.last_used = now
            return sp

    def _evict_one(self, now: float) -> bool:
        """Evict the LRU unpinned session (hint-aware, unlike vanilla LRU)."""
        cands = [s for s in self._sessions.values() if s.pages and not s.pinned]
        if not cands:
            return False
        victim = min(cands, key=lambda s: s.last_used)
        self._release(victim)
        return True

    def _release(self, sp: SessionPages) -> None:
        self._free.extend(sp.pages)
        sp.pages = []
        sp.tokens = 0
        sp.offloaded = False

    def release(self, session_id: str) -> None:
        with self._lock:
            sp = self._sessions.pop(session_id, None)
            if sp is not None:
                self._release(sp)

    # ----------------------------------------------------------- hint hooks
    def on_hint(self, session_id: str, hint: str) -> None:
        """KVRegistry hook target (retain/drop/offload/migrate_*)."""
        with self._lock:
            sp = self._sessions.get(session_id)
            if sp is None:
                return
            if hint == "retain":
                sp.pinned = True
            elif hint == "drop":
                sp.pinned = False
                self._release(sp)
                self._sessions.pop(session_id, None)
            elif hint == "offload":
                sp.offloaded = True
                sp.pinned = False
            elif hint == "migrate_out":
                # ownership moved away; free local pages
                self._release(sp)
                self._sessions.pop(session_id, None)
            elif hint == "migrate_in":
                pass  # pages arrive via export/import below

    # ----------------------------------------------------------- migration
    def export_session(self, session_id: str) -> Optional[Tuple[np.ndarray, np.ndarray, int]]:
        """Serialize a session's K/V pages (the migration payload)."""
        with self._lock:
            sp = self._sessions.get(session_id)
            if sp is None or not sp.pages:
                return None
            idx = jnp.asarray(sp.pages)
            return (np.asarray(self.k[:, idx]), np.asarray(self.v[:, idx]),
                    sp.tokens)

    def import_session(self, session_id: str, payload, now: float = 0.0) -> bool:
        kpages, vpages, tokens = payload
        n = kpages.shape[1]
        with self._lock:
            while len(self._free) < n:
                if not self._evict_one(now):
                    return False
            pages = [self._free.pop() for _ in range(n)]
            idx = jnp.asarray(pages)
            self.k = self.k.at[:, idx].set(jnp.asarray(kpages))
            self.v = self.v.at[:, idx].set(jnp.asarray(vpages))
            self._sessions[session_id] = SessionPages(
                session_id, pages=pages, tokens=tokens, last_used=now)
            return True

    # ------------------------------------------------------------- reading
    def session(self, session_id: str) -> Optional[SessionPages]:
        with self._lock:
            return self._sessions.get(session_id)

    def page_table(self, session_id: str, max_pages: int) -> np.ndarray:
        """Padded page table row for the Pallas paged-attention kernel."""
        with self._lock:
            sp = self._sessions.get(session_id)
            pages = sp.pages if sp else []
        row = np.full((max_pages,), -1, np.int32)
        row[:len(pages)] = pages[:max_pages]
        return row

    def gather_contiguous(self, session_id: str, max_seq: int):
        """Materialize [L, max_seq, Hkv, Dh] dense K/V for the XLA path."""
        with self._lock:
            sp = self._sessions.get(session_id)
            if sp is None or not sp.pages:
                return None
            idx = jnp.asarray(sp.pages)
            tokens = sp.tokens
        L = self.cfg.n_layers
        k = self.k[:, idx].reshape(L, -1, *self.k.shape[3:])[:, :max_seq]
        v = self.v[:, idx].reshape(L, -1, *self.v.shape[3:])[:, :max_seq]
        return k, v, tokens

    def write_session(self, session_id: str, k_seq, v_seq, tokens: int,
                      now: float = 0.0) -> bool:
        """Store a sequence's dense K/V ([L, S, Hkv, Dh]) into pages."""
        self.release(session_id)
        sp = self.allocate(session_id, tokens, now)
        if sp is None:
            return False
        P = self.page_size
        pad = len(sp.pages) * P - k_seq.shape[1]
        if pad:
            padding = [(0, 0), (0, pad), (0, 0), (0, 0)]
            k_seq = jnp.pad(k_seq, padding)
            v_seq = jnp.pad(v_seq, padding)
        idx = jnp.asarray(sp.pages)
        kp = k_seq.reshape(self.cfg.n_layers, len(sp.pages), P,
                           *k_seq.shape[2:])
        vp = v_seq.reshape(self.cfg.n_layers, len(sp.pages), P,
                           *v_seq.shape[2:])
        with self._lock:
            self.k = self.k.at[:, idx].set(kp)
            self.v = self.v.at[:, idx].set(vp)
        return True


class StateCachePool:
    """O(1)-state cache pool for SSM/hybrid sessions (conv + recurrent
    state, plus the bounded sliding-window KV for hybrid attention layers).

    Migration cost is tokens-independent — the property DESIGN.md calls out
    as making NALAR-style session migration *cheaper* for these families.
    """

    def __init__(self, cfg: ModelConfig) -> None:
        self.cfg = cfg
        self._states: Dict[str, Tuple[dict, int]] = {}
        self._lock = threading.RLock()

    def store(self, session_id: str, state: dict, tokens: int) -> None:
        with self._lock:
            self._states[session_id] = (state, tokens)

    def load(self, session_id: str) -> Optional[Tuple[dict, int]]:
        with self._lock:
            return self._states.get(session_id)

    def release(self, session_id: str) -> None:
        with self._lock:
            self._states.pop(session_id, None)

    def on_hint(self, session_id: str, hint: str) -> None:
        if hint in ("drop", "migrate_out"):
            self.release(session_id)

    def export_session(self, session_id: str):
        with self._lock:
            return self._states.get(session_id)

    def import_session(self, session_id: str, payload, now: float = 0.0) -> bool:
        with self._lock:
            self._states[session_id] = payload
            return True
