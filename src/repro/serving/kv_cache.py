"""Paged K,V cache pool with per-session page tables (TPU adaptation of
vLLM's PagedAttention + the paper's LMCache control hooks, §4.3.2).

Design (DESIGN.md §2): pages are sized to TPU-friendly multiples in the
KV-length dimension; the pool is one HBM-resident array per layer stack
[L, n_pages, page, Hkv, Dh].  Sessions own page lists; NALAR's KVRegistry
drives retention (`retain`), eviction (`drop`), offload (`far`) and
migration — the engine consults those hints instead of blind LRU, which is
exactly the paper's remedy for "generic eviction heuristics that discard
caches about to be reused".

Cross-session prefix sharing: the pool keeps a radix index at page
granularity — ``(parent_page, token_block) -> page`` with the root parent
``-1`` — over every session whose page contents are a known function of a
token prefix (``SessionPages.token_ids``).  Pages are refcounted; a cold
session whose prompt prefix is resident *acquires* the matching chain
(``acquire_prefix``) and prefills only its novel suffix.  Divergence is
copy-on-write: ``write_session`` keeps the still-common full pages in
place (shared or not — their bytes are already correct) and gives the
diverging tail fresh pages, so no session ever observes another session's
writes.  Eviction and ``release`` decref; a page is freed (and unindexed)
only when its last reference drops.

Paged-native decode (PR 7): the engine's hot loop no longer copies pages
in or out.  ``begin_append``/``commit_append`` reserve and publish in-place
page writes for each decode step — a write never touches a page with
refcount > 1 (``begin_append`` privatizes a shared tail first, which is the
copy-on-write event), and ``protect``/``unprotect`` pin the sessions that
are actively decoding against eviction and drop hints.
``gather_contiguous`` remains only for the off-hot-path consumers: session
export/migration, warm-up replay (``warm_session``), the dense fallback
engine (``paged_decode=False``) and debugging.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig

# radix-index root: the "parent" of a session's first page
_ROOT = -1


@dataclass
class SessionPages:
    session_id: str
    pages: List[int] = field(default_factory=list)
    tokens: int = 0                  # valid tokens across pages
    # token ids whose K/V the pages hold, in position order.  Valid (and
    # eligible for sharing / keep-in-place rewrites) only when
    # len(token_ids) == tokens; sessions built through raw allocate() are
    # opaque (token_ids == []) and never enter the prefix index.
    token_ids: List[int] = field(default_factory=list)
    pinned: bool = False             # retain hint from the global controller
    offloaded: bool = False          # "far memory" (host) residency
    last_used: float = 0.0


class PagedKVPool:
    """One pool per engine instance.

    The pool stores K and V as [L, n_pages, page_size, Hkv, Dh].  On real
    TPU hardware this lives in HBM; pages are the granularity of eviction,
    sharing and session migration (the paper's K,V migration maps to
    copying a session's page list between instances' pools).
    """

    def __init__(self, cfg: ModelConfig, n_pages: int, page_size: int = 128,
                 dtype=None) -> None:
        if cfg.family == "ssm":
            raise ValueError("SSM caches are O(1); use StateCachePool")
        self.cfg = cfg
        self.page_size = page_size
        self.n_pages = n_pages
        shape = (cfg.n_layers, n_pages, page_size, cfg.n_kv_heads,
                 cfg.head_dim_)
        dt = dtype or cfg.jnp_dtype
        self.k = jnp.zeros(shape, dt)
        self.v = jnp.zeros(shape, dt)
        self._free: List[int] = list(range(n_pages))
        self._sessions: Dict[str, SessionPages] = {}
        # page -> number of session page-lists containing it
        self._ref: Dict[int, int] = {}
        # prefix index: parent page (or _ROOT) -> {token block -> page}.
        # The index holds no references of its own — entries die with the
        # page — and a page has at most one entry (its _page_key).
        self._index: Dict[int, Dict[Tuple[int, ...], int]] = {}
        self._page_key: Dict[int, Tuple[int, Tuple[int, ...]]] = {}
        self.stats: Dict[str, int] = {
            "prefix_queries": 0, "prefix_hits": 0, "prefix_tokens": 0,
            "cow_copies": 0, "dedup_pages": 0, "evictions": 0,
            "inplace_appends": 0,
        }
        # sessions an engine slot is actively decoding into: never evicted,
        # never released by drop/migrate hints (their pages are the live
        # write targets of the paged-native step)
        self._protected: set = set()
        self._lock = threading.RLock()

    # ---------------------------------------------------------- allocation
    def pages_needed(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def free_pages(self) -> int:
        with self._lock:
            return len(self._free)

    def _free_page(self, page: int) -> None:
        """Return ``page`` to the free list and drop its index entries."""
        self._unindex(page)
        sub = self._index.pop(page, None)
        if sub:
            # orphan any children: their chain prefix no longer exists, so
            # they must not be discoverable under a recycled parent id
            for child in sub.values():
                self._page_key.pop(child, None)
        self._ref.pop(page, None)
        self._free.append(page)

    def _incref(self, page: int) -> None:
        self._ref[page] = self._ref.get(page, 0) + 1

    def _decref(self, page: int) -> None:
        r = self._ref.get(page, 0) - 1
        if r <= 0:
            self._free_page(page)
        else:
            self._ref[page] = r

    def _alloc_page(self, now: float, avoid: Optional[str] = None
                    ) -> Optional[int]:
        while not self._free:
            if not self._evict_one(now, avoid=avoid):
                return None
        page = self._free.pop()
        self._ref[page] = 1
        return page

    def allocate(self, session_id: str, tokens: int, now: float = 0.0,
                 evict: bool = True) -> Optional[SessionPages]:
        """Reserve pages for ``tokens`` new tokens of a session.

        Raw reservations carry no token identity: the session becomes
        opaque to the prefix index until a ``write_session`` with
        ``token_ids`` re-describes its contents.
        """
        with self._lock:
            sp = self._sessions.setdefault(session_id,
                                           SessionPages(session_id))
            have = len(sp.pages) * self.page_size
            need_pages = self.pages_needed(max(0, sp.tokens + tokens - have))
            got: List[int] = []
            for _ in range(need_pages):
                if evict:
                    page = self._alloc_page(now, avoid=session_id)
                elif self._free:
                    page = self._free.pop()
                    self._ref[page] = 1
                else:
                    page = None
                if page is None:
                    for p in got:
                        self._decref(p)
                    return None
                got.append(page)
            sp.pages.extend(got)
            sp.tokens += tokens
            sp.token_ids = []
            sp.last_used = now
            return sp

    def _evict_one(self, now: float, avoid: Optional[str] = None) -> bool:
        """Evict the LRU unpinned session (hint-aware, unlike vanilla LRU).

        Shared pages survive eviction of one owner — only their last
        reference frees them — so evicting a donor never corrupts the
        sessions that acquired its prefix.  Protected sessions (actively
        decoding in an engine slot) are never candidates."""
        cands = [s for s in self._sessions.values()
                 if s.pages and not s.pinned and s.session_id != avoid
                 and s.session_id not in self._protected]
        if not cands:
            return False
        victim = min(cands, key=lambda s: s.last_used)
        self._release(victim)
        self.stats["evictions"] += 1
        return True

    def _release(self, sp: SessionPages) -> None:
        for p in sp.pages:
            self._decref(p)
        sp.pages = []
        sp.tokens = 0
        sp.token_ids = []
        sp.offloaded = False

    def release(self, session_id: str) -> None:
        with self._lock:
            sp = self._sessions.pop(session_id, None)
            if sp is not None:
                self._release(sp)

    # ------------------------------------------------- paged-native appends
    def protect(self, session_id: str) -> None:
        """Pin a session against eviction and drop/migrate hints while an
        engine slot decodes straight into its pages."""
        with self._lock:
            self._protected.add(session_id)

    def unprotect(self, session_id: str) -> None:
        with self._lock:
            self._protected.discard(session_id)

    def begin_append(self, session_id: str, n: int, now: float = 0.0) -> bool:
        """Reserve in-place write capacity for ``n`` more tokens.

        The paged-native decode step writes new K/V straight into the
        session's pages (positions ``tokens .. tokens+n-1``).  This call
        makes that safe:

        * every page about to be written becomes exclusively owned — a
          shared page (refcount > 1, e.g. an adopted prefix tail from PR 6)
          is privatized onto a fresh page first (the copy-on-write event),
          so **an in-place write never mutates a page with refcount > 1**;
        * an exclusively-owned tail is unindexed before the write: its
          index key may still describe a departed donor's longer block, and
          any chain hanging off it would splice content computed under a
          different prefix (``commit_append`` re-keys it afterwards);
        * capacity pages for the overflow are allocated up front.

        All-or-nothing: returns False (session untouched) if the pool
        cannot provide the pages.  The caller publishes the write with
        ``commit_append`` after the step lands."""
        if n <= 0:
            return True
        P = self.page_size
        with self._lock:
            sp = self._sessions.setdefault(session_id,
                                           SessionPages(session_id))
            first_b = sp.tokens // P
            last_b = (sp.tokens + n - 1) // P
            existing = list(range(first_b, min(last_b + 1, len(sp.pages))))
            n_new = max(0, last_b + 1 - len(sp.pages))
            n_cow = sum(1 for b in existing
                        if self._ref.get(sp.pages[b], 0) > 1)
            fresh: List[int] = []
            for _ in range(n_new + n_cow):
                page = self._alloc_page(now, avoid=session_id)
                if page is None:
                    for p in fresh:
                        self._decref(p)
                    return False
                fresh.append(page)
            for b in existing:
                old = sp.pages[b]
                if self._ref.get(old, 0) > 1:
                    # privatize: the other owners keep the old page (and
                    # its index entry) untouched
                    new = fresh.pop()
                    self.k = self.k.at[:, new].set(self.k[:, old])
                    self.v = self.v.at[:, new].set(self.v[:, old])
                    sp.pages[b] = new
                    self._decref(old)
                    self.stats["cow_copies"] += 1
                else:
                    # exclusively ours, but its key/children may describe a
                    # departed donor's content past our valid tokens —
                    # stale the moment we write in place
                    self._unindex(old)
                    sub = self._index.pop(old, None)
                    if sub:
                        for child in sub.values():
                            self._page_key.pop(child, None)
            sp.pages.extend(fresh)
            sp.last_used = now
            self.stats["inplace_appends"] += 1
            return True

    def commit_append(self, session_id: str, n: int, token_ids=None,
                      now: float = 0.0) -> None:
        """Publish ``n`` tokens written in place by the paged decode step.

        With ``token_ids`` (the ``n`` consumed tokens, extending a valid
        provenance) the affected pages (re-)enter the prefix index —
        completed full pages and the new partial tail — so cross-session
        sharing keeps working without any gather/write-back.  Without ids
        (or on a provenance break) the session goes opaque; already-indexed
        prefix pages keep their entries, which stay valid."""
        P = self.page_size
        with self._lock:
            sp = self._sessions.get(session_id)
            if sp is None or n <= 0:
                return
            old_tokens = sp.tokens
            sp.tokens = old_tokens + n
            sp.last_used = now
            ok = (token_ids is not None and len(token_ids) == n
                  and len(sp.token_ids) == old_tokens)
            if not ok:
                sp.token_ids = []
                return
            sp.token_ids = sp.token_ids + [int(t) for t in token_ids]
            ids = sp.token_ids
            for b in range(old_tokens // P, (sp.tokens - 1) // P + 1):
                page = sp.pages[b]
                block = tuple(ids[b * P:min((b + 1) * P, sp.tokens)])
                parent = sp.pages[b - 1] if b > 0 else _ROOT
                self._unindex(page)
                if block:
                    self._index_page(parent, block, page)

    def truncate_reserved(self, session_id: str) -> int:
        """Release reserved pages past the committed token count.

        Speculative decode reserves ``begin_append(sid, k+1)`` capacity but
        may commit fewer positions (rejected-tail rollback): the trailing
        pages hold K/V for draft tokens that never became part of the
        sequence.  ``begin_append`` guarantees every page in the write range
        is exclusively owned and unindexed, so dropping them cannot disturb
        a sharer or the prefix index; the partial tail page that still holds
        committed tokens is kept (garbage past ``sp.tokens`` inside it is
        masked by position everywhere).  Returns the number of pages freed."""
        with self._lock:
            sp = self._sessions.get(session_id)
            if sp is None:
                return 0
            keep = self.pages_needed(sp.tokens)
            freed = len(sp.pages) - keep
            if freed <= 0:
                return 0
            for page in sp.pages[keep:]:
                self._decref(page)
            del sp.pages[keep:]
            return freed

    # --------------------------------------------------------- prefix index
    def _unindex(self, page: int) -> None:
        key = self._page_key.pop(page, None)
        if key is not None:
            parent, block = key
            children = self._index.get(parent)
            if children is not None and children.get(block) == page:
                del children[block]
                if not children:
                    self._index.pop(parent, None)

    def _index_page(self, parent: int, block: Tuple[int, ...],
                    page: int) -> None:
        if page in self._page_key:      # one entry per page
            return
        children = self._index.setdefault(parent, {})
        if block in children:           # first writer wins
            return
        children[block] = page
        self._page_key[page] = (parent, block)

    def _match_prefix_locked(self, ids: List[int]
                             ) -> Tuple[List[int], int]:
        """Longest resident chain covering a prefix of ``ids``.

        Full-page blocks must match a stored block exactly; the walk ends
        at the first block matched only partially (the page is shared up
        to the common token prefix — positions beyond it are never read,
        and rewrites COW)."""
        P = self.page_size
        pages: List[int] = []
        matched = 0
        parent = _ROOT
        n = len(ids)
        while matched < n:
            block = tuple(ids[matched:matched + P])
            children = self._index.get(parent)
            if not children:
                break
            page = children.get(block)
            if page is not None and len(block) == P:
                pages.append(page)
                matched += P
                parent = page
                continue
            best, best_c = None, 0
            for key, kpage in children.items():
                m = min(len(key), len(block))
                c = 0
                while c < m and key[c] == block[c]:
                    c += 1
                if c > best_c:
                    best, best_c = kpage, c
            if best is not None:
                pages.append(best)
                matched += best_c
            break
        return pages, matched

    def match_prefix(self, token_ids: List[int]) -> int:
        """Tokens of ``token_ids`` resident in the index (read-only probe)."""
        with self._lock:
            _pages, matched = self._match_prefix_locked(
                [int(t) for t in token_ids])
            return matched

    def acquire_prefix(self, session_id: str, token_ids: List[int],
                       now: float = 0.0) -> int:
        """Adopt the longest indexed chain covering a prefix of
        ``token_ids`` as the (cold) session's initial pages.

        Returns the number of tokens now cached for the session (0 on a
        miss or if the session already holds pages)."""
        ids = [int(t) for t in token_ids]
        with self._lock:
            sp = self._sessions.get(session_id)
            if sp is not None and sp.pages:
                return 0
            self.stats["prefix_queries"] += 1
            pages, matched = self._match_prefix_locked(ids)
            if matched <= 0:
                return 0
            for p in pages:
                self._incref(p)
            if sp is None:
                sp = SessionPages(session_id)
                self._sessions[session_id] = sp
            sp.pages = list(pages)
            sp.tokens = matched
            sp.token_ids = ids[:matched]
            sp.last_used = now
            sp.offloaded = False
            self.stats["prefix_hits"] += 1
            self.stats["prefix_tokens"] += matched
            return matched

    # ----------------------------------------------------------- hint hooks
    def on_hint(self, session_id: str, hint: str) -> None:
        """KVRegistry hook target (retain/drop/offload/migrate_*)."""
        with self._lock:
            sp = self._sessions.get(session_id)
            if sp is None:
                return
            if hint == "retain":
                sp.pinned = True
            elif hint == "drop":
                sp.pinned = False
                # a protected session is the live write target of an active
                # paged decode: freeing its pages under the step would hand
                # them to another session mid-write.  The hint downgrades
                # to unpin; LRU reclaims the pages once decode finishes.
                if session_id not in self._protected:
                    self._release(sp)
                    self._sessions.pop(session_id, None)
            elif hint == "offload":
                sp.offloaded = True
                sp.pinned = False
            elif hint == "migrate_out":
                # ownership moved away; drop local references (shared pages
                # stay alive for their remaining owners)
                if session_id not in self._protected:
                    self._release(sp)
                    self._sessions.pop(session_id, None)
            elif hint == "migrate_in":
                pass  # pages arrive via export/import below

    # ----------------------------------------------------------- migration
    def compatible_with(self, other: "PagedKVPool") -> bool:
        """Page payloads are portable between pools of identical geometry."""
        return (isinstance(other, PagedKVPool)
                and self.page_size == other.page_size
                and self.k.shape[0] == other.k.shape[0]
                and self.k.shape[2:] == other.k.shape[2:]
                and self.k.dtype == other.k.dtype)

    def export_session(self, session_id: str) -> Optional[Dict[str, Any]]:
        """Serialize a session's K/V pages (the migration payload)."""
        with self._lock:
            sp = self._sessions.get(session_id)
            if sp is None or not sp.pages:
                return None
            idx = jnp.asarray(sp.pages)
            return {"k": np.asarray(self.k[:, idx]),
                    "v": np.asarray(self.v[:, idx]),
                    "tokens": sp.tokens,
                    "token_ids": list(sp.token_ids),
                    "page_size": self.page_size}

    def import_session(self, session_id: str, payload,
                       now: float = 0.0) -> bool:
        """Install a migration payload, deduplicating against the local
        prefix index: full pages whose token blocks are already resident
        are adopted (refcounted) instead of copied."""
        if payload is None:
            return False
        if isinstance(payload, dict):
            kpages, vpages = payload["k"], payload["v"]
            tokens = payload["tokens"]
            token_ids = payload.get("token_ids") or []
            if payload.get("page_size", self.page_size) != self.page_size:
                return False
        else:   # legacy (k, v, tokens) tuple
            kpages, vpages, tokens = payload
            token_ids = []
        n = kpages.shape[1]
        ids = [int(t) for t in token_ids]
        if len(ids) != tokens:
            ids = []
        P = self.page_size
        with self._lock:
            old = self._sessions.pop(session_id, None)
            if old is not None:
                self._release(old)
            # adopt resident full pages (exact-chain matches only: a
            # partially matched page cannot be spliced with payload pages)
            shared: List[int] = []
            if ids:
                chain, matched = self._match_prefix_locked(ids)
                shared = chain[:matched // P]
                for p in shared:
                    self._incref(p)
                self.stats["dedup_pages"] += len(shared)
            first_new = len(shared)
            fresh: List[int] = []
            for _ in range(first_new, n):
                page = self._alloc_page(now, avoid=session_id)
                if page is None:
                    for p in shared:
                        self._decref(p)
                    for p in fresh:
                        self._decref(p)
                    return False
                fresh.append(page)
            if fresh:
                idx = jnp.asarray(fresh)
                self.k = self.k.at[:, idx].set(jnp.asarray(kpages[:, first_new:]))
                self.v = self.v.at[:, idx].set(jnp.asarray(vpages[:, first_new:]))
            pages = shared + fresh
            if ids:
                parent = shared[-1] if shared else _ROOT
                for b, page in enumerate(fresh, start=first_new):
                    block = tuple(ids[b * P:min((b + 1) * P, tokens)])
                    if block:
                        self._index_page(parent, block, page)
                    parent = page
            self._sessions[session_id] = SessionPages(
                session_id, pages=pages, tokens=tokens, token_ids=ids,
                last_used=now)
            return True

    # ------------------------------------------------------------- reading
    def session(self, session_id: str) -> Optional[SessionPages]:
        with self._lock:
            return self._sessions.get(session_id)

    def page_table(self, session_id: str, max_pages: int) -> np.ndarray:
        """Padded page table row for the Pallas paged-attention kernel."""
        with self._lock:
            sp = self._sessions.get(session_id)
            pages = sp.pages if sp else []
        row = np.full((max_pages,), -1, np.int32)
        row[:len(pages)] = pages[:max_pages]
        return row

    def gather_contiguous(self, session_id: str, max_seq: int):
        """Materialize [L, max_seq, Hkv, Dh] dense K/V.

        No longer on the serving hot path: paged-native decode consumes
        page tables directly.  This remains the export/debug path — warm
        replay, migration payload assembly, the ``paged_decode=False``
        fallback engine, and tests that compare cache bytes."""
        with self._lock:
            sp = self._sessions.get(session_id)
            if sp is None or not sp.pages:
                return None
            idx = jnp.asarray(sp.pages)
            tokens = sp.tokens
        L = self.cfg.n_layers
        k = self.k[:, idx].reshape(L, -1, *self.k.shape[3:])[:, :max_seq]
        v = self.v[:, idx].reshape(L, -1, *self.v.shape[3:])[:, :max_seq]
        return k, v, tokens

    # ------------------------------------------------------------- writing
    def write_session(self, session_id: str, k_seq, v_seq, tokens: int,
                      now: float = 0.0, token_ids=None) -> bool:
        """Store a sequence's dense K/V ([L, S, Hkv, Dh]) into pages.

        With ``token_ids`` (one id per cached position) the write is
        sharing-aware: full pages whose token prefix is unchanged stay in
        place untouched — shared pages stay shared, which *is* the
        copy-on-write: the diverging tail gets fresh pages while the old
        tail pages survive for their other owners.  New full pages (and
        the partial tail) enter the prefix index for future cross-session
        hits.  Without ``token_ids`` the legacy release-and-rewrite path
        runs (opaque contents, no sharing)."""
        ids = None
        if token_ids is not None:
            ids = [int(t) for t in token_ids]
            if len(ids) != tokens:
                ids = None
        if ids is None:
            return self._write_opaque(session_id, k_seq, v_seq, tokens, now)
        P = self.page_size
        n_blocks = self.pages_needed(tokens)
        with self._lock:
            sp = self._sessions.setdefault(session_id,
                                           SessionPages(session_id))
            old_pages = list(sp.pages)
            old_valid = len(sp.token_ids) == sp.tokens and sp.tokens > 0
            common = 0
            if old_valid:
                m = min(len(sp.token_ids), tokens)
                while common < m and sp.token_ids[common] == ids[common]:
                    common += 1
            keep = min(common // P, sp.tokens // P, len(old_pages))
            # build the new chain before dropping the old tail, so an
            # unchanged tail is re-adopted instead of freed and rewritten
            pages = old_pages[:keep]
            parent = pages[-1] if pages else _ROOT
            adopted: List[int] = []
            fresh: List[int] = []
            novel: List[Tuple[int, int]] = []       # (block index, page)
            ok = True
            for b in range(keep, n_blocks):
                block = tuple(ids[b * P:min((b + 1) * P, tokens)])
                child = self._index.get(parent, {}).get(block)
                if child is not None and self._ref.get(child, 0) > 0:
                    self._incref(child)
                    adopted.append(child)
                    pages.append(child)
                    parent = child
                    continue
                page = self._alloc_page(now, avoid=session_id)
                if page is None:
                    ok = False
                    break
                fresh.append(page)
                novel.append((b, page))
                self._index_page(parent, block, page)
                pages.append(page)
                parent = page
            if not ok:
                for p in adopted + fresh:
                    self._decref(p)
                return False
            self.stats["dedup_pages"] += len(adopted)
            # divergence from a shared page = the copy-on-write event: the
            # old owner keeps the page, this session wrote a fresh one
            new_set = set(pages)
            self.stats["cow_copies"] += sum(
                1 for p in old_pages[keep:]
                if p not in new_set and self._ref.get(p, 0) > 1)
            for p in old_pages[keep:]:
                self._decref(p)
            if novel:
                pad = n_blocks * P - k_seq.shape[1]
                if pad:
                    padding = [(0, 0), (0, pad), (0, 0), (0, 0)]
                    k_seq = jnp.pad(k_seq, padding)
                    v_seq = jnp.pad(v_seq, padding)
                kp = k_seq.reshape(self.cfg.n_layers, n_blocks, P,
                                   *k_seq.shape[2:])
                vp = v_seq.reshape(self.cfg.n_layers, n_blocks, P,
                                   *v_seq.shape[2:])
                bsel = jnp.asarray([b for b, _ in novel])
                psel = jnp.asarray([p for _, p in novel])
                self.k = self.k.at[:, psel].set(kp[:, bsel])
                self.v = self.v.at[:, psel].set(vp[:, bsel])
            sp.pages = pages
            sp.tokens = tokens
            sp.token_ids = ids
            sp.last_used = now
            sp.offloaded = False
            return True

    def _write_opaque(self, session_id: str, k_seq, v_seq, tokens: int,
                      now: float) -> bool:
        """Legacy path: fresh exclusive pages, contents unindexed."""
        self.release(session_id)
        sp = self.allocate(session_id, tokens, now)
        if sp is None:
            return False
        P = self.page_size
        pad = len(sp.pages) * P - k_seq.shape[1]
        if pad:
            padding = [(0, 0), (0, pad), (0, 0), (0, 0)]
            k_seq = jnp.pad(k_seq, padding)
            v_seq = jnp.pad(v_seq, padding)
        idx = jnp.asarray(sp.pages)
        kp = k_seq.reshape(self.cfg.n_layers, len(sp.pages), P,
                           *k_seq.shape[2:])
        vp = v_seq.reshape(self.cfg.n_layers, len(sp.pages), P,
                           *v_seq.shape[2:])
        with self._lock:
            self.k = self.k.at[:, idx].set(kp)
            self.v = self.v.at[:, idx].set(vp)
        return True

    # ----------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        """Assert the pool's aliasing/accounting invariants (test hook).

        * every page is exactly one of: free, or referenced by >= 1 session;
        * refcounts equal the number of session page-lists containing the
          page (no double-free, no leak: free + live == n_pages);
        * no page appears twice in one session (aliased positions);
        * index entries point at live pages, agree with the reverse map,
          and hang off live parents.
        """
        with self._lock:
            free = list(self._free)
            assert len(free) == len(set(free)), "duplicate pages in free list"
            occ: Dict[int, int] = {}
            for sp in self._sessions.values():
                assert len(sp.pages) == len(set(sp.pages)), \
                    f"session {sp.session_id} owns a page twice"
                assert sp.tokens <= len(sp.pages) * self.page_size, \
                    f"session {sp.session_id} tokens exceed its pages"
                assert len(sp.token_ids) in (0, sp.tokens), \
                    f"session {sp.session_id} token_ids length mismatch"
                for p in sp.pages:
                    occ[p] = occ.get(p, 0) + 1
            for p, n in occ.items():
                assert self._ref.get(p, 0) == n, \
                    f"page {p}: refcount {self._ref.get(p, 0)} != {n} owners"
                assert p not in free, f"page {p} is both owned and free"
            live = {p for p, r in self._ref.items() if r > 0}
            assert live == set(occ), \
                f"refcounted pages {live} != owned pages {set(occ)}"
            assert len(free) + len(live) == self.n_pages, \
                f"{len(free)} free + {len(live)} live != {self.n_pages}"
            for page, (parent, block) in self._page_key.items():
                assert self._ref.get(page, 0) > 0, \
                    f"index entry for free page {page}"
                assert self._index.get(parent, {}).get(block) == page, \
                    f"reverse map for page {page} disagrees with index"
                assert parent == _ROOT or self._ref.get(parent, 0) > 0, \
                    f"page {page} indexed under freed parent {parent}"
            for parent, children in self._index.items():
                for block, page in children.items():
                    assert self._page_key.get(page) == (parent, block), \
                        f"index entry ({parent},{block})->{page} unmapped"


class StateCachePool:
    """O(1)-state cache pool for SSM/hybrid sessions (conv + recurrent
    state, plus the bounded sliding-window KV for hybrid attention layers).

    Migration cost is tokens-independent — the property DESIGN.md calls out
    as making NALAR-style session migration *cheaper* for these families.
    """

    def __init__(self, cfg: ModelConfig) -> None:
        self.cfg = cfg
        self._states: Dict[str, Tuple[dict, int]] = {}
        self._lock = threading.RLock()

    def store(self, session_id: str, state: dict, tokens: int) -> None:
        with self._lock:
            self._states[session_id] = (state, tokens)

    def load(self, session_id: str) -> Optional[Tuple[dict, int]]:
        with self._lock:
            return self._states.get(session_id)

    def release(self, session_id: str) -> None:
        with self._lock:
            self._states.pop(session_id, None)

    def on_hint(self, session_id: str, hint: str) -> None:
        if hint in ("drop", "migrate_out"):
            self.release(session_id)

    def export_session(self, session_id: str):
        with self._lock:
            return self._states.get(session_id)

    def import_session(self, session_id: str, payload, now: float = 0.0) -> bool:
        with self._lock:
            self._states[session_id] = payload
            return True
