from .batching import (EngineOverloaded, Request, RequestExpired, WaitQueue,
                       bucket_len)
from .bridge import (EngineBridge, EngineMethod, GenerationResult,
                     hash_tokenize, register_engine_agent)
from .chaos import (ChaosInjector, ChaosSpec, ScaledLatency, clear_engine,
                    inject_engine, restore_instance, slow_instance)
from .engine import EngineMetrics, InferenceEngine, get_slot, set_slot
from .kv_cache import PagedKVPool, SessionPages, StateCachePool
from .pool import EnginePool, register_engine_pool
from .sampler import SamplingParams, sample

__all__ = ["ChaosInjector", "ChaosSpec", "EngineBridge", "EngineMethod",
           "EngineMetrics", "EngineOverloaded", "EnginePool",
           "GenerationResult", "InferenceEngine", "PagedKVPool", "Request",
           "RequestExpired", "SamplingParams", "ScaledLatency",
           "SessionPages", "StateCachePool", "WaitQueue",
           "bucket_len", "clear_engine", "get_slot", "hash_tokenize",
           "inject_engine", "register_engine_agent", "register_engine_pool",
           "restore_instance", "sample", "set_slot", "slow_instance"]
