from .batching import Request, WaitQueue, bucket_len
from .engine import EngineMetrics, InferenceEngine, get_slot, set_slot
from .kv_cache import PagedKVPool, SessionPages, StateCachePool
from .sampler import SamplingParams, sample

__all__ = ["EngineMetrics", "InferenceEngine", "PagedKVPool", "Request",
           "SamplingParams", "SessionPages", "StateCachePool", "WaitQueue",
           "bucket_len", "get_slot", "sample", "set_slot"]
