from .batching import EngineOverloaded, Request, WaitQueue, bucket_len
from .bridge import (EngineBridge, EngineMethod, GenerationResult,
                     hash_tokenize, register_engine_agent)
from .engine import EngineMetrics, InferenceEngine, get_slot, set_slot
from .kv_cache import PagedKVPool, SessionPages, StateCachePool
from .pool import EnginePool, register_engine_pool
from .sampler import SamplingParams, sample

__all__ = ["EngineBridge", "EngineMethod", "EngineMetrics",
           "EngineOverloaded", "EnginePool",
           "GenerationResult", "InferenceEngine", "PagedKVPool", "Request",
           "SamplingParams", "SessionPages", "StateCachePool", "WaitQueue",
           "bucket_len", "get_slot", "hash_tokenize",
           "register_engine_agent", "register_engine_pool", "sample",
           "set_slot"]
