"""Bridge between NALAR futures and the real JAX ``InferenceEngine``.

This is the module that turns the repo from a discrete-event *emulator* of
agent serving into an actual agent-serving system: a stub call on an
engine-backed agent creates an ordinary NALAR future, the runtime routes it
like any other, and the component controller hands it here — where it becomes
a ``serving.Request`` in the engine's continuous-batching queue.  A pump
thread steps the engine; completion callbacks resolve the futures.

Per-session KV state flows through the two core registries:

* ``KVRegistry`` (agent layer) knows which engine instance holds a session's
  cache and how many tokens it covers.  Before submitting, the bridge asks
  ``expect_reuse(session, instance)``: a warm cache means only the *new*
  tokens are sent (the engine appends them to the cached prefix — measurably
  fewer prefill tokens); a cold one means the full transcript is prefilled.
* ``SessionTranscript`` (managed state, ``core/state.py``) records every
  call's prompt + generated tokens under the session's identity, so that
  cold rebuilds and cross-instance migrations keep the conversation context
  without developer involvement (§3.3).

Layering: ``repro.core`` never imports serving; the abstract
``EngineBackedMethod`` hook lives in ``core.executor`` and is implemented
here, keeping the core runtime importable without JAX.
"""

from __future__ import annotations

import logging
import threading
import time
import zlib
from collections import deque
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from ..core.directives import Directives
from ..core.executor import EngineBackedMethod
from ..core.future import (DeadlineExceeded, Future, InstanceDied,
                           resolve_args)
from ..core.state import SessionTranscript
from ..core.stubs import AgentSpec
from .batching import Request, RequestExpired
from .engine import InferenceEngine
from .sampler import SamplingParams

log = logging.getLogger(__name__)


def hash_tokenize(text: Any, vocab_size: int) -> List[int]:
    """Deterministic toy tokenizer: stable token id per whitespace word.

    The reproduction has no trained tokenizer; what matters for serving
    behaviour is that identical text maps to identical token ids (so prefix
    caching is exercised honestly) and ids stay inside the vocabulary.
    """
    words = str(text).split()
    if not words:
        return [0]
    return [zlib.crc32(w.encode()) % vocab_size for w in words]


@dataclass
class GenerationResult:
    """Value an engine-backed future resolves to (default decode)."""

    request_id: str
    session_id: str
    tokens: List[int]               # newly generated token ids
    prompt_tokens: int              # tokens actually sent this call
    prefix_reused_tokens: int       # prefix restored from the session cache
    engine_id: str = ""

    def __len__(self) -> int:
        return len(self.tokens)

    def __str__(self) -> str:
        return (f"GenerationResult({len(self.tokens)} tokens, "
                f"reused={self.prefix_reused_tokens}, via {self.engine_id})")


class EngineBridge:
    """Owns one ``InferenceEngine`` and its pump thread.

    ``submit_future`` is called by ``EngineMethod.launch`` on the component
    controller's thread; everything JAX happens on the single pump thread
    (continuous batching), and future resolution re-enters the runtime via
    ``ComponentController.complete_async`` (kernel-scheduled, thread-safe).
    """

    def __init__(self, runtime, engine: InferenceEngine,
                 agent_type: str) -> None:
        self.rt = runtime
        self.engine = engine
        self.agent_type = agent_type
        self.transcript: Optional[SessionTranscript] = None
        self._cv = threading.Condition()
        self._pending = 0
        self._stop = False
        self._draining = False
        # request_id -> (future, controller): for failure propagation when
        # the pump loop itself dies (engine bug, OOM, ...)
        self._inflight: Dict[str, Tuple[Future, Any]] = {}
        # per-session ordering: a session's calls must hit the engine one at
        # a time (each call's prompt depends on the previous call's
        # transcript and cache), while different sessions batch freely
        self._session_active: set = set()
        self._session_q: Dict[str, Deque[Tuple[Future, Any, "EngineMethod"]]] = {}
        # session migrations deferred until the in-flight call resolves:
        # sid -> fn(remaining_queue).  A migration must never yank the KV
        # cache out from under a running request; it runs between calls.
        self._migrate_pending: Dict[str, Callable] = {}
        self._thread = threading.Thread(
            target=self._pump, daemon=True,
            name=f"engine-pump:{engine.instance_id}")
        self._thread.start()
        runtime.add_shutdown_hook(self.drain)

    # ------------------------------------------------------------- lifecycle
    def attach(self, instance_id: str, node_id: str) -> None:
        """Bind to the provisioned NALAR agent instance: one identity for
        routing, KV residency, and managed-state placement."""
        self.engine.bind_registry(self.rt.kv_registry, instance_id)
        self.transcript = SessionTranscript(self.rt.state_store,
                                            self.agent_type, node_id)

    def stop(self) -> None:
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        self._thread.join(timeout=5.0)
        if self._thread.is_alive():
            # never silently abandon a wedged pump: the daemon thread will
            # die with the process, but the operator must know it leaked
            log.warning("engine pump %s did not stop within 5s; "
                        "abandoning daemon thread", self.engine.instance_id)

    def drain(self, timeout: float = 5.0) -> int:
        """Graceful shutdown: stop admitting new futures, keep pumping until
        in-flight work completes (or ``timeout`` passes), then fail-fast
        whatever remains through the normal failure path instead of leaking
        it, and finally stop the pump thread.  Returns the number of
        requests failed-fast (0 = clean drain)."""
        deadline = time.monotonic() + timeout
        with self._cv:
            self._draining = True
            while self._pending > 0 and time.monotonic() < deadline:
                self._cv.wait(timeout=0.1)
            leftover = self._pending
        failed = 0
        if leftover:
            log.warning("engine bridge %s drained with %d requests still "
                        "in flight; failing them fast",
                        self.engine.instance_id, leftover)
            failed = self.fail_inflight(InstanceDied(
                f"engine {self.engine.instance_id} shut down mid-flight"))
        self.stop()
        return failed

    def fail_inflight(self, error: BaseException) -> int:
        """Fail every in-flight and session-queued future with ``error`` and
        clear the bridge's session bookkeeping.  Deferred migrations still
        fire (with an empty queue) so their sessions re-home even though the
        queued calls died.  Returns the number of futures failed.

        Used when the engine itself dies (pump-loop crash) and when the
        replica is hard-killed (fault injection): either way the engine's
        results will never arrive, so the futures must travel the retry
        ladder now rather than hang.  Partially-streamed requests fail the
        same way: ``Future.fail`` wakes blocked chunk iterators, which
        terminate by raising the failure — a consumer mid-stream observes a
        drain/crash as a fast error, never a hang."""
        with self._cv:
            dead = list(self._inflight.values())
            dead += [(f, c) for q in self._session_q.values()
                     for (f, c, _m) in q]
            self._inflight.clear()
            self._session_q.clear()
            self._session_active.clear()
            self._pending = 0
            migs = list(self._migrate_pending.values())
            self._migrate_pending.clear()
        try:
            # drop queued/in-slot work and clear per-slot residue (pending
            # prompts) so nothing of the dead attempts leaks into recycled
            # slots if the engine is ever stepped again
            self.engine.abort_all()
        except Exception:  # noqa: BLE001 — engine may be the thing that died
            pass
        for fut, ctrl in dead:
            ctrl.complete_async(fut, error=error)
        for mig in migs:
            # still re-home the session: its queued futures died with
            # the engine, but follow-ups must not land here again
            try:
                mig([])
            except Exception:  # noqa: BLE001 — best-effort re-home
                pass
        return len(dead)

    def cancel_inflight(self, fid: str, instance_id: str = "") -> bool:
        """Withdraw one in-flight future's engine request (hedge-loser
        cancellation): the winning replica already resolved the future, so
        this engine's copy is pure waste — pull it from the wait queue or
        vacate its batch slot (reclaiming the slot and its KV pages), drop
        the completion callback, and release the session's ordering slot.
        Returns True if a request was actually withdrawn."""
        if instance_id and instance_id != self.engine.instance_id:
            return False
        with self._cv:
            rid = cancel_sid = None
            for r, (f, _c) in self._inflight.items():
                if f.fid == fid:
                    rid, cancel_sid = r, f.meta.session_id
                    break
            if rid is None:
                return False
            self._inflight.pop(rid, None)
            self._pending -= 1
            self._cv.notify_all()
        self.engine.cancel_request(rid)
        if cancel_sid:
            self._advance_session(cancel_sid)
        return True

    def on_replica_killed(self, instance_id: str) -> int:
        """Fault-injection hook (``runtime.kill_instance(..., hard=True)``):
        fail the in-flight work and stop the pump so no zombie completion
        resolves a retried future.  Returns the number of futures failed.
        ``EnginePool`` layers session recovery on top of this."""
        n = self.fail_inflight(InstanceDied(
            f"engine instance {instance_id} died"))
        self.stop()
        return n

    # ------------------------------------------------------------ submission
    def submit_future(self, fut: Future, controller,
                      method: "EngineMethod") -> None:
        if self.transcript is None:
            raise RuntimeError(
                "EngineBridge not attached to an agent instance; register "
                "the agent via repro.serving.bridge.register_engine_agent")
        if fut.available:
            return      # cancelled/resolved before launch: nothing to run
        sid = fut.meta.session_id
        if sid:
            with self._cv:
                if sid in self._session_active:
                    # a same-session call is in flight: its completion will
                    # submit this one (the prompt depends on its outcome)
                    self._session_q.setdefault(sid, deque()).append(
                        (fut, controller, method))
                    return
                self._session_active.add(sid)
        try:
            self._submit_now(fut, controller, method)
        except BaseException:
            if sid:
                self._advance_session(sid)
            raise

    def defer_until_idle(self, sid: str, fn: Callable) -> bool:
        """If ``sid`` has an in-flight engine call, arrange for ``fn(queued)``
        to run once it resolves — *before* any queued same-session call is
        submitted — where ``queued`` is the list of (future, controller,
        method) tuples still waiting.  Returns True if deferred, False if the
        session is idle here (the caller should act immediately).

        This is the in-flight-future safety rule of session migration: the
        running request finishes where it started; everything after it moves.
        """
        with self._cv:
            if sid in self._session_active:
                self._migrate_pending[sid] = fn
                return True
        return False

    def _advance_session(self, sid: str) -> None:
        """Previous call of ``sid`` settled: submit the next queued one."""
        while True:
            with self._cv:
                # deferred migration takes priority over queued calls, and
                # must be checked under the same lock that deactivates the
                # session (a migrate request landing between those two steps
                # would otherwise never fire)
                mig = self._migrate_pending.pop(sid, None)
                if mig is not None:
                    # hand the whole remaining session queue to the deferred
                    # migration; the session is no longer active here
                    remaining = list(self._session_q.pop(sid, ()))
                    self._session_active.discard(sid)
                else:
                    q = self._session_q.get(sid)
                    if not q:
                        self._session_active.discard(sid)
                        self._session_q.pop(sid, None)
                        return
                    fut, controller, method = q.popleft()
            if mig is not None:
                mig(remaining)
                return
            if fut.available:
                continue    # cancelled while parked here: skip, pop the next
            try:
                self._submit_now(fut, controller, method)
                return
            except BaseException as e:  # noqa: BLE001 — fail this call only
                controller.complete_async(fut, error=e)

    def _submit_now(self, fut: Future, controller,
                    method: "EngineMethod") -> None:
        args, kwargs = resolve_args(
            fut.args, fut.kwargs,
            stream_min=fut.meta.work_hint.get("stream_min_tokens"))
        vocab = self.engine.cfg.vocab_size
        new_tokens = [int(t) % vocab for t in method.encode(*args, **kwargs)]

        hint = fut.meta.work_hint
        max_new = int(hint.get("out_tokens", method.sampling.max_new_tokens))
        sampling = replace(method.sampling, max_new_tokens=max_new)
        if "temperature" in hint:
            # per-call sampling override (the HTTP front end forwards the
            # OpenAI request's temperature; 0 = greedy)
            sampling = replace(sampling,
                              temperature=float(hint["temperature"]))

        sid = fut.meta.session_id
        iid = self.engine.instance_id
        prompt: List[int] = new_tokens
        fallback: Optional[List[int]] = None
        if sid:
            history = self.transcript.tokens(sid)
            # keep context within the engine's sequence budget
            room = max(1, self.engine.max_seq - max_new - len(new_tokens) - 1)
            history = history[-room:]
            if history:
                cached = self.rt.kv_registry.expect_reuse(sid, iid)
                full = history + new_tokens
                if cached > 0:
                    # warm cache on this instance: send only the suffix; the
                    # engine appends it to the cached prefix.  If the pool
                    # evicted the pages since we checked, the engine falls
                    # back to prefilling the full context.
                    prompt, fallback = new_tokens, full
                else:
                    prompt = full

        req = Request.make(prompt, session_id=sid,
                           sampling=sampling, priority=fut.meta.priority,
                           now=self.rt.kernel.now(), fallback_prompt=fallback)
        # stamp the wall clock here, not in engine.submit: TTFT must count
        # from when the bridge hands the request over, even if the engine
        # is mid-step when the submission lands
        req.submitted_wall = time.monotonic()
        if fut.meta.deadline >= 0:
            # kernel time -> engine wall clock: same absolute instant, so a
            # hedged duplicate on a sibling engine expires simultaneously
            req.deadline_wall = (time.monotonic()
                                 + (fut.meta.deadline - self.rt.kernel.now()))
        # run-id fence: if the replica dies and the future is retried on a
        # sibling, a late completion from this engine must not resolve it
        run_id = fut._run_id

        def on_chunk(r: Request, chunk: List[int]) -> None:
            # per-step tokens -> incremental future updates.  Doubly fenced:
            # expect_run drops chunks from a superseded attempt (retry /
            # preemption), owner drops a hedge duplicate racing the stream's
            # first producer (hedges share the run id).  The first accepted
            # chunk stamps workload-level TTFT.
            if fut.append_chunk(chunk, now=self.rt.kernel.now(),
                                expect_run=run_id, owner=iid):
                self.rt.telemetry.on_first_output(fut.meta.request_id,
                                                  self.rt.kernel.now())

        def on_done(r: Request) -> None:
            with self._cv:
                self._pending -= 1
                self._inflight.pop(r.request_id, None)
                self._cv.notify_all()
            if not self.rt.claim_hedge_completion(fut.fid):
                # hedge loser finishing in the winner's resolution window:
                # the winning replica owns the transcript and the future;
                # just release this bridge's per-session slot
                if sid:
                    self._advance_session(sid)
                return
            if fut.meta.executor != self.engine.instance_id:
                # hedged duplicate completing first: attribute the win to
                # the replica that actually produced the value
                self.rt.futures.set_executor(fut, self.engine.instance_id)
            value = err = None
            if r.expired:
                # the engine preempted (or rejected) this request because
                # its deadline passed: non-retryable by design, and the
                # partial tokens never reach the transcript
                err = DeadlineExceeded(
                    f"request {r.request_id} exceeded its deadline on "
                    f"{self.engine.instance_id}")
                if sid:
                    self._advance_session(sid)
                controller.complete_async(fut, error=err, expect_run=run_id)
                return
            try:
                # decode FIRST: if make_value raises, the attempt failed and
                # its tokens must never reach the transcript — a retry would
                # re-send them as history (exactly-once would break)
                value = method.make_value(r, self.engine.instance_id)
                if sid and not fut.available and fut._run_id == run_id:
                    # the conversation advances by this call's new tokens +
                    # the generation; any prefilled history was already in
                    # the transcript (rebuild paths must not duplicate it).
                    # Skip if the future was already resolved elsewhere
                    # (failed/cancelled): the caller never saw these tokens.
                    # Cap at the engine's context budget — older tokens can
                    # never be prefilled again, so storing them only bloats
                    # state migration.
                    self.transcript.extend(sid, new_tokens + list(r.generated),
                                           max_tokens=self.engine.max_seq)
            except BaseException as e:  # noqa: BLE001 — fault reporting (§5)
                err = e
            if err is None:
                # reconcile the chunk log with the final tokens before
                # materializing: the common case appends the unstreamed
                # tail; a hedge race that let the loser claim the stream is
                # truncated and replaced with the winner's tokens, so
                # consumers always assemble exactly the completion value
                fut.seal_stream([int(t) for t in r.generated], owner=iid,
                                expect_run=run_id)
            # deactivate the session BEFORE resolving the future: a caller
            # that migrates the session the moment ``value()`` returns must
            # see it idle, not spuriously deferred behind a request that has
            # already finished.  The transcript is final at this point, so a
            # queued same-session call submitted here reads correct history.
            if sid:
                self._advance_session(sid)
            try:
                if err is None:
                    controller.complete_async(fut, value=value,
                                              expect_run=run_id)
                else:
                    controller.complete_async(fut, error=err,
                                              expect_run=run_id)
            except BaseException as e:  # noqa: BLE001 — fault reporting (§5)
                controller.complete_async(fut, error=e, expect_run=run_id)

        with self._cv:
            if self._stop or self._draining:
                raise RuntimeError("engine bridge is stopped")
            self._pending += 1
            self._inflight[req.request_id] = (fut, controller)
        try:
            # may raise EngineOverloaded: the bounded wait queue is full.
            # The exception travels back through launch() into the retry
            # ladder — a *retryable* failure (backoff locally, escalate to
            # the RetryPolicy for a reroute) instead of unbounded queueing.
            self.engine.submit_async(req, on_done, on_chunk)
        except RequestExpired as e:
            with self._cv:
                self._pending -= 1
                self._inflight.pop(req.request_id, None)
            # expired work is worthless: convert the engine's retryable
            # admission error into the runtime's terminal DeadlineExceeded
            # so the retry ladder never re-arms it
            raise DeadlineExceeded(str(e)) from e
        except BaseException:
            with self._cv:
                self._pending -= 1
                self._inflight.pop(req.request_id, None)
            raise
        with self._cv:
            self._cv.notify_all()

    # ------------------------------------------------------------ pump loop
    def _pump(self) -> None:
        while True:
            with self._cv:
                while not self._stop and self._pending == 0:
                    self._cv.wait(timeout=0.25)
                if self._stop:
                    return
            try:
                self.engine.step()
                self.engine.drain_completions()
            except BaseException as e:  # noqa: BLE001 — engine died
                self.fail_inflight(e)

    def telemetry(self) -> Dict[str, Any]:
        t = dict(self.engine.telemetry())
        t["kv_reuse"] = dict(self.rt.kv_registry.stats)
        t["resident_sessions"] = self.rt.kv_registry.instance_sessions(
            self.engine.instance_id)
        with self._cv:
            t["bridge_inflight"] = self._pending
        return t

    # ------------------------------------------------- admission telemetry
    def saturation_of(self, instance_id: str = "") -> float:
        """Wait-queue saturation of the backing engine (Router shed hook)."""
        return self.engine.saturation()

    def instance_metrics(self, instance_id: str = "") -> Dict[str, Any]:
        """Engine data-plane gauges merged into the controller's metrics
        mirror each publish, so the queue-depth watermark reaches the
        ``InstanceView`` the global policies act on (EngineMetrics →
        bridge → view)."""
        e = self.engine
        return {
            "engine_queue": len(e.queue),
            "engine_active": int(e._active_mask.sum()),
            "engine_saturation": e.saturation(),
            "engine_rejects": e.queue.rejected,
            "engine_shared_prefix_hits": e.metrics.shared_prefix_hits,
            "engine_shared_prefix_tokens": e.metrics.shared_prefix_tokens,
            "engine_tier": getattr(e, "tier", ""),
            "engine_expired": e.metrics.expired,
            "engine_spec_acceptance": e.metrics.spec_acceptance,
            "engine_decode_tokens_per_step":
                e.metrics.decode_tokens_per_step,
        }


@dataclass
class EngineMethod(EngineBackedMethod):
    """Leaf LLM method executed on a real ``InferenceEngine``.

    Drop-in peer of ``EmulatedMethod`` in an ``AgentSpec.methods`` dict:
    same stubs, same futures, same routing/migration machinery — but the
    call lands in a continuous-batching engine instead of a latency model.

    ``encode(*args, **kwargs)`` maps the stub call to prompt token ids;
    ``decode(request)`` maps the finished engine request to the future's
    value (defaults: :func:`hash_tokenize` / :class:`GenerationResult`).
    Per-call ``_hint={"out_tokens": n}`` overrides the generation length,
    mirroring how the emulated ``LLMLatency`` consumes hints.
    """

    bridge: EngineBridge
    sampling: SamplingParams = field(
        default_factory=lambda: SamplingParams(max_new_tokens=16))
    encode: Optional[Callable[..., List[int]]] = None
    decode: Optional[Callable[[Request], Any]] = None

    def __post_init__(self) -> None:
        if self.encode is None:
            vocab = self.bridge.engine.cfg.vocab_size
            self.encode = lambda *a, **kw: hash_tokenize(
                " ".join(str(x) for x in a), vocab)

    def capacity(self) -> int:
        e = self.bridge.engine
        if e.max_queue:
            # bounded admission: overshoot slots+queue so the engine's
            # admission bound — not an invisible controller-side buffer —
            # is what says no.  Overflow fails fast through the retry
            # ladder (backoff / reroute / shed) instead of parking
            # upstream until it times out, which is exactly the unbounded
            # pathology the bound exists to prevent.
            return e.max_batch * 2 + e.max_queue
        # keep the wait queue primed one batch deep so freed slots refill
        # without a controller round-trip
        return e.max_batch * 2

    def launch(self, batch: List[Future], controller) -> None:
        for fut in batch:
            try:
                self.bridge.submit_future(fut, controller, self)
            except BaseException as e:  # noqa: BLE001 — bad encode/args must
                # fail only this future, not batch-mates already submitted
                controller.complete_async(fut, error=e)

    def make_value(self, req: Request, engine_id: str) -> Any:
        if self.decode is not None:
            return self.decode(req)
        return GenerationResult(
            request_id=req.request_id, session_id=req.session_id,
            tokens=list(req.generated), prompt_tokens=len(req.prompt),
            prefix_reused_tokens=req.prefix_reused_tokens,
            engine_id=engine_id)


def register_engine_agent(runtime, name: str, engine: InferenceEngine, *,
                          methods: Tuple[str, ...] = ("generate",),
                          sampling: Optional[SamplingParams] = None,
                          encode: Optional[Callable[..., List[int]]] = None,
                          decode: Optional[Callable[[Request], Any]] = None,
                          node: Optional[str] = None,
                          resources: Optional[Dict[str, float]] = None):
    """Register a real-engine-backed agent type on ``runtime``.

    Returns the stub.  The engine becomes the single instance of the agent
    type: its telemetry, KV residency and managed state are all tagged with
    the provisioned NALAR instance id, so the Router's cache-locality rule
    (§4.3.2) and session migration see one coherent component.

    Requires ``NalarRuntime(simulate=False)``: engine completions arrive in
    wall-clock time, which the virtual-time SimKernel cannot await.
    """
    from ..core.clock import RealTimeKernel
    if not isinstance(runtime.kernel, RealTimeKernel):
        raise RuntimeError(
            "engine-backed agents need a real-time runtime; construct "
            "NalarRuntime(simulate=False) (the SimKernel's virtual time "
            "cannot wait on wall-clock engine completions)")

    bridge = EngineBridge(runtime, engine, agent_type=name)
    m = EngineMethod(bridge=bridge,
                     sampling=sampling or SamplingParams(max_new_tokens=16),
                     encode=encode, decode=decode)
    spec = AgentSpec(
        name=name,
        methods={mn: m for mn in methods},
        directives=Directives(max_instances=1, min_instances=1,
                              uses_managed_state=True,
                              resources=resources or {}))
    node = node or next(iter(runtime.nodes))
    stub = runtime.register_agent(spec, nodes=[node], instances=1)
    iid = runtime.instances_of_type(name)[0]
    bridge.attach(iid, node)
    runtime.engine_backends[name] = bridge
    return stub
