"""JAX inference engine: slot-based continuous batching over any zoo model.

The engine is the "LLM serving backend" of the reproduction (the vLLM role
in the paper's stack).  One engine instance = one NALAR agent instance; the
engine exports queue/latency telemetry and consumes KVRegistry hints via its
cache pool, which is precisely the LMCache-hook integration of §4.3.2.

Execution model:
  * ``max_batch`` slots share a stacked per-slot cache (model.init_cache);
  * admission pulls from a bounded, heap-ordered priority wait queue; a new
    request either resumes its session's cache from the pool (prefix reuse —
    the paper's motivating win for session stickiness/migration) or starts a
    **chunked prefill**: the prompt is admitted into a blank cache row and
    consumed ``prefill_chunk`` tokens per step, piggybacked onto the same
    batched decode the active slots run — a long prompt therefore never
    head-of-line-blocks the batch the way the legacy monolithic (left-padded
    bucket) prefill does, and no pad token ever enters the KV cache;
  * each ``step()`` runs one batched step: every decoding slot advances one
    token while prefilling slots consume up to a chunk of prompt (masked
    sub-steps over the shared jitted decode fn);
  * a bounded wait queue (``max_queue``) rejects overflow with
    ``EngineOverloaded`` — backpressure the bridge turns into a retryable
    failure instead of unbounded queue growth — and exports a saturation
    watermark so routers/policies shed load before collapse;
  * finished sessions write their cache back to the pool so follow-up
    requests in the same session skip recomputation;
  * **paged-native decode** (default where supported): the pool pages ARE
    the decode cache — each step feeds per-slot page tables into the model
    and scatters new K/V straight into pool pages (COW-privatized first if
    shared), so admission, resume, eviction and finish move zero cache
    bytes and the dense per-slot K/V arrays are never allocated.
    ``paged_decode=False`` restores the dense gather/write-back path.
"""

from __future__ import annotations

import threading
import time
import zlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.model import Model
from .batching import (EngineOverloaded, Request, RequestExpired, WaitQueue,
                       bucket_len)
from .kv_cache import PagedKVPool, StateCachePool
from .sampler import SamplingParams, sample, speculative_verify

# model families whose decode step, run token-by-token from a blank cache
# row, is exactly prefill (causal attention / recurrent state).  Encoder-
# decoder ("audio") models chunk too (``_chunked_for`` special-cases them:
# one ``encode_cross`` pass supplies the cross-attention memory first), but
# stay OUT of this tuple — it also gates prefix sharing, and audio decoder
# K/V depends on the frames, so token-identity never implies K/V-identity.
_CHUNKABLE_FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm")


@dataclass
class EngineMetrics:
    queued: int = 0
    active: int = 0
    completed: int = 0
    decode_steps: int = 0
    prefills: int = 0
    prefix_hits: int = 0
    # cross-session sharing: cold sessions admitted onto another session's
    # indexed prefix pages (suffix-only prefill)
    shared_prefix_hits: int = 0
    shared_prefix_tokens: int = 0
    tokens_generated: int = 0
    prefill_tokens: int = 0
    admission_rejects: int = 0
    # resumes refused because the restored cache would not fit the slot
    # (previously a silent None -> cold rebuild)
    resume_overflows: int = 0
    # resumes refused because the family cannot restore from the pool
    # (encoder-decoder: cross-attention memory is not poolable; previously
    # the dense path silently resumed with zeroed xk/xv)
    resume_unsupported: int = 0
    # paged-native admissions/steps aborted because the pool could not
    # provide pages (all residents protected or pinned)
    paged_append_failures: int = 0
    # speculative decoding: rounds that ran a draft, draft tokens proposed,
    # and tokens the verifier accepted
    spec_rounds: int = 0
    spec_proposed: int = 0
    spec_accepted: int = 0
    # requests dropped because their deadline passed: rejected at admission
    # (push/pop) or preempted mid-decode with slot + KV pages reclaimed
    expired: int = 0

    @property
    def spec_acceptance(self) -> float:
        return (self.spec_accepted / self.spec_proposed
                if self.spec_proposed else 0.0)

    @property
    def decode_tokens_per_step(self) -> float:
        """Acceptance-weighted decode throughput: > 1 means speculation is
        paying (every accepted draft token rides a step for free)."""
        return (self.tokens_generated / self.decode_steps
                if self.decode_steps else 0.0)


def _cache_slot_axis(key: str) -> int:
    return 0 if key == "pos" else 1


def set_slot(cache: dict, slot: int, row: dict) -> dict:
    """Insert a single sequence's cache (batch dim 1) into batch slot.

    Row caches produced by bucketed prefill can be shorter in the seq dim
    than the slot cache; they are zero-padded at the end (consistent with
    the ring layout: prefill caches are unrolled when S <= window).
    """
    out = {}
    for k, v in cache.items():
        ax = _cache_slot_axis(k)
        r = row[k]
        r = jnp.squeeze(r, axis=ax) if r.ndim == v.ndim else r
        target = tuple(s for i, s in enumerate(v.shape) if i != ax)
        if tuple(r.shape) != target:
            pads = [(0, t - s) for s, t in zip(r.shape, target)]
            if any(p[1] < 0 for p in pads):
                raise ValueError(f"row cache leaf {k}: {r.shape} exceeds "
                                 f"slot shape {target}")
            r = jnp.pad(r, pads)
        idx = [slice(None)] * v.ndim
        idx[ax] = slot
        out[k] = v.at[tuple(idx)].set(r)
    return out


def get_slot(cache: dict, slot: int) -> dict:
    out = {}
    for k, v in cache.items():
        ax = _cache_slot_axis(k)
        out[k] = jnp.expand_dims(jnp.take(v, slot, axis=ax), axis=ax)
    return out


class InferenceEngine:
    def __init__(self, model: Model, params: dict, *, max_batch: int = 8,
                 max_seq: int = 512, instance_id: str = "engine:0",
                 kv_registry=None, pool_pages: int = 0,
                 page_size: int = 64, rng_seed: int = 0,
                 prefill_chunk: int = 8, max_queue: int = 0,
                 queue_watermark: float = 0.75,
                 finished_cap: int = 8192,
                 prefix_sharing: bool = True,
                 paged_decode: bool = True,
                 paged_kernel: Optional[bool] = None,
                 draft_model: Optional[Model] = None,
                 draft_params: Optional[dict] = None,
                 spec_k: int = 3,
                 spec_min_accept: float = 0.25,
                 spec_warmup: int = 24,
                 tier: str = "") -> None:
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.instance_id = instance_id
        self.kv_registry = kv_registry
        self.metrics = EngineMetrics()
        # optional fault injector (repro.serving.chaos.ChaosConfig-driven);
        # when set, step() calls chaos.before_step(engine) outside the lock
        self.chaos: Optional[Any] = None
        # prompt tokens consumed per slot per step while prefilling;
        # 0 = legacy monolithic bucket prefill at admission
        self.prefill_chunk = int(prefill_chunk)
        self.max_queue = int(max_queue)
        # saturation fraction above which routers should shed new sessions
        # to a sibling replica (surfaced via telemetry(); advisory only)
        self.queue_watermark = queue_watermark
        self.finished_cap = int(finished_cap)
        self.queue = WaitQueue(maxsize=self.max_queue)
        self._rng = jax.random.PRNGKey(rng_seed)     # base of request streams
        self._lock = threading.RLock()
        # completion plumbing has its own lock: submissions and drains must
        # never serialize behind a long step (a monolithic prefill used to
        # block submit_async for its whole duration)
        self._done_lock = threading.Lock()

        # per-slot state
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.cache = model.init_cache(max_batch, max_seq)
        self._active_mask = np.zeros(max_batch, bool)
        # slot -> prompt tokens not yet consumed (resumed suffixes and
        # chunked prefills); always cleared when the slot is vacated
        self._pending_prompt: Dict[int, List[int]] = {}
        # request_id -> private PRNG stream (stochastic sampling only)
        self._req_rng: Dict[str, jax.Array] = {}
        self._blank_row_cache: Optional[dict] = None

        # session cache pool (paged KV for attention families, O(1) state
        # for ssm/hybrid) + NALAR hint hook
        if self.cfg.family == "ssm":
            self.pool: Any = StateCachePool(self.cfg)
        elif self.cfg.family == "hybrid":
            self.pool = StateCachePool(self.cfg)
        else:
            n_pages = pool_pages or (max_batch * (max_seq // page_size + 1) * 2)
            self.pool = PagedKVPool(self.cfg, n_pages=n_pages,
                                    page_size=page_size)
        if kv_registry is not None:
            kv_registry.register_hook(instance_id, self.pool.on_hint)

        # cross-session prefix sharing: admission/warm_session consult the
        # pool's radix index before prefilling.  Valid only when a cache
        # position maps 1:1 to a token prefix position — paged pools,
        # causal-chunkable families, no sliding-window ring wraparound.
        self.prefix_sharing = bool(prefix_sharing)
        W = self.cfg.sliding_window
        self._prefix_share_ok = (
            self.prefix_sharing
            and isinstance(self.pool, PagedKVPool)
            and self.cfg.family in _CHUNKABLE_FAMILIES
            and (not W or self.max_seq <= W))
        # paged-native decode (the tentpole): the KV pool IS the decode
        # cache.  The per-slot dense k/v arrays are dropped entirely; each
        # step consumes per-slot page tables and scatters new K/V straight
        # into pool pages, so admission/eviction/finish move no cache bytes
        # (``gather_contiguous`` leaves the hot path).  Windowed configs
        # qualify only when the ring never wraps (max_seq <= window, the
        # same condition as prefix sharing) — slot == position then, so the
        # linear page layout matches the ring layout bitwise.
        self.paged_decode = bool(paged_decode)
        self._paged = (self.paged_decode
                       and isinstance(self.pool, PagedKVPool)
                       and model.decode_chunk_paged is not None
                       and (not W or self.max_seq <= W))
        # Pallas paged-attention kernel instead of the bitwise-identical
        # gathered-dense attention inside the paged step (auto-on on TPU;
        # near-identical numerics, not bitwise)
        self._paged_kernel = (bool(paged_kernel) if paged_kernel is not None
                              else jax.default_backend() == "tpu")
        # dense per-slot cache length (what self.cache["k"].shape[2] was)
        self._slot_C = min(self.max_seq, W) if W else self.max_seq
        # slot -> pool session key the slot decodes into (paged mode only;
        # anonymous requests get a synthetic key released at vacate)
        self._slot_sid: Dict[int, str] = {}
        if self._paged:
            self.cache = {key: v for key, v in self.cache.items()
                          if key not in ("k", "v")}
            self._max_pages = self.pool.pages_needed(self.max_seq)
        # slot -> token ids whose K/V occupy the slot's cache positions so
        # far (None = unknown provenance, the finish write stays opaque)
        self._slot_tokens: Dict[int, Optional[List[int]]] = {}
        # lazily jitted batch-1 fns for suffix-only warm extension
        self._extend_chunk: Optional[Callable] = None
        self._extend_step: Optional[Callable] = None

        def _masked_decode(params, tokens, cache, mask):
            # one batched decode where only masked-in slots advance: the
            # cache (and pos) of a masked-out slot is untouched, so prompt
            # chunks and single decode tokens share one compiled step
            logits, new = model.decode_step(params, tokens, cache)
            out = {}
            for k in new:
                ax = _cache_slot_axis(k)
                shp = [1] * new[k].ndim
                shp[ax] = new[k].shape[ax]
                out[k] = jnp.where(mask.reshape(shp), new[k], cache[k])
            return logits, out

        self._masked_decode = jax.jit(_masked_decode)
        # fused chunk step (transformer families): a whole prompt chunk is
        # one forward instead of prefill_chunk sequential decodes.  Two
        # compiled shapes only: T=1 (decode-only steps) and T=prefill_chunk.
        self._decode_chunk = (jax.jit(model.decode_chunk)
                              if model.decode_chunk is not None else None)
        # paged-native fused step: chunked prefill + decode + per-slot
        # sampling prep in ONE jit over (slim cache, pool pages, page
        # tables).  Only the [B,V] next-token rows and the greedy argmax
        # cross the host boundary — the [B,T,V] logits never leave device.
        self._paged_step: Optional[Callable] = None
        if self._paged:
            paged_fn = model.decode_chunk_paged
            _max_seq = self.max_seq
            _kernel = self._paged_kernel

            def _paged_chunk(params, toks, valid, cache, kp, vp, pt):
                logits, cache, kp, vp = paged_fn(
                    params, toks, valid, cache, kp, vp, pt,
                    max_seq=_max_seq, kernel=_kernel)
                rows = jnp.take_along_axis(
                    logits, jnp.maximum(valid - 1, 0)[:, None, None],
                    axis=1)[:, 0]                               # [B,V]
                greedy = jnp.argmax(rows, axis=-1)
                return rows, greedy, cache, kp, vp

            # donate the pool arrays on TPU so the step updates them in
            # place (CPU donation is a no-op and only warns)
            donate = (4, 5) if jax.default_backend() == "tpu" else ()
            self._paged_step = jax.jit(_paged_chunk, donate_argnums=donate)

        # speculative decoding (paged plane only): a small-tier draft
        # proposes spec_k tokens per decode round; the same paged chunk
        # step verifies all k+1 positions at once.  The spec variant of the
        # step jit returns the full [B,T,V] logits plus per-position argmax
        # — the verifier needs every row, not just the last valid one.
        self.tier = str(tier)
        self.spec_k = int(spec_k)
        self.spec_min_accept = float(spec_min_accept)
        self.spec_warmup = int(spec_warmup)
        self._spec = None
        self._paged_step_all: Optional[Callable] = None
        # pool-session -> [proposed, accepted]: the acceptance ledger the
        # adaptive controller reads to disable speculation per session
        self._spec_ledger: Dict[str, List[int]] = {}
        self._spec_off: set = set()
        # slots whose draft stream mirrors the target's consumed tokens
        # (unknown provenance = no speculation for that slot)
        self._spec_ok: set = set()
        if draft_model is not None and self._paged and self.spec_k > 0:
            if draft_model.cfg.vocab_size != self.cfg.vocab_size:
                raise ValueError(
                    "draft/target vocab mismatch: "
                    f"{draft_model.cfg.vocab_size} vs {self.cfg.vocab_size}")
            from .speculative import DraftEngine
            self._spec = DraftEngine(draft_model, draft_params,
                                     max_batch=max_batch, max_seq=max_seq)
            paged_fn = model.decode_chunk_paged
            _max_seq = self.max_seq
            _kernel = self._paged_kernel

            def _paged_chunk_all(params, toks, valid, cache, kp, vp, pt):
                logits, cache, kp, vp = paged_fn(
                    params, toks, valid, cache, kp, vp, pt,
                    max_seq=_max_seq, kernel=_kernel)
                greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return logits, greedy, cache, kp, vp

            donate = (4, 5) if jax.default_backend() == "tpu" else ()
            self._paged_step_all = jax.jit(_paged_chunk_all,
                                           donate_argnums=donate)
        # lazily jitted encoder pass for chunked encoder-decoder admission
        self._encode_cross: Optional[Callable] = None
        self._prefill_cache: Dict[int, Callable] = {}

        # async completion plumbing (NALAR bridge): request_id -> callback,
        # plus a list of finished requests awaiting drain.  Callbacks fire
        # outside the step lock so they may re-enter submit().
        self._callbacks: Dict[str, Callable[[Request], None]] = {}
        self._finished: List[Request] = []
        # token-streaming plumbing: per-request chunk callbacks plus the
        # pending (request, chunk) pairs each step emitted.  Chunks are
        # buffered under _done_lock at the end of step()/_finish_slot and
        # delivered by drain_completions — off the step lock, before the
        # completion callback of the same request.
        self._stream_cbs: Dict[str, Callable[[Request, List[int]], None]] = {}
        self._stream_pending: List[tuple] = []

    # ----------------------------------------------------------- submission
    def submit(self, req: Request) -> str:
        """Queue ``req``.  Raises :class:`EngineOverloaded` when the bounded
        wait queue is at capacity (backpressure — callers retry or shed) and
        :class:`RequestExpired` when the request's deadline already passed."""
        if req.submitted_wall < 0:
            req.submitted_wall = time.monotonic()
        try:
            self.queue.push(req)
        except EngineOverloaded:
            self.metrics.admission_rejects += 1
            raise
        except RequestExpired:
            self.metrics.expired += 1
            raise
        return req.request_id

    def submit_async(self, req: Request,
                     on_done: Optional[Callable[[Request], None]] = None,
                     on_chunk: Optional[
                         Callable[[Request, List[int]], None]] = None) -> str:
        """Queue ``req``; ``on_done(req)`` fires from ``drain_completions``
        after the request finishes (the NALAR future-resolution hook).
        ``on_chunk(req, tokens)`` fires from the same drain for every batch
        of tokens the request's slot emitted since the previous drain —
        in order, and always before the request's ``on_done``."""
        with self._done_lock:
            if on_done is not None:
                self._callbacks[req.request_id] = on_done
            if on_chunk is not None:
                self._stream_cbs[req.request_id] = on_chunk
        try:
            return self.submit(req)
        except BaseException:
            with self._done_lock:       # rejected: no completion will fire
                self._callbacks.pop(req.request_id, None)
                self._stream_cbs.pop(req.request_id, None)
            raise

    def poll_finished(self) -> List[Request]:
        """Requests finished since the last poll/drain (no callbacks fired)."""
        with self._done_lock:
            out, self._finished = self._finished, []
        return out

    def drain_completions(self) -> int:
        """Fire stream-chunk then completion callbacks for work the step
        loop emitted.  Called by the bridge pump thread after each step(),
        outside the engine lock — chunk callbacks for a request always fire
        before (and never after) its completion callback."""
        with self._done_lock:
            chunks, self._stream_pending = self._stream_pending, []
            ccbs = [(r, c, self._stream_cbs.get(r.request_id))
                    for r, c in chunks]
            done, self._finished = self._finished, []
            cbs = [(r, self._callbacks.pop(r.request_id, None)) for r in done]
            for r in done:
                self._stream_cbs.pop(r.request_id, None)
        for req, chunk, ccb in ccbs:
            if ccb is not None:
                ccb(req, chunk)
        for req, cb in cbs:
            if cb is not None:
                cb(req)
        return len(cbs)

    def bind_registry(self, kv_registry, instance_id: str) -> None:
        """(Re)bind this engine to a NALAR runtime identity: the engine's
        telemetry and cache-pool hints are tagged with the agent-instance id
        so the runtime's Router and KVRegistry see one coherent name."""
        self.instance_id = instance_id
        self.kv_registry = kv_registry
        if kv_registry is not None:
            kv_registry.register_hook(instance_id, self.pool.on_hint)

    def generate(self, prompt, session_id: str = "",
                 sampling: Optional[SamplingParams] = None,
                 **extras) -> Request:
        """Synchronous helper: submit + run until this request finishes."""
        req = Request.make(prompt, session_id=session_id, sampling=sampling,
                           now=time.monotonic(), **extras)
        self.submit(req)
        while not req.finished:
            self.step()
        return req

    # ------------------------------------------------------------ admission
    def saturation(self) -> float:
        """Wait-queue depth as a fraction of capacity (0.0 if unbounded)."""
        return self.queue.saturation()

    def overloaded(self) -> bool:
        """Above the shed watermark: routers should prefer a sibling."""
        return bool(self.max_queue) and self.saturation() >= self.queue_watermark

    def _prefill(self, req: Request, align: str = "left"):
        """Monolithic bucketed prefill (legacy path + migration replay).

        ``align="right"`` places the prompt at the start of the bucket:
        under causal attention the trailing pads never contaminate the
        first ``len(prompt)`` cache positions, so callers that only need
        the cache (``warm_session``) get an exact-token prefix.  The
        left-aligned default keeps the final position's logits real, at
        the cost of pad positions entering the cache (the legacy
        exposure chunked prefill removes).
        """
        S = len(req.prompt)
        bucket = min(bucket_len(S), self.max_seq)
        toks = np.zeros((1, bucket), np.int32)
        if align == "right":
            toks[0, :S] = req.prompt
        else:
            toks[0, -S:] = req.prompt      # left-pad so last position is real
        batch = {"tokens": jnp.asarray(toks)}
        for k, v in req.extras.items():
            batch[k] = jnp.asarray(v[None] if v.ndim == 2 else v)
        if bucket not in self._prefill_cache:
            self._prefill_cache[bucket] = jax.jit(self.model.prefill)
        logits, row_cache = self._prefill_cache[bucket](self.params, batch)
        self.metrics.prefills += 1
        self.metrics.prefill_tokens += S
        return logits, row_cache

    def _try_resume(self, req: Request):
        """Prefix reuse: restore this session's cache from the pool.

        Refusals are explicit and counted (``resume_overflows`` /
        ``resume_unsupported``) — a ``None`` always means the caller
        rebuilds the context cold.  In paged mode a successful resume moves
        no bytes at all: the slot simply adopts the session's pages and the
        sentinel ``("paged", tokens)`` is returned instead of a dense row.
        """
        if isinstance(self.pool, StateCachePool):
            payload = self.pool.load(req.session_id)
            if payload is None:
                return None
            state, tokens = payload
            return state, tokens
        if self.cfg.family == "audio":
            # decoder self-attention K/V is poolable, but the cross-
            # attention memory (xk/xv) is not: a resumed slot would cross-
            # attend zeros.  The dense path used to do exactly that
            # silently; refuse and count instead.
            sp = self.pool.session(req.session_id)
            if sp is not None and sp.pages:
                self.metrics.resume_unsupported += 1
            return None
        if self._paged:
            sp = self.pool.session(req.session_id)
            if sp is None or not sp.pages or sp.tokens <= 0:
                return None
            if sp.tokens > self.max_seq:
                self.metrics.resume_overflows += 1
                return None
            return "paged", sp.tokens
        got = self.pool.gather_contiguous(req.session_id, self.max_seq)
        if got is None:
            return None
        k, v, tokens = got
        C = self._slot_C
        pad = C - k.shape[1]
        if pad < 0:
            self.metrics.resume_overflows += 1
            return None
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))[:, None]
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))[:, None]
        row = {key: None for key in self.cache}
        row["k"], row["v"] = k, v
        row["pos"] = jnp.asarray([tokens], jnp.int32)
        for key in self.cache:
            if row.get(key) is None:   # xk/xv etc.: zeros
                ax = _cache_slot_axis(key)
                shp = list(self.cache[key].shape)
                shp[ax] = 1
                row[key] = jnp.zeros(shp, self.cache[key].dtype)
        return row, tokens

    def _blank_row(self) -> dict:
        """Zeroed single-slot cache row for chunked-prefill admission
        (recurrent families accumulate state unconditionally, so a recycled
        slot must never start from its previous occupant's row)."""
        if self._blank_row_cache is None:
            row = {}
            for k, v in self.cache.items():
                ax = _cache_slot_axis(k)
                shp = tuple(s for i, s in enumerate(v.shape) if i != ax)
                row[k] = jnp.zeros(shp, v.dtype)
            self._blank_row_cache = row
        return self._blank_row_cache

    def _paged_row(self, tokens: int) -> dict:
        """Slim cache row for a paged-native admission: position only — the
        K/V lives in the session's pool pages."""
        row = dict(self._blank_row())
        row["pos"] = jnp.asarray(tokens, jnp.int32)
        return row

    def _resumed_slot_tokens(self, req: Request,
                             tokens: int) -> Optional[List[int]]:
        """Token provenance of a resumed slot: the pool session's ids, when
        they exactly describe the restored cache positions."""
        if not self._prefix_share_ok:
            return None
        sp = self.pool.session(req.session_id)
        if (sp is not None and sp.tokens == tokens
                and len(sp.token_ids) == sp.tokens):
            return list(sp.token_ids)
        return None

    def _chunked_for(self, req: Request) -> bool:
        if self.prefill_chunk <= 0:
            return False
        if self.cfg.family == "audio":
            # encoder-decoder: one encoder pass computes the cross-attn
            # memory (exactly the bytes prefill would), then the decoder
            # prompt chunks like any causal family
            return (set(req.extras) == {"frames"}
                    and self.model.encode_cross is not None
                    and self.model.decode_chunk is not None)
        if req.extras:
            return False
        return self.cfg.family in _CHUNKABLE_FAMILIES

    def _request_key(self, req: Request) -> jax.Array:
        sp = req.sampling
        salt = (sp.seed if sp.seed is not None
                else zlib.crc32(req.request_id.encode()))
        return jax.random.fold_in(self._rng, int(salt) & 0x7FFFFFFF)

    def _sample_slot(self, req: Request, logits, row: int,
                     greedy: np.ndarray) -> int:
        """Sample one token for ``row`` with the request's *own* params,
        exactly once.  Greedy requests take the batch argmax and burn no
        RNG; stochastic requests draw from their private per-request
        stream, so batch composition never perturbs a request's samples."""
        sp = req.sampling
        if sp.temperature <= 0.0:
            return int(greedy[row])
        key = self._req_rng.get(req.request_id)
        if key is None:
            key = self._request_key(req)
        key, sub = jax.random.split(key)
        self._req_rng[req.request_id] = key
        return int(np.asarray(sample(logits[row:row + 1], sp, sub))[0])

    def _admit(self) -> None:
        for slot in range(self.max_batch):
            if self._active_mask[slot]:
                continue
            req = self.queue.pop_next()
            if req is None:
                return
            now = time.monotonic()
            while 0 <= req.deadline_wall <= now:
                # expired while waiting: never occupy a slot.  Finished
                # with expired=True so the bridge callback still fires and
                # can resolve the future DeadlineExceeded.
                req.expired = True
                req.finished = True
                req.finished_at = now
                self.metrics.expired += 1
                with self._done_lock:
                    self._finished.append(req)
                req = self.queue.pop_next()
                if req is None:
                    return
            if (self._paged and req.session_id
                    and req.session_id in self._slot_sid.values()):
                # the session's pages are already the live in-place write
                # target of an active slot; a second concurrent appender
                # would corrupt them.  Defer until that slot finishes.
                self.queue.push(req)
                return
            W = self.cfg.sliding_window
            if self._paged:
                req.decode_path = "paged"
            elif W and self.max_seq > W and self._decode_chunk is not None:
                # windowed config whose ring wraps (max_seq > window): the
                # paged plane cannot serve it (linear page layout != ring
                # layout), so it stays on the dense ring fallback plane
                req.decode_path = "dense-ring"
            elif self._decode_chunk is not None:
                req.decode_path = "fused"
            else:
                req.decode_path = "masked"
            resumed = None
            if req.session_id:
                resumed = self._try_resume(req)
            if resumed is not None and not isinstance(self.pool, PagedKVPool):
                # SSM/hybrid: resumed state + run prompt incrementally is
                # equivalent to prefill; simplest correct path: prefill anyway
                resumed = None
            if resumed is not None:
                _row, cached = resumed
                if cached + len(req.prompt) > self.max_seq - 1:
                    # the resumed suffix would run past the slot's cache
                    # capacity mid-prompt; rebuild the (bounded) full
                    # context cold instead of overflowing the ring
                    self.metrics.resume_overflows += 1
                    resumed = None
            if resumed is None and req.fallback_prompt is not None:
                # The caller sent only a continuation suffix expecting a warm
                # session cache, but the cache is cold (evicted or migrated):
                # rebuild the full context in one prefill instead.
                req.prompt = req.fallback_prompt
            if len(req.prompt) > self.max_seq - 1:
                req.prompt = req.prompt[-(self.max_seq - 1):]
            if (resumed is None and req.session_id and not req.extras
                    and self._prefix_share_ok and len(req.prompt) > 1):
                # cold session: another session may have indexed this
                # prompt's prefix.  Adopt the shared pages and feed only
                # the novel suffix (keep >= 1 token so the final position's
                # logits are computed by a real forward).
                ids = [int(t) for t in req.prompt]
                matched = self.pool.acquire_prefix(req.session_id, ids[:-1],
                                                   now=now)
                if matched > 0:
                    resumed = self._try_resume(req)
                    if resumed is None:    # defensive: capacity race
                        self.pool.release(req.session_id)
                    else:
                        self.metrics.shared_prefix_hits += 1
                        self.metrics.shared_prefix_tokens += matched
                        req.prompt = req.prompt[matched:]
            if resumed is not None:
                row_cache, tokens = resumed
                req.prefix_reused_tokens = tokens
                self.metrics.prefix_hits += 1
                # feed the prompt as additional decode steps (short suffix)
                if self._paged and row_cache == "paged":
                    # zero-copy resume: the slot decodes straight into the
                    # session's resident pages (shared prefix tails are
                    # privatized lazily by begin_append's COW)
                    self.pool.protect(req.session_id)
                    self._slot_sid[slot] = req.session_id
                    self.cache = set_slot(self.cache, slot,
                                          self._paged_row(tokens))
                else:
                    self.cache = set_slot(self.cache, slot, row_cache)
                self._pending_prompt[slot] = [int(t) for t in req.prompt]
                self._slot_tokens[slot] = self._resumed_slot_tokens(req, tokens)
                if self._spec is not None:
                    # the draft can only shadow this slot if the resumed
                    # positions have exact token provenance to replay
                    self._spec.reset(slot)
                    ids = self._slot_tokens[slot]
                    if ids is not None and len(ids) == tokens:
                        self._spec.observe(slot, ids)
                        self._spec_ok.add(slot)
            elif self._chunked_for(req):
                # chunked prefill: blank row now, prompt consumed by step()
                # in prefill_chunk-sized pieces piggybacked on decode
                row = self._blank_row()
                if self.cfg.family == "audio":
                    frames = req.extras["frames"]
                    frames = jnp.asarray(frames[None] if frames.ndim == 2
                                         else frames)
                    if self._encode_cross is None:
                        self._encode_cross = jax.jit(self.model.encode_cross)
                    xk, xv = self._encode_cross(self.params, frames)
                    row = dict(row)
                    row["xk"], row["xv"] = xk[:, 0], xv[:, 0]
                if self._paged:
                    sid = req.session_id or f"__anon:{req.request_id}"
                    if req.session_id:
                        # stale pages from a refused resume would misplace
                        # the first in-place append: start cold
                        self.pool.release(sid)
                    self.pool.protect(sid)
                    self._slot_sid[slot] = sid
                self.cache = set_slot(self.cache, slot, row)
                self._pending_prompt[slot] = [int(t) for t in req.prompt]
                self._slot_tokens[slot] = [] if self._prefix_share_ok else None
                if self._spec is not None:
                    # chunked prefill feeds the whole prompt through the
                    # step loop, which mirrors each chunk into the draft
                    self._spec.reset(slot)
                    self._spec_ok.add(slot)
                self.metrics.prefills += 1
                self.metrics.prefill_tokens += len(req.prompt)
            else:
                logits, row_cache = self._prefill(req)
                greedy = np.asarray(jnp.argmax(logits, axis=-1))
                tok = self._sample_slot(req, logits, 0, greedy)
                req.generated.append(tok)
                # TTFT: the first token exists *now*, after the prefill
                # compute — not at admission time
                req.first_token_at = time.monotonic()
                S = len(req.prompt)
                bucket = min(bucket_len(S), self.max_seq)
                share = self._prefix_share_ok and not req.extras
                # left-aligned bucket prefill: pad token 0's K/V enters
                # the leading positions and is part of the provenance
                ids = ([0] * (bucket - S) + [int(t) for t in req.prompt]
                       if share else None)
                if self._paged:
                    sid = req.session_id or f"__anon:{req.request_id}"
                    tokens = int(np.asarray(row_cache["pos"]).reshape(-1)[0])
                    if req.session_id:
                        self.pool.release(sid)
                    if tokens > self.max_seq or not self.pool.write_session(
                            sid, row_cache["k"][:, 0, :tokens],
                            row_cache["v"][:, 0, :tokens], tokens, now,
                            token_ids=ids):
                        # pool exhausted (residents all protected/pinned):
                        # deliver what we have instead of wedging the slot
                        self.metrics.paged_append_failures += 1
                        self.metrics.tokens_generated += 1
                        req.finished = True
                        req.finished_at = time.monotonic()
                        self.metrics.completed += 1
                        self._emit_stream(req)
                        with self._done_lock:
                            self._finished.append(req)
                        continue
                    self.pool.protect(sid)
                    self._slot_sid[slot] = sid
                    row = {key: v for key, v in row_cache.items()
                           if key not in ("k", "v")}
                    self.cache = set_slot(self.cache, slot, row)
                else:
                    self.cache = set_slot(self.cache, slot, row_cache)
                self._slot_tokens[slot] = list(ids) if ids is not None else None
                if self._spec is not None and self._paged:
                    # bucketed prefill: the cache holds the left-padded
                    # bucket, reconstructible whether or not it was indexed
                    full = ([0] * (bucket - S) + [int(t) for t in req.prompt])
                    self._spec.reset(slot)
                    if tokens == len(full):
                        self._spec.observe(slot, full)
                        self._spec_ok.add(slot)
                self.metrics.tokens_generated += 1
                if (len(req.generated) >= req.sampling.max_new_tokens
                        or tok == req.sampling.eos_token):
                    # stop conditions apply to the admission-sampled token
                    # too: a max_new_tokens=1 (or instant-eos) request must
                    # not decode a second token
                    self.slots[slot] = req
                    self._active_mask[slot] = True
                    self._finish_slot(slot, time.monotonic())
                    if self.kv_registry is not None:
                        self.kv_registry.touch(req.session_id,
                                               self.instance_id,
                                               len(req.prompt), now)
                    continue
            self.slots[slot] = req
            self._active_mask[slot] = True
            if self.kv_registry is not None:
                self.kv_registry.touch(req.session_id, self.instance_id,
                                       len(req.prompt), now)

    # ------------------------------------------------------------ migration
    def warm_session(self, session_id: str, prompt_tokens: List[int]) -> int:
        """Prefill ``prompt_tokens`` straight into the session cache pool.

        This is the migration-in half of transcript replay (§4.3.1 applied
        to K,V state): the pool replays a session's transcript onto this
        replica so the *next* call in the session is a warm continuation —
        no batch slot is occupied and nothing is generated.  Returns the
        number of tokens now cached for the session (0 if nothing to do).

        The prefill cost is real and shows up in ``metrics.prefill_tokens``
        — that is the honest price of a migration, paid once, instead of on
        every follow-up call (which is what cold re-routing would cost).
        """
        if not session_id or not prompt_tokens:
            return 0
        vocab = self.cfg.vocab_size
        toks = [int(t) % vocab for t in prompt_tokens]
        toks = toks[-(self.max_seq - 1):]       # respect the context budget
        req = Request.make(toks, session_id=session_id)
        now = time.monotonic()
        W = self.cfg.sliding_window
        bucket = min(bucket_len(len(toks)), self.max_seq)
        with self._lock:
            if self._prefix_share_ok:
                # resident-prefix fast path: pages covering a prefix of the
                # transcript (this session's own, or another session's via
                # the index) make the replay partial or entirely redundant
                warmed = self._warm_from_resident(session_id, toks, now)
                if warmed:
                    return warmed
            if isinstance(self.pool, PagedKVPool) and (not W or bucket <= W):
                # right-aligned prefill: under causal attention the trailing
                # pads never touch the first len(toks) positions, so the
                # stored prefix is exact — no pad K/V enters the session
                # cache (the legacy left-pad exposure)
                _logits, row_cache = self._prefill(req, align="right")
                tokens = len(toks)
                ids = toks if self._prefix_share_ok else None
            else:
                _logits, row_cache = self._prefill(req)
                tokens = int(np.asarray(row_cache["pos"]).reshape(-1)[0])
                ids = None
            if isinstance(self.pool, PagedKVPool):
                if tokens > self.max_seq:
                    return 0
                k = row_cache["k"][:, 0, :tokens]
                v = row_cache["v"][:, 0, :tokens]
                if not self.pool.write_session(session_id, k, v, tokens, now,
                                               token_ids=ids):
                    return 0
            else:
                self.pool.store(session_id, row_cache, tokens)
            if self.kv_registry is not None:
                self.kv_registry.touch(session_id, self.instance_id,
                                       tokens, now)
        return tokens

    def _warm_from_resident(self, session_id: str, toks: List[int],
                            now: float) -> int:
        """Warm a session from pages already resident in the pool.

        Full coverage (the session's own pages after a page-ship import, or
        a shared prefix acquired from the index) costs *zero* prefill
        steps; partial coverage prefills only the missing suffix through
        batch-1 decode (``_extend_session``).  Returns tokens cached, or 0
        to make the caller fall back to the full transcript replay."""
        pool = self.pool
        sp = pool.session(session_id)
        resident = 0
        if sp is not None and sp.pages:
            if len(sp.token_ids) != sp.tokens:
                return 0    # opaque contents: cannot trust the prefix
            n = min(sp.tokens, len(toks))
            if sp.token_ids[:n] != toks[:n]:
                return 0    # diverged: full replay reconciles via COW
            if sp.tokens >= len(toks):
                if self.kv_registry is not None:
                    self.kv_registry.touch(session_id, self.instance_id,
                                           sp.tokens, now)
                return sp.tokens
            resident = sp.tokens
        else:
            resident = pool.acquire_prefix(session_id, toks, now=now)
            if resident >= len(toks):
                if self.kv_registry is not None:
                    self.kv_registry.touch(session_id, self.instance_id,
                                           resident, now)
                return resident
        if resident <= 0:
            return 0
        tokens = self._extend_session(session_id, toks, resident, now)
        if tokens and self.kv_registry is not None:
            self.kv_registry.touch(session_id, self.instance_id, tokens, now)
        return tokens

    def _extend_session(self, session_id: str, toks: List[int],
                        resident: int, now: float) -> int:
        """Suffix-only warm: feed ``toks[resident:]`` through batch-1
        decode on top of the session's resident cache and write the
        extended cache back.  The honest migration/warm cost becomes the
        novel suffix, not the whole transcript."""
        suffix = toks[resident:]
        C = self._slot_C
        if resident + len(suffix) > min(C, self.max_seq):
            return 0
        got = self.pool.gather_contiguous(session_id, self.max_seq)
        if got is None:
            return 0
        k, v, cached = got
        if cached != resident:
            return 0
        pad = C - k.shape[1]
        if pad < 0:
            return 0
        row: Dict[str, Any] = {
            "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))[:, None],
            "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))[:, None],
            "pos": jnp.asarray([resident], jnp.int32),
        }
        for key in self.cache:
            if key not in row:
                ax = _cache_slot_axis(key)
                shp = list(self.cache[key].shape)
                shp[ax] = 1
                row[key] = jnp.zeros(shp, self.cache[key].dtype)
        if self.model.decode_chunk is not None:
            if self._extend_chunk is None:
                self._extend_chunk = jax.jit(self.model.decode_chunk)
            T = max(1, self.prefill_chunk or 8)
            i = 0
            while i < len(suffix):
                n = min(T, len(suffix) - i)
                tk = np.zeros((1, T), np.int32)
                tk[0, :n] = suffix[i:i + n]
                _logits, row = self._extend_chunk(
                    self.params, jnp.asarray(tk),
                    jnp.asarray([n], jnp.int32), row)
                i += n
        else:
            if self._extend_step is None:
                self._extend_step = jax.jit(self.model.decode_step)
            for t in suffix:
                _logits, row = self._extend_step(
                    self.params, jnp.asarray([t], jnp.int32), row)
        tokens = resident + len(suffix)
        kk = row["k"][:, 0, :tokens]
        vv = row["v"][:, 0, :tokens]
        if not self.pool.write_session(session_id, kk, vv, tokens, now,
                                       token_ids=toks):
            return 0
        self.metrics.prefills += 1
        self.metrics.prefill_tokens += len(suffix)
        return tokens

    # ----------------------------------------------------------------- step
    def step(self) -> int:
        """Admit + one piggybacked batched step.

        Every decoding slot advances one token; prefilling slots consume up
        to ``prefill_chunk`` prompt tokens via masked sub-steps against the
        same compiled decode fn.  Returns #active sequences.
        """
        if self.chaos is not None:
            self.chaos.before_step(self)
        with self._lock:
            self._admit()
            now = time.monotonic()
            expired = [i for i in range(self.max_batch)
                       if self._active_mask[i] and self.slots[i] is not None
                       and 0 <= self.slots[i].deadline_wall <= now]
            for i in expired:
                # mid-decode preemption: the deadline passed, so further
                # tokens are worthless.  _finish_slot vacates through the
                # normal COW-safe path (unprotect + page release), the slot
                # is free for the next admission this very step.
                self.slots[i].expired = True
                self._finish_slot(i, now)
            if expired:
                self._admit()
            active = [i for i in range(self.max_batch) if self._active_mask[i]]
            if not active:
                self.metrics.queued = len(self.queue)
                self.metrics.active = 0
                return 0
            pending = self._pending_prompt
            prefilling = any(pending.get(i) for i in active)
            budget = max(1, self.prefill_chunk) if prefilling else 1
            if self._paged:
                sampled = self._step_paged(active, budget)
            elif self._decode_chunk is not None:
                sampled = self._step_fused(active, budget)
            else:
                sampled = self._step_masked(active, budget)
            pos_arr = np.asarray(self.cache["pos"])
            now = time.monotonic()
            for i in active:
                req = self.slots[i]
                if req is None:
                    continue
                done = False
                if i in sampled:
                    tok = req.generated[-1]
                    done = (len(req.generated) >= req.sampling.max_new_tokens
                            or tok == req.sampling.eos_token)
                if pos_arr[i] >= self.max_seq - 1:
                    done = True
                if done:
                    self._finish_slot(i, now)
                else:
                    self._emit_stream(req)
            self.metrics.queued = len(self.queue)
            self.metrics.active = int(self._active_mask.sum())
            return len(active)

    def _emit_stream(self, req: Request) -> None:
        """Buffer the tokens this request's slot appended since the last
        emission as one stream chunk (caller holds the step lock; delivery
        happens off it, in ``drain_completions``).  Only requests with a
        registered chunk callback buffer anything, so sync callers and
        abandoned requests cost nothing."""
        n = len(req.generated)
        if n <= req.streamed:
            return
        chunk = [int(t) for t in req.generated[req.streamed:]]
        req.streamed = n
        with self._done_lock:
            if req.request_id in self._stream_cbs:
                self._stream_pending.append((req, chunk))

    def _step_paged(self, active: List[int], budget: int) -> set:
        """One paged-native fused step.

        Identical batching policy to ``_step_fused`` (chunk width sized to
        need, rounded to a power of two), but the K/V never touches a
        per-slot dense cache: ``begin_append`` reserves (and COW-privatizes)
        each advancing session's pages, the jitted step scatters new K/V
        into them by page table and returns only the next-token rows, and
        ``commit_append`` publishes the new tokens (re-keying the prefix
        index).  A slot whose reservation fails is aborted explicitly —
        counted, finished with what it has — never silently wedged."""
        pending = self._pending_prompt
        pos_before = np.asarray(self.cache["pos"])
        # plan speculation: decode-only slots with a shadowing draft stream
        # propose spec_k tokens each (batched across slots in the draft)
        spec_plan: Dict[int, List[int]] = {}
        if self._spec is not None:
            want: Dict[int, int] = {}
            for i in active:
                if pending.get(i) or i not in self._spec_ok:
                    continue
                req = self.slots[i]
                if req is None or not req.generated:
                    continue
                k_i = self._spec_budget(i, req, int(pos_before[i]))
                if k_i > 0:
                    self._spec.observe(i, [int(req.generated[-1])])
                    want[i] = k_i
            if want:
                spec_plan = self._spec.propose(want)
        need = 1
        for i in active:
            q = pending.get(i)
            if q:
                need = max(need, min(len(q), budget))
            elif i in spec_plan:
                need = max(need, 1 + len(spec_plan[i]))
        cap = budget
        if spec_plan:
            cap = max(cap, max(1 + len(d) for d in spec_plan.values()))
        T = min(1 << (need - 1).bit_length(), cap)
        toks = np.zeros((self.max_batch, T), np.int32)
        valid = np.zeros((self.max_batch,), np.int32)
        for i in active:
            q = pending.get(i)
            if q:
                n = min(len(q), T)
                toks[i, :n] = q[:n]
                del q[:n]
                valid[i] = n
                if not q:
                    pending.pop(i, None)
                if self._spec is not None and i in self._spec_ok:
                    # mirror the consumed chunk into the draft stream
                    self._spec.observe(i, toks[i, :n].tolist())
            else:
                req = self.slots[i]
                seq = [int(req.generated[-1]) if req.generated else 0]
                seq += spec_plan.get(i, [])
                toks[i, :len(seq)] = seq
                valid[i] = len(seq)
        now = time.monotonic()
        aborted: List[int] = []
        for i in active:
            if not valid[i]:
                continue
            if not self.pool.begin_append(self._slot_sid[i], int(valid[i]),
                                          now):
                self.metrics.paged_append_failures += 1
                valid[i] = 0
                aborted.append(i)
        if self._prefix_share_ok:
            for i in active:
                ids = self._slot_tokens.get(i)
                if ids is not None and valid[i] and i not in spec_plan:
                    ids.extend(int(t) for t in toks[i, :valid[i]])
        pt = np.full((self.max_batch, self._max_pages), -1, np.int32)
        for i in active:
            if valid[i]:
                pt[i] = self.pool.page_table(self._slot_sid[i],
                                             self._max_pages)
        if self._paged_step_all is not None:
            logits, greedy_all, self.cache, self.pool.k, self.pool.v = \
                self._paged_step_all(self.params, jnp.asarray(toks),
                                     jnp.asarray(valid), self.cache,
                                     self.pool.k, self.pool.v,
                                     jnp.asarray(pt))
            greedy_np_all = np.asarray(greedy_all)               # [B,T]
            greedy = greedy_np_all[np.arange(self.max_batch),
                                   np.maximum(valid - 1, 0)]     # [B]
            rows = None                                          # lazy [B,V]
        else:
            rows, greedy, self.cache, self.pool.k, self.pool.v = \
                self._paged_step(self.params, jnp.asarray(toks),
                                 jnp.asarray(valid), self.cache,
                                 self.pool.k, self.pool.v, jnp.asarray(pt))
            logits = greedy_np_all = None
        self.metrics.decode_steps += 1
        for i in active:
            if valid[i] and i not in spec_plan:
                n = int(valid[i])
                ids = (toks[i, :n].tolist()
                       if self._slot_tokens.get(i) is not None else None)
                self.pool.commit_append(self._slot_sid[i], n, token_ids=ids,
                                        now=now)
        for i in aborted:
            self._finish_slot(i, now)
        ready = [i for i in active if valid[i] and i not in pending]
        if not ready:
            return set()
        greedy_np = np.asarray(greedy)
        sampled: set = set()
        for i in ready:
            req = self.slots[i]
            if i in spec_plan:
                self._verify_slot(req, i, spec_plan[i], int(toks[i, 0]),
                                  int(pos_before[i]), logits, greedy_np_all,
                                  now)
                sampled.add(i)
                continue
            if rows is None and logits is not None:
                rows = jnp.take_along_axis(
                    logits, jnp.asarray(np.maximum(valid - 1, 0))
                    [:, None, None], axis=1)[:, 0]               # [B,V]
            tok = self._sample_slot(req, rows, i, greedy_np)
            req.generated.append(tok)
            if req.first_token_at < 0:
                req.first_token_at = time.monotonic()
            self.metrics.tokens_generated += 1
            sampled.add(i)
        return sampled

    def _spec_budget(self, slot: int, req: Request, pos: int) -> int:
        """Draft tokens worth proposing for this slot this round (0 = run a
        plain decode step): bounded by the configured ``spec_k``, by the
        request's remaining new-token budget (a round emits at most k+1),
        by the slot's remaining positions, and by the adaptive per-session
        off-switch."""
        sid = self._slot_sid.get(slot)
        if sid is None or sid in self._spec_off:
            return 0
        remaining_new = req.sampling.max_new_tokens - len(req.generated)
        n_max = self.max_seq - 1 - pos       # emission budget to the cap
        return max(0, min(self.spec_k, remaining_new - 1, n_max - 1))

    def _verify_slot(self, req: Request, slot: int, drafts: List[int],
                     t_prev: int, pos0: int, logits, greedy_all: np.ndarray,
                     now: float) -> None:
        """Rejection-sample one verified draft chunk for ``slot``.

        The jitted step already scattered K/V for all ``k+1`` fed positions
        into the slot's reserved pages; this decides how many survive.
        Greedy accepts the longest prefix where the in-jit argmax equals
        the draft (bitwise the non-speculative sequence, because chunked ==
        sequential is pinned); stochastic runs the accept/resample rule on
        the per-position logits with the request's seeded stream.  Commits
        exactly the consumed positions, rolls the rejected tail's reserved
        pages back, rewinds the slot position, and truncates the draft's
        stream to the surviving prefix."""
        k = len(drafts)
        sp = req.sampling
        if sp.temperature <= 0.0:
            g = greedy_all[slot]
            m = 0
            while m < k and int(g[m]) == drafts[m]:
                m += 1
            candidates = drafts[:m] + [int(g[m])]
        else:
            key = self._req_rng.get(req.request_id)
            if key is None:
                key = self._request_key(req)
            key, sub = jax.random.split(key)
            self._req_rng[req.request_id] = key
            rows_np = np.asarray(logits[slot, :k + 1], dtype=np.float32)
            candidates, m = speculative_verify(rows_np, drafts, sp, sub)
        # trim emissions at the request's stop conditions (a mid-chunk eos
        # or budget hit ends the round early, exactly like the one-token
        # path would have)
        emitted: List[int] = []
        for t in candidates:
            emitted.append(int(t))
            if (len(req.generated) + len(emitted) >= sp.max_new_tokens
                    or t == sp.eos_token
                    or pos0 + len(emitted) >= self.max_seq - 1):
                break
        r = len(emitted)
        sid = self._slot_sid[slot]
        consumed_ids = [t_prev] + [int(t) for t in emitted[:r - 1]]
        ids = self._slot_tokens.get(slot)
        if ids is not None:
            ids.extend(consumed_ids)
        self.pool.commit_append(
            sid, r, token_ids=(consumed_ids if ids is not None else None),
            now=now)
        self.pool.truncate_reserved(sid)
        # the jit advanced pos by the full k+1 feed; only r positions exist
        self.cache["pos"] = self.cache["pos"].at[slot].set(pos0 + r)
        self._spec.rollback(slot, pos0 + r)
        for t in emitted:
            req.generated.append(int(t))
            self.metrics.tokens_generated += 1
        if req.first_token_at < 0:
            req.first_token_at = time.monotonic()
        self.metrics.spec_rounds += 1
        self.metrics.spec_proposed += k
        self.metrics.spec_accepted += m
        led = self._spec_ledger.setdefault(sid, [0, 0])
        led[0] += k
        led[1] += m
        if (led[0] >= self.spec_warmup
                and led[1] < self.spec_min_accept * led[0]):
            # observed acceptance makes speculation a loss for this
            # session: every future round decodes plain
            self._spec_off.add(sid)
        if len(self._spec_ledger) > 8192:
            self._spec_ledger.clear()
            self._spec_off.clear()

    def _step_fused(self, active: List[int], budget: int) -> set:
        """One fused chunk forward: prefilling slots consume up to
        ``budget`` prompt tokens, decoding slots advance one, idle slots
        none.  The chunk width is sized to the actual need and rounded up
        to a power of two, so a short prompt never pays a full-width chunk
        step and the compiled-shape set stays logarithmic.  Returns the
        slots that produced a token."""
        pending = self._pending_prompt
        need = 1
        for i in active:
            q = pending.get(i)
            if q:
                need = max(need, min(len(q), budget))
        # next power of two, clipped to the chunk budget (need <= budget,
        # so T >= need always holds and the chunk is consumed in full)
        T = min(1 << (need - 1).bit_length(), budget)
        toks = np.zeros((self.max_batch, T), np.int32)
        valid = np.zeros((self.max_batch,), np.int32)
        for i in active:
            q = pending.get(i)
            if q:
                n = min(len(q), T)
                toks[i, :n] = q[:n]
                del q[:n]
                valid[i] = n
                if not q:
                    pending.pop(i, None)
            else:
                req = self.slots[i]
                toks[i, 0] = req.generated[-1] if req.generated else 0
                valid[i] = 1
        if self._prefix_share_ok:
            for i in active:
                ids = self._slot_tokens.get(i)
                if ids is not None and valid[i]:
                    ids.extend(int(t) for t in toks[i, :valid[i]])
        logits, self.cache = self._decode_chunk(
            self.params, jnp.asarray(toks), jnp.asarray(valid), self.cache)
        self.metrics.decode_steps += 1
        ready = [i for i in active if valid[i] and i not in pending]
        if not ready:
            return set()
        # next-token distribution sits at each slot's last valid row
        rows = jnp.take_along_axis(
            logits, jnp.asarray(np.maximum(valid - 1, 0))[:, None, None],
            axis=1)[:, 0]                                        # [B,V]
        greedy = np.asarray(jnp.argmax(rows, axis=-1))
        sampled: set = set()
        for i in ready:
            req = self.slots[i]
            tok = self._sample_slot(req, rows, i, greedy)
            req.generated.append(tok)
            if req.first_token_at < 0:
                # stamp after the sampled token exists (consistent between
                # prefill and prefix-reuse paths)
                req.first_token_at = time.monotonic()
            self.metrics.tokens_generated += 1
            sampled.add(i)
        return sampled

    def _step_masked(self, active: List[int], budget: int) -> set:
        """Per-token fallback for families without a fused chunk step:
        up to ``budget`` masked sub-steps over the shared decode fn, where
        only prompt-consuming slots advance after the first."""
        pending = self._pending_prompt
        sampled: set = set()
        for j in range(budget):
            toks = np.zeros((self.max_batch,), np.int32)
            mask = np.zeros((self.max_batch,), bool)
            for i in active:
                q = pending.get(i)
                if q:
                    toks[i] = q.pop(0)
                    mask[i] = True
                    if not q:
                        pending.pop(i, None)
                elif j == 0 and i not in sampled:
                    req = self.slots[i]
                    toks[i] = req.generated[-1] if req.generated else 0
                    mask[i] = True
            if not mask.any():
                break
            if self._prefix_share_ok:
                for i in active:
                    if mask[i]:
                        ids = self._slot_tokens.get(i)
                        if ids is not None:
                            ids.append(int(toks[i]))
            logits, self.cache = self._masked_decode(
                self.params, jnp.asarray(toks), self.cache,
                jnp.asarray(mask))
            self.metrics.decode_steps += 1
            ready = [i for i in active
                     if mask[i] and i not in pending and i not in sampled]
            if not ready:
                continue
            greedy = np.asarray(jnp.argmax(logits, axis=-1))
            for i in ready:
                req = self.slots[i]
                tok = self._sample_slot(req, logits, i, greedy)
                req.generated.append(tok)
                if req.first_token_at < 0:
                    req.first_token_at = time.monotonic()
                self.metrics.tokens_generated += 1
                sampled.add(i)
        return sampled

    def _vacate_slot(self, slot: int) -> None:
        """Free a batch slot and every per-slot residue (pending prompt,
        request PRNG stream) so a recycled slot can never inherit a previous
        request's unconsumed prompt tokens."""
        req = self.slots[slot]
        self.slots[slot] = None
        self._active_mask[slot] = False
        self._pending_prompt.pop(slot, None)
        self._slot_tokens.pop(slot, None)
        if self._spec is not None:
            self._spec.reset(slot)
            self._spec_ok.discard(slot)
        sid = self._slot_sid.pop(slot, None)
        if sid is not None:
            self.pool.unprotect(sid)
            if req is None or not req.session_id:
                # anonymous paged session: no follow-up can resume it
                self.pool.release(sid)
        if req is not None:
            self._req_rng.pop(req.request_id, None)

    def _finish_slot(self, slot: int, now: float) -> None:
        req = self.slots[slot]
        req.finished = True
        req.finished_at = now
        if not req.expired:
            # flush the final tokens as a last chunk BEFORE delivery, so a
            # consumer's chunk stream concatenates to exactly the completion
            # value.  Expired requests emit nothing further: their partial
            # generation is worthless past the deadline.
            self._emit_stream(req)
        if req.expired:
            # deadline preemption: the partial generation is worthless and
            # its tokens never reach the transcript, so don't leave a warm
            # session cache behind (a later session-affine resume would
            # continue from divergent history) — reclaim slot and pages
            self.metrics.expired += 1
            self._vacate_slot(slot)
            if req.session_id:
                self.pool.release(req.session_id)
            with self._done_lock:
                self._finished.append(req)
                if len(self._finished) > self.finished_cap:
                    self._trim_finished()
            return
        self.metrics.completed += 1
        # persist session cache for prefix reuse on follow-ups
        if self._paged:
            # nothing to persist: the pool pages ARE the session cache,
            # already current through commit_append.  Vacate unprotects
            # (and releases anonymous sessions).
            if req.session_id:
                sp = self.pool.session(req.session_id)
                tokens = (sp.tokens if sp is not None
                          else int(np.asarray(self.cache["pos"])[slot]))
                if self.kv_registry is not None:
                    self.kv_registry.touch(req.session_id, self.instance_id,
                                           tokens, now)
        elif req.session_id:
            row = get_slot(self.cache, slot)
            tokens = int(np.asarray(row["pos"])[0])
            if isinstance(self.pool, PagedKVPool):
                k = row["k"][:, 0, :tokens]
                v = row["v"][:, 0, :tokens]
                ids = self._slot_tokens.get(slot)
                if ids is not None and len(ids) != tokens:
                    ids = None      # provenance lost: keep the write opaque
                if tokens <= self.max_seq:
                    self.pool.write_session(req.session_id, k, v, tokens, now,
                                            token_ids=ids)
            else:
                self.pool.store(req.session_id,
                                jax.tree_util.tree_map(lambda x: x, row),
                                tokens)
            if self.kv_registry is not None:
                self.kv_registry.touch(req.session_id, self.instance_id,
                                       tokens, now)
        self._vacate_slot(slot)
        with self._done_lock:
            self._finished.append(req)
            if len(self._finished) > self.finished_cap:
                self._trim_finished()

    def _trim_finished(self) -> None:
        """Bound the finished list without losing async completions.

        Sync callers never drain, so the list must stay bounded — but a
        request with a registered callback still owes its caller a
        completion: evicting it would strand a NALAR future forever.
        Fire-or-keep: evict oldest callback-less requests first; callback-
        bearing requests survive until ``drain_completions``.  Only under a
        pathological flood (callbacks registered but never drained) does
        the hard cap evict them too, dropping the orphaned callback entry
        with the request so the callback table cannot leak.

        Caller holds ``_done_lock``.
        """
        cut = len(self._finished) - self.finished_cap // 2
        kept: List[Request] = []
        for idx, r in enumerate(self._finished):
            if idx < cut and r.request_id not in self._callbacks:
                continue
            kept.append(r)
        overflow = len(kept) - 2 * self.finished_cap
        if overflow > 0:
            for r in kept[:overflow]:
                self._callbacks.pop(r.request_id, None)
            kept = kept[overflow:]
        self._finished = kept

    def cancel_request(self, request_id: str) -> bool:
        """Abandon one request (hedge loser / caller gone): remove it from
        the wait queue, or vacate its batch slot mid-decode — the slot and
        its protected KV pages are reclaimed through the normal vacate
        path.  The request is NOT delivered to ``_finished`` and its
        completion callback is dropped: the caller already resolved the
        future elsewhere.  Returns True if the request was found."""
        with self._lock:
            with self._done_lock:
                self._callbacks.pop(request_id, None)
                self._stream_cbs.pop(request_id, None)
                self._stream_pending = [
                    (r, c) for r, c in self._stream_pending
                    if r.request_id != request_id]
            req = self.queue.remove(request_id)
            if req is not None:
                self.metrics.queued = len(self.queue)
                return True
            for slot in range(self.max_batch):
                r = self.slots[slot]
                if r is not None and r.request_id == request_id:
                    self._vacate_slot(slot)
                    if r.session_id:
                        # the abandoned decode already extended this
                        # session's cache with tokens that will never reach
                        # the transcript (the winner's did) — a later
                        # session-affine resume here would continue from
                        # divergent history, so drop the cache outright
                        self.pool.release(r.session_id)
                    self.metrics.active = int(self._active_mask.sum())
                    return True
        return False

    def abort_all(self) -> int:
        """Clear the wait queue and vacate every slot (replica death /
        bridge ``fail_inflight``): results will never be delivered, and a
        recycled slot must not inherit a dead request's pending prompt.
        Returns the number of requests dropped."""
        with self._lock:
            n = self.queue.clear()
            for slot in range(self.max_batch):
                if self.slots[slot] is not None:
                    n += 1
                    self._vacate_slot(slot)
            self._pending_prompt.clear()
            self._slot_tokens.clear()
            with self._done_lock:
                self._callbacks.clear()
                self._stream_cbs.clear()
                self._stream_pending.clear()
            self.metrics.queued = 0
            self.metrics.active = 0
            return n

    # ------------------------------------------------------------ telemetry
    def run_until_idle(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and len(self.queue) == 0:
                return

    def slot_sessions(self) -> Dict[int, str]:
        """Session tag of every occupied batch slot (cache-slot ownership)."""
        with self._lock:
            return {i: r.session_id for i, r in enumerate(self.slots)
                    if r is not None}

    def telemetry(self) -> Dict[str, Any]:
        m = self.metrics
        return {"queued": len(self.queue), "active": m.active,
                "completed": m.completed, "decode_steps": m.decode_steps,
                "prefills": m.prefills, "prefill_tokens": m.prefill_tokens,
                "prefix_hits": m.prefix_hits,
                "shared_prefix_hits": m.shared_prefix_hits,
                "shared_prefix_tokens": m.shared_prefix_tokens,
                "prefix_sharing": (dict(self.pool.stats)
                                   if isinstance(self.pool, PagedKVPool)
                                   else {}),
                "tokens_generated": m.tokens_generated,
                "queue_limit": self.max_queue,
                "queue_saturation": self.saturation(),
                "admission_rejects": self.queue.rejected,
                "expired": m.expired,
                "expired_rejects": self.queue.expired_rejects,
                "prefill_chunk": self.prefill_chunk,
                "paged_decode": self._paged,
                "paged_kernel": self._paged and self._paged_kernel,
                "tier": self.tier,
                "speculative": self._spec is not None,
                "spec_rounds": m.spec_rounds,
                "spec_proposed": m.spec_proposed,
                "spec_accepted": m.spec_accepted,
                "spec_acceptance": m.spec_acceptance,
                "decode_tokens_per_step": m.decode_tokens_per_step,
                "resume_overflows": m.resume_overflows,
                "resume_unsupported": m.resume_unsupported,
                "paged_append_failures": m.paged_append_failures,
                "slot_sessions": self.slot_sessions()}
