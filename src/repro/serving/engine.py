"""JAX inference engine: slot-based continuous batching over any zoo model.

The engine is the "LLM serving backend" of the reproduction (the vLLM role
in the paper's stack).  One engine instance = one NALAR agent instance; the
engine exports queue/latency telemetry and consumes KVRegistry hints via its
cache pool, which is precisely the LMCache-hook integration of §4.3.2.

Execution model:
  * ``max_batch`` slots share a stacked per-slot cache (model.init_cache);
  * admission pulls from a priority wait-queue; a new request either
    resumes its session's cache from the pool (prefix reuse — the paper's
    motivating win for session stickiness/migration) or runs prefill;
  * each ``step()`` runs one batched decode for every active slot;
  * finished sessions write their cache back to the pool so follow-up
    requests in the same session skip recomputation.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.model import Model
from .batching import Request, WaitQueue, bucket_len
from .kv_cache import PagedKVPool, StateCachePool
from .sampler import SamplingParams, sample


@dataclass
class EngineMetrics:
    queued: int = 0
    active: int = 0
    completed: int = 0
    decode_steps: int = 0
    prefills: int = 0
    prefix_hits: int = 0
    tokens_generated: int = 0
    prefill_tokens: int = 0


def _cache_slot_axis(key: str) -> int:
    return 0 if key == "pos" else 1


def set_slot(cache: dict, slot: int, row: dict) -> dict:
    """Insert a single sequence's cache (batch dim 1) into batch slot.

    Row caches produced by bucketed prefill can be shorter in the seq dim
    than the slot cache; they are zero-padded at the end (consistent with
    the ring layout: prefill caches are unrolled when S <= window).
    """
    out = {}
    for k, v in cache.items():
        ax = _cache_slot_axis(k)
        r = row[k]
        r = jnp.squeeze(r, axis=ax) if r.ndim == v.ndim else r
        target = tuple(s for i, s in enumerate(v.shape) if i != ax)
        if tuple(r.shape) != target:
            pads = [(0, t - s) for s, t in zip(r.shape, target)]
            if any(p[1] < 0 for p in pads):
                raise ValueError(f"row cache leaf {k}: {r.shape} exceeds "
                                 f"slot shape {target}")
            r = jnp.pad(r, pads)
        idx = [slice(None)] * v.ndim
        idx[ax] = slot
        out[k] = v.at[tuple(idx)].set(r)
    return out


def get_slot(cache: dict, slot: int) -> dict:
    out = {}
    for k, v in cache.items():
        ax = _cache_slot_axis(k)
        out[k] = jnp.expand_dims(jnp.take(v, slot, axis=ax), axis=ax)
    return out


class InferenceEngine:
    def __init__(self, model: Model, params: dict, *, max_batch: int = 8,
                 max_seq: int = 512, instance_id: str = "engine:0",
                 kv_registry=None, pool_pages: int = 0,
                 page_size: int = 64, rng_seed: int = 0) -> None:
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.instance_id = instance_id
        self.kv_registry = kv_registry
        self.metrics = EngineMetrics()
        self.queue = WaitQueue()
        self._rng = jax.random.PRNGKey(rng_seed)
        self._lock = threading.RLock()

        # per-slot state
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.cache = model.init_cache(max_batch, max_seq)
        self._active_mask = np.zeros(max_batch, bool)

        # session cache pool (paged KV for attention families, O(1) state
        # for ssm/hybrid) + NALAR hint hook
        if self.cfg.family == "ssm":
            self.pool: Any = StateCachePool(self.cfg)
        elif self.cfg.family == "hybrid":
            self.pool = StateCachePool(self.cfg)
        else:
            n_pages = pool_pages or (max_batch * (max_seq // page_size + 1) * 2)
            self.pool = PagedKVPool(self.cfg, n_pages=n_pages,
                                    page_size=page_size)
        if kv_registry is not None:
            kv_registry.register_hook(instance_id, self.pool.on_hint)

        self._decode_fn = jax.jit(model.decode_step)
        self._prefill_cache: Dict[int, Callable] = {}

        # async completion plumbing (NALAR bridge): request_id -> callback,
        # plus a list of finished requests awaiting drain.  Callbacks fire
        # outside the step lock so they may re-enter submit().
        self._callbacks: Dict[str, Callable[[Request], None]] = {}
        self._finished: List[Request] = []

    # ----------------------------------------------------------- submission
    def submit(self, req: Request) -> str:
        self.queue.push(req)
        return req.request_id

    def submit_async(self, req: Request,
                     on_done: Optional[Callable[[Request], None]] = None) -> str:
        """Queue ``req``; ``on_done(req)`` fires from ``drain_completions``
        after the request finishes (the NALAR future-resolution hook)."""
        if on_done is not None:
            with self._lock:
                self._callbacks[req.request_id] = on_done
        return self.submit(req)

    def poll_finished(self) -> List[Request]:
        """Requests finished since the last poll/drain (no callbacks fired)."""
        with self._lock:
            out, self._finished = self._finished, []
        return out

    def drain_completions(self) -> int:
        """Fire completion callbacks for finished requests.  Called by the
        bridge pump thread after each step(), outside the engine lock."""
        with self._lock:
            done, self._finished = self._finished, []
            cbs = [(r, self._callbacks.pop(r.request_id, None)) for r in done]
        for req, cb in cbs:
            if cb is not None:
                cb(req)
        return len(cbs)

    def bind_registry(self, kv_registry, instance_id: str) -> None:
        """(Re)bind this engine to a NALAR runtime identity: the engine's
        telemetry and cache-pool hints are tagged with the agent-instance id
        so the runtime's Router and KVRegistry see one coherent name."""
        self.instance_id = instance_id
        self.kv_registry = kv_registry
        if kv_registry is not None:
            kv_registry.register_hook(instance_id, self.pool.on_hint)

    def generate(self, prompt, session_id: str = "",
                 sampling: Optional[SamplingParams] = None,
                 **extras) -> Request:
        """Synchronous helper: submit + run until this request finishes."""
        req = Request.make(prompt, session_id=session_id, sampling=sampling,
                           now=time.monotonic(), **extras)
        self.submit(req)
        while not req.finished:
            self.step()
        return req

    # ------------------------------------------------------------ admission
    def _prefill(self, req: Request):
        S = len(req.prompt)
        bucket = min(bucket_len(S), self.max_seq)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, -S:] = req.prompt      # left-pad so last position is real
        batch = {"tokens": jnp.asarray(toks)}
        for k, v in req.extras.items():
            batch[k] = jnp.asarray(v[None] if v.ndim == 2 else v)
        if bucket not in self._prefill_cache:
            self._prefill_cache[bucket] = jax.jit(self.model.prefill)
        logits, row_cache = self._prefill_cache[bucket](self.params, batch)
        self.metrics.prefills += 1
        self.metrics.prefill_tokens += S
        return logits, row_cache

    def _try_resume(self, req: Request):
        """Prefix reuse: restore this session's cache from the pool."""
        if isinstance(self.pool, StateCachePool):
            payload = self.pool.load(req.session_id)
            if payload is None:
                return None
            state, tokens = payload
            return state, tokens
        got = self.pool.gather_contiguous(req.session_id, self.max_seq)
        if got is None:
            return None
        k, v, tokens = got
        C = self.cache["k"].shape[2]
        pad = C - k.shape[1]
        if pad < 0:
            return None
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))[:, None]
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))[:, None]
        row = dict(self.cache.__class__() if False else {})
        row = {key: None for key in self.cache}
        row["k"], row["v"] = k, v
        row["pos"] = jnp.asarray([tokens], jnp.int32)
        for key in self.cache:
            if row.get(key) is None:   # xk/xv etc.: zeros
                ax = _cache_slot_axis(key)
                shp = list(self.cache[key].shape)
                shp[ax] = 1
                row[key] = jnp.zeros(shp, self.cache[key].dtype)
        return row, tokens

    def _admit(self) -> None:
        now = time.monotonic()
        for slot in range(self.max_batch):
            if self._active_mask[slot]:
                continue
            req = self.queue.pop_next()
            if req is None:
                return
            resumed = None
            if req.session_id:
                resumed = self._try_resume(req)
            if resumed is not None and not isinstance(self.pool, PagedKVPool):
                # SSM/hybrid: resumed state + run prompt incrementally is
                # equivalent to prefill; simplest correct path: prefill anyway
                resumed = None
            if resumed is None and req.fallback_prompt is not None:
                # The caller sent only a continuation suffix expecting a warm
                # session cache, but the cache is cold (evicted or migrated):
                # rebuild the full context in one prefill instead.
                req.prompt = req.fallback_prompt
            if resumed is not None:
                row_cache, tokens = resumed
                req.prefix_reused_tokens = tokens
                self.metrics.prefix_hits += 1
                # feed the prompt as additional decode steps (short suffix)
                self.cache = set_slot(self.cache, slot, row_cache)
                self.slots[slot] = req
                self._active_mask[slot] = True
                self._pending_prompt = getattr(self, "_pending_prompt", {})
                self._pending_prompt[slot] = list(req.prompt)
            else:
                logits, row_cache = self._prefill(req)
                tok = int(np.asarray(sample(logits, req.sampling, self._next_rng()))[0])
                req.generated.append(tok)
                req.first_token_at = now
                self.cache = set_slot(self.cache, slot, row_cache)
                self.slots[slot] = req
                self._active_mask[slot] = True
            if self.kv_registry is not None:
                self.kv_registry.touch(req.session_id, self.instance_id,
                                       len(req.prompt), now)

    # ------------------------------------------------------------ migration
    def warm_session(self, session_id: str, prompt_tokens: List[int]) -> int:
        """Prefill ``prompt_tokens`` straight into the session cache pool.

        This is the migration-in half of transcript replay (§4.3.1 applied
        to K,V state): the pool replays a session's transcript onto this
        replica so the *next* call in the session is a warm continuation —
        no batch slot is occupied and nothing is generated.  Returns the
        number of tokens now cached for the session (0 if nothing to do).

        The prefill cost is real and shows up in ``metrics.prefill_tokens``
        — that is the honest price of a migration, paid once, instead of on
        every follow-up call (which is what cold re-routing would cost).
        """
        if not session_id or not prompt_tokens:
            return 0
        vocab = self.cfg.vocab_size
        toks = [int(t) % vocab for t in prompt_tokens]
        toks = toks[-(self.max_seq - 1):]       # respect the context budget
        req = Request.make(toks, session_id=session_id)
        now = time.monotonic()
        with self._lock:
            _logits, row_cache = self._prefill(req)
            tokens = int(np.asarray(row_cache["pos"]).reshape(-1)[0])
            if isinstance(self.pool, PagedKVPool):
                if tokens > self.max_seq:
                    return 0
                k = row_cache["k"][:, 0, :tokens]
                v = row_cache["v"][:, 0, :tokens]
                if not self.pool.write_session(session_id, k, v, tokens, now):
                    return 0
            else:
                self.pool.store(session_id, row_cache, tokens)
            if self.kv_registry is not None:
                self.kv_registry.touch(session_id, self.instance_id,
                                       tokens, now)
        return tokens

    # ----------------------------------------------------------------- step
    def _next_rng(self) -> jax.Array:
        self._rng, sub = jax.random.split(self._rng)
        return sub

    def step(self) -> int:
        """Admit + one batched decode step.  Returns #active sequences."""
        with self._lock:
            self._admit()
            active = [i for i in range(self.max_batch) if self._active_mask[i]]
            if not active:
                self.metrics.queued = len(self.queue)
                return 0
            tokens = np.zeros((self.max_batch,), np.int32)
            pending = getattr(self, "_pending_prompt", {})
            for i in active:
                req = self.slots[i]
                if i in pending and pending[i]:
                    tokens[i] = pending[i].pop(0)
                    if not pending[i]:
                        del pending[i]
                else:
                    tokens[i] = req.generated[-1] if req.generated else 0
            logits, self.cache = self._decode_fn(self.params,
                                                 jnp.asarray(tokens),
                                                 self.cache)
            self.metrics.decode_steps += 1
            sampled = sample(logits, SamplingParams(), self._next_rng())
            now = time.monotonic()
            for i in active:
                req = self.slots[i]
                if i in pending:     # still consuming a resumed prompt
                    continue
                tok = int(np.asarray(sampled)[i])
                if req.sampling.temperature > 0:
                    tok = int(np.asarray(sample(
                        logits[i:i + 1], req.sampling, self._next_rng()))[0])
                if req.generated and req.first_token_at < 0:
                    req.first_token_at = now
                req.generated.append(tok)
                self.metrics.tokens_generated += 1
                done = (len(req.generated) >= req.sampling.max_new_tokens
                        or tok == req.sampling.eos_token)
                pos_i = int(np.asarray(self.cache["pos"])[i])
                if pos_i >= self.max_seq - 1:
                    done = True
                if done:
                    self._finish_slot(i, now)
            self.metrics.queued = len(self.queue)
            self.metrics.active = int(self._active_mask.sum())
            return len(active)

    def _finish_slot(self, slot: int, now: float) -> None:
        req = self.slots[slot]
        req.finished = True
        req.finished_at = now
        self.metrics.completed += 1
        # persist session cache for prefix reuse on follow-ups
        if req.session_id:
            row = get_slot(self.cache, slot)
            tokens = int(np.asarray(row["pos"])[0])
            if isinstance(self.pool, PagedKVPool):
                k = row["k"][:, 0, :tokens]
                v = row["v"][:, 0, :tokens]
                if tokens <= self.max_seq:
                    self.pool.write_session(req.session_id, k, v, tokens, now)
            else:
                self.pool.store(req.session_id,
                                jax.tree_util.tree_map(lambda x: x, row),
                                tokens)
            if self.kv_registry is not None:
                self.kv_registry.touch(req.session_id, self.instance_id,
                                       tokens, now)
        self.slots[slot] = None
        self._active_mask[slot] = False
        self._finished.append(req)
        if len(self._finished) > 8192:   # sync callers never drain; bound it
            del self._finished[:4096]

    # ------------------------------------------------------------ telemetry
    def run_until_idle(self, max_steps: int = 100_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and len(self.queue) == 0:
                return

    def slot_sessions(self) -> Dict[int, str]:
        """Session tag of every occupied batch slot (cache-slot ownership)."""
        with self._lock:
            return {i: r.session_id for i, r in enumerate(self.slots)
                    if r is not None}

    def telemetry(self) -> Dict[str, Any]:
        m = self.metrics
        return {"queued": m.queued, "active": m.active,
                "completed": m.completed, "decode_steps": m.decode_steps,
                "prefills": m.prefills, "prefill_tokens": m.prefill_tokens,
                "prefix_hits": m.prefix_hits,
                "tokens_generated": m.tokens_generated,
                "slot_sessions": self.slot_sessions()}
