"""Latency-fault chaos harness for the serving stack.

Robustness claims (deadline propagation, hedged dispatch, the retry
ladder) are only as believable as the faults they were demonstrated
against.  This module injects the tail-producing faults the paper's
straggler experiments assume, at the two layers the repo executes on:

* **Real engines** (`InferenceEngine` + `EngineBridge` pump): an
  injector installed as ``engine.chaos`` is called by ``step()`` before
  each batched step — outside the engine lock — and can slow every step
  (a straggler replica), stall periodically (a stuck pump), add seeded
  jitter, or pin KV pages to create allocation pressure
  (``paged_append_failures`` / admission aborts downstream).

* **Emulated instances** (SimKernel): wall-clock sleeps would break
  virtual-time determinism, so stragglers are modeled by wrapping the
  instance's ``LatencyModel`` with :class:`ScaledLatency` — same seeded
  RNG discipline as the rest of the emulator, bit-identical across runs.

Every injector keeps counters (``steps``, ``stalls``,
``injected_delay_s``) so benchmarks can report exactly how much fault
was injected alongside what the serving stack did about it.
"""

from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from ..core.executor import EmulatedMethod, LatencyModel
from .kv_cache import PagedKVPool

_HOLD_SID = "__chaos_hold"


@dataclass
class ChaosSpec:
    """Fault recipe for one engine replica.

    All delays are wall-clock seconds (the engine pump runs in wall
    time).  ``step_delay_s`` is the straggler knob: it stretches every
    decode step, which is how a slow replica actually presents (every
    request on it is slow, the siblings are fine).
    """

    step_delay_s: float = 0.0     # added to every step (straggler replica)
    jitter_s: float = 0.0         # + uniform[0, jitter_s) seeded noise
    stall_every: int = 0          # every Nth step additionally...
    stall_s: float = 0.0          # ...sleeps this long (stuck pump)
    hold_pages: int = 0           # KV pages pinned away from the pool
    seed: int = 0


class ChaosInjector:
    """Installed as ``engine.chaos``; ``before_step`` runs per step."""

    def __init__(self, spec: ChaosSpec) -> None:
        self.spec = spec
        self.rng = random.Random(spec.seed)
        self._lock = threading.Lock()
        self.enabled = True
        self.steps = 0
        self.stalls = 0
        self.injected_delay_s = 0.0
        self._pages_held = False

    def before_step(self, engine) -> None:
        with self._lock:
            if not self.enabled:
                return
            self.steps += 1
            sp = self.spec
            delay = sp.step_delay_s
            if sp.jitter_s > 0:
                delay += self.rng.uniform(0.0, sp.jitter_s)
            if sp.stall_every and self.steps % sp.stall_every == 0:
                delay += sp.stall_s
                self.stalls += 1
            if sp.hold_pages > 0 and not self._pages_held:
                self._hold_pages(engine)
        if delay > 0:
            time.sleep(delay)
            with self._lock:
                self.injected_delay_s += delay

    def _hold_pages(self, engine) -> None:
        """Pin ``hold_pages`` pages on a synthetic protected session so the
        pool runs that much closer to exhaustion (allocation-pressure
        fault).  Caller holds ``self._lock``."""
        pool = engine.pool
        if not isinstance(pool, PagedKVPool):
            return
        tokens = self.spec.hold_pages * pool.page_size
        if pool.allocate(_HOLD_SID, tokens, now=time.monotonic()):
            pool.protect(_HOLD_SID)
            self._pages_held = True

    def stop(self, engine=None) -> None:
        """Disable injection and release any held pages."""
        with self._lock:
            self.enabled = False
            held = self._pages_held
            self._pages_held = False
        if held and engine is not None:
            pool = engine.pool
            pool.unprotect(_HOLD_SID)
            pool.release(_HOLD_SID)

    def telemetry(self) -> Dict[str, Any]:
        with self._lock:
            return {"steps": self.steps, "stalls": self.stalls,
                    "injected_delay_s": round(self.injected_delay_s, 4),
                    "pages_held": (self.spec.hold_pages
                                   if self._pages_held else 0)}


def inject_engine(engine, spec: ChaosSpec) -> ChaosInjector:
    """Attach a fault injector to one engine replica; returns it so the
    caller can ``stop()`` / read ``telemetry()``."""
    inj = ChaosInjector(spec)
    engine.chaos = inj
    return inj


def clear_engine(engine) -> None:
    inj = getattr(engine, "chaos", None)
    if inj is not None:
        inj.stop(engine)
    engine.chaos = None


# ------------------------------------------------- emulated-layer faults
@dataclass
class ScaledLatency(LatencyModel):
    """A LatencyModel stretched by ``factor`` plus ``extra`` seconds —
    the SimKernel-deterministic straggler: virtual service time scales,
    the seeded RNG stream is the inner model's own."""

    inner: LatencyModel
    factor: float = 1.0
    extra: float = 0.0

    def service_time(self, hints: List[dict], rng: random.Random) -> float:
        return self.inner.service_time(hints, rng) * self.factor + self.extra


def slow_instance(runtime, instance_id: str, factor: float = 10.0,
                  extra: float = 0.0) -> int:
    """Turn one emulated instance into a straggler: every EmulatedMethod's
    latency model is wrapped in :class:`ScaledLatency`.  Deterministic
    under SimKernel.  Returns the number of methods slowed (0 if the
    instance is unknown or engine-backed)."""
    inst = runtime.instance(instance_id)
    if inst is None:
        return 0
    # the methods dict is shared across the agent type's instances (it
    # comes from the AgentSpec); copy-on-write so only this replica slows
    inst.methods = dict(inst.methods)
    n = 0
    for name, method in list(inst.methods.items()):
        if isinstance(method, EmulatedMethod):
            inst.methods[name] = EmulatedMethod(
                latency=ScaledLatency(method.latency, factor=factor,
                                      extra=extra),
                value_fn=method.value_fn)
            n += 1
    return n


def restore_instance(runtime, instance_id: str) -> int:
    """Undo :func:`slow_instance`.  Returns the number of methods restored."""
    inst = runtime.instance(instance_id)
    if inst is None:
        return 0
    n = 0
    for name, method in list(inst.methods.items()):
        if (isinstance(method, EmulatedMethod)
                and isinstance(method.latency, ScaledLatency)):
            inst.methods[name] = EmulatedMethod(
                latency=method.latency.inner, value_fn=method.value_fn)
            n += 1
    return n
