"""Token sampling."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0      # 0 = greedy
    top_k: int = 0                # 0 = disabled
    max_new_tokens: int = 32
    eos_token: int = -1           # -1 = never stop early
    # Seeds this request's private PRNG stream (temperature > 0).  None
    # derives a stream from the request id; either way draws are independent
    # of batch composition, so a request's sample sequence is reproducible
    # no matter what it happens to be batched with.
    seed: Optional[int] = None


def sample(logits: jnp.ndarray, params: SamplingParams,
           rng: jax.Array) -> jnp.ndarray:
    """logits: [B, V] -> tokens [B]."""
    if params.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / params.temperature
    if params.top_k > 0:
        kth = jax.lax.top_k(logits, params.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(rng, logits).astype(jnp.int32)
