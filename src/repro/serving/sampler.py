"""Token sampling."""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0      # 0 = greedy
    top_k: int = 0                # 0 = disabled
    max_new_tokens: int = 32
    eos_token: int = -1           # -1 = never stop early


def sample(logits: jnp.ndarray, params: SamplingParams,
           rng: jax.Array) -> jnp.ndarray:
    """logits: [B, V] -> tokens [B]."""
    if params.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / params.temperature
    if params.top_k > 0:
        kth = jax.lax.top_k(logits, params.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(rng, logits).astype(jnp.int32)
