"""Token sampling + speculative-decode rejection sampling."""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0      # 0 = greedy
    top_k: int = 0                # 0 = disabled
    max_new_tokens: int = 32
    eos_token: int = -1           # -1 = never stop early
    # Seeds this request's private PRNG stream (temperature > 0).  None
    # derives a stream from the request id; either way draws are independent
    # of batch composition, so a request's sample sequence is reproducible
    # no matter what it happens to be batched with.
    seed: Optional[int] = None


def sample(logits: jnp.ndarray, params: SamplingParams,
           rng: jax.Array) -> jnp.ndarray:
    """logits: [B, V] -> tokens [B]."""
    if params.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / params.temperature
    if params.top_k > 0:
        kth = jax.lax.top_k(logits, params.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(rng, logits).astype(jnp.int32)


# ------------------------------------------------- speculative verification
def target_probs(logits: jnp.ndarray, params: SamplingParams) -> np.ndarray:
    """The distribution :func:`sample` draws from, as explicit probabilities.

    logits [..., V] -> float32 probabilities [..., V] after temperature
    scaling and top-k filtering.  ``temperature <= 0`` returns the argmax
    point mass (greedy is a distribution too, which keeps the accept rule
    uniform across both modes)."""
    logits = np.asarray(logits, dtype=np.float32)
    if params.temperature <= 0.0:
        out = np.zeros_like(logits)
        idx = np.argmax(logits, axis=-1)
        np.put_along_axis(out, idx[..., None], 1.0, axis=-1)
        return out
    logits = logits / params.temperature
    if params.top_k > 0:
        kth = np.sort(logits, axis=-1)[..., -params.top_k][..., None]
        logits = np.where(logits < kth, -np.inf, logits)
    logits = logits - logits.max(axis=-1, keepdims=True)
    e = np.exp(logits)
    return e / e.sum(axis=-1, keepdims=True)


def speculative_verify(
        logits: jnp.ndarray, draft_tokens: List[int],
        params: SamplingParams, rng: Optional[jax.Array],
        draft_probs: Optional[np.ndarray] = None) -> Tuple[List[int], int]:
    """Batched rejection sampling over one verified draft chunk.

    ``logits`` [k+1, V]: target logits where row ``j`` is the next-token
    distribution after consuming the committed prefix plus
    ``draft_tokens[:j]`` — exactly what one ragged ``decode_chunk`` /
    ``decode_chunk_paged`` call over ``[prev_token, d_1..d_k]`` returns.
    ``draft_probs`` [k, V] is the proposal distribution each draft token was
    sampled from; ``None`` declares a deterministic (argmax) draft, i.e. a
    point mass at ``draft_tokens[j]``.

    Returns ``(tokens, n_accepted)``: the accepted draft prefix followed by
    exactly one correction/bonus token.  Greedy (``temperature <= 0``)
    accepts the longest prefix where the target argmax equals the draft and
    emits the argmax at the first divergence — bitwise the non-speculative
    greedy sequence.  Stochastic uses the standard accept-with-p/q,
    resample-from-max(p-q, 0) rule (Leviathan et al.), which preserves the
    target distribution exactly for *any* proposal; draws come from ``rng``
    (per-position ``fold_in``, so draws are independent of batch
    composition and of how many positions end up accepted)."""
    k = len(draft_tokens)
    if params.temperature <= 0.0:
        greedy = np.argmax(np.asarray(logits, dtype=np.float32), axis=-1)
        out: List[int] = []
        for j in range(k):
            if int(greedy[j]) != int(draft_tokens[j]):
                return out + [int(greedy[j])], j
            out.append(int(draft_tokens[j]))
        return out + [int(greedy[k])], k

    p = target_probs(logits, params)                      # [k+1, V]
    out = []
    for j in range(k):
        d = int(draft_tokens[j])
        q_d = 1.0 if draft_probs is None else float(draft_probs[j, d])
        u = float(jax.random.uniform(jax.random.fold_in(rng, j)))
        if q_d > 0.0 and u < min(1.0, float(p[j, d]) / q_d):
            out.append(d)
            continue
        # rejected: resample from the normalized residual max(p - q, 0)
        q_row = np.zeros_like(p[j]) if draft_probs is None else draft_probs[j]
        if draft_probs is None:
            q_row = q_row.copy()
            q_row[d] = 1.0
        resid = np.maximum(p[j] - q_row, 0.0)
        total = float(resid.sum())
        row = resid / total if total > 0.0 else p[j]
        key = jax.random.fold_in(rng, 1000 + j)
        tok = int(jax.random.choice(key, row.shape[0], p=jnp.asarray(row)))
        return out + [tok], j
    # every draft accepted: bonus token from the final target row
    key = jax.random.fold_in(rng, 1000 + k)
    tok = int(jax.random.choice(key, p.shape[1], p=jnp.asarray(p[k])))
    return out + [tok], k
