"""Continuous-batching scheduler for the inference engine.

Admission: priority first, then FCFS (NALAR's local controllers can reorder
by installing a different comparator — the same LocalSchedule idea applied
to the engine's waiting queue).  The wait queue is a binary heap (O(log n)
push/pop instead of the seed's O(n) scan) and can be *bounded*: a full
queue rejects the submission with :class:`EngineOverloaded`, which the
engine bridge propagates as a retryable failure into the runtime's retry
ladder — backpressure instead of unbounded queue growth, the baseline
failure mode the paper's serving claims are measured against.

Prompt lengths are padded to power-of-two buckets so monolithic prefill
compiles a bounded set of shapes (the chunked-prefill path feeds exact
tokens through the decode step and needs no buckets).
"""

from __future__ import annotations

import heapq
import itertools
import threading
from dataclasses import dataclass, field
from time import monotonic as _monotonic
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from .sampler import SamplingParams

_req_ids = itertools.count()


class EngineOverloaded(RuntimeError):
    """Admission rejected: the engine's bounded wait queue is at capacity.

    Retryable by design — the component controller's retry ladder backs off
    and re-submits, and on budget exhaustion the global RetryPolicy reroutes
    the future to a less-loaded replica.
    """


class RequestExpired(RuntimeError):
    """Admission rejected: the request's deadline already passed.

    Distinct from :class:`EngineOverloaded` so callers can tell backpressure
    from deadline misses.  Retryable at the engine layer (another replica
    might still race the deadline after clock skew), but the NALAR bridge
    converts it into the runtime's non-retryable ``DeadlineExceeded`` —
    expired agent work is worthless and must not burn retry budget.
    """


@dataclass
class Request:
    request_id: str
    session_id: str
    prompt: np.ndarray                       # [S] int32
    sampling: SamplingParams
    extras: Dict[str, np.ndarray] = field(default_factory=dict)
    priority: float = 0.0
    submitted_at: float = 0.0
    # Full-context prompt to prefill if the session's KV cache turns out to
    # be cold at admission (evicted/migrated since the caller checked).  Set
    # by the NALAR engine bridge when ``prompt`` is only the continuation
    # suffix of a longer transcript.
    fallback_prompt: Optional[np.ndarray] = None
    # absolute wall-clock (time.monotonic) deadline; -1.0 = none.  Enforced
    # at admission (push + pop) and mid-decode by the step loop, which
    # preempts the slot and reclaims its KV pages.
    deadline_wall: float = -1.0
    # filled during execution
    generated: List[int] = field(default_factory=list)
    finished: bool = False
    # the request was preempted/rejected because its deadline passed
    expired: bool = False
    # wall-clock (time.monotonic) stamps taken by the engine itself, so TTFT
    # is measured on one clock regardless of which kernel created the request
    submitted_wall: float = -1.0
    first_token_at: float = -1.0
    finished_at: float = -1.0
    prefix_reused_tokens: int = 0
    # execution path the engine admitted this request onto ("paged" /
    # "fused" / "masked") — observability for tests and benchmarks that
    # must assert which data plane actually served them
    decode_path: str = ""
    # tokens already emitted as stream chunks (prefix length of
    # ``generated``); maintained by the engine's step loop so each drain
    # delivers exactly the tokens appended since the previous one
    streamed: int = 0

    @staticmethod
    def make(prompt, session_id: str = "", sampling: Optional[SamplingParams] = None,
             priority: float = 0.0, now: float = 0.0,
             fallback_prompt=None, deadline_wall: float = -1.0,
             **extras) -> "Request":
        return Request(
            request_id=f"req{next(_req_ids)}",
            session_id=session_id or f"sess-req{next(_req_ids)}",
            prompt=np.asarray(prompt, np.int32),
            sampling=sampling or SamplingParams(),
            extras={k: np.asarray(v) for k, v in extras.items()},
            priority=priority,
            submitted_at=now,
            fallback_prompt=(None if fallback_prompt is None
                             else np.asarray(fallback_prompt, np.int32)),
            deadline_wall=deadline_wall,
        )


def bucket_len(n: int, minimum: int = 16) -> int:
    b = minimum
    while b < n:
        b *= 2
    return b


class WaitQueue:
    """Heap-ordered admission queue, optionally bounded.

    ``order_key(req)`` maps a request to a sort key (smaller pops first);
    the key is evaluated at push time, so installing a new comparator
    reorders future pushes only.  ``maxsize == 0`` means unbounded (the
    seed behaviour); a bounded queue raises :class:`EngineOverloaded` on
    overflow and counts the rejection.
    """

    def __init__(self, maxsize: int = 0) -> None:
        self._lock = threading.Lock()
        self._heap: List[tuple] = []
        self._seq = itertools.count()          # FIFO tie-break, stable heap
        self.maxsize = int(maxsize)
        self.rejected = 0
        self.expired_rejects = 0
        # wall clock for deadline checks; swappable for deterministic tests
        self.clock: Callable[[], float] = _monotonic
        self.order_key: Callable[[Request], Any] = (
            lambda r: (-r.priority, r.submitted_at))

    def push(self, req: Request) -> None:
        with self._lock:
            if 0 <= req.deadline_wall <= self.clock():
                self.expired_rejects += 1
                req.expired = True
                raise RequestExpired(
                    f"request {req.request_id} deadline passed before "
                    f"admission")
            if self.maxsize and len(self._heap) >= self.maxsize:
                self.rejected += 1
                raise EngineOverloaded(
                    f"engine wait queue full ({len(self._heap)}/"
                    f"{self.maxsize}); shed or retry elsewhere")
            heapq.heappush(self._heap, (self.order_key(req), next(self._seq),
                                        req))

    def pop_next(self) -> Optional[Request]:
        with self._lock:
            if not self._heap:
                return None
            return heapq.heappop(self._heap)[2]

    def remove(self, request_id: str) -> Optional[Request]:
        """Withdraw one waiting request by id (hedge-loser cancellation).
        O(n) scan + heapify — cancellation is rare by construction (hedge
        budget caps it), so simplicity beats an index here."""
        with self._lock:
            for idx, entry in enumerate(self._heap):
                if entry[2].request_id == request_id:
                    self._heap[idx] = self._heap[-1]
                    self._heap.pop()
                    heapq.heapify(self._heap)
                    return entry[2]
        return None

    def clear(self) -> int:
        with self._lock:
            n = len(self._heap)
            self._heap.clear()
            return n

    def saturation(self) -> float:
        """Queue depth as a fraction of capacity (0.0 when unbounded)."""
        with self._lock:
            if not self.maxsize:
                return 0.0
            return len(self._heap) / float(self.maxsize)

    def __len__(self) -> int:
        with self._lock:
            return len(self._heap)
