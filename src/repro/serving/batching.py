"""Continuous-batching scheduler for the inference engine.

Admission: priority first, then FCFS (NALAR's local controllers can reorder
by installing a different comparator — the same LocalSchedule idea applied
to the engine's waiting queue).  Prompt lengths are padded to power-of-two
buckets so prefill compiles a bounded set of shapes.
"""

from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from .sampler import SamplingParams

_req_ids = itertools.count()


@dataclass
class Request:
    request_id: str
    session_id: str
    prompt: np.ndarray                       # [S] int32
    sampling: SamplingParams
    extras: Dict[str, np.ndarray] = field(default_factory=dict)
    priority: float = 0.0
    submitted_at: float = 0.0
    # Full-context prompt to prefill if the session's KV cache turns out to
    # be cold at admission (evicted/migrated since the caller checked).  Set
    # by the NALAR engine bridge when ``prompt`` is only the continuation
    # suffix of a longer transcript.
    fallback_prompt: Optional[np.ndarray] = None
    # filled during execution
    generated: List[int] = field(default_factory=list)
    finished: bool = False
    first_token_at: float = -1.0
    finished_at: float = -1.0
    prefix_reused_tokens: int = 0

    @staticmethod
    def make(prompt, session_id: str = "", sampling: Optional[SamplingParams] = None,
             priority: float = 0.0, now: float = 0.0,
             fallback_prompt=None, **extras) -> "Request":
        return Request(
            request_id=f"req{next(_req_ids)}",
            session_id=session_id or f"sess-req{next(_req_ids)}",
            prompt=np.asarray(prompt, np.int32),
            sampling=sampling or SamplingParams(),
            extras={k: np.asarray(v) for k, v in extras.items()},
            priority=priority,
            submitted_at=now,
            fallback_prompt=(None if fallback_prompt is None
                             else np.asarray(fallback_prompt, np.int32)),
        )


def bucket_len(n: int, minimum: int = 16) -> int:
    b = minimum
    while b < n:
        b *= 2
    return b


class WaitQueue:
    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._items: List[Request] = []
        self.order_key: Callable[[Request], Any] = (
            lambda r: (-r.priority, r.submitted_at))

    def push(self, req: Request) -> None:
        with self._lock:
            self._items.append(req)

    def pop_next(self) -> Optional[Request]:
        with self._lock:
            if not self._items:
                return None
            best = min(self._items, key=self.order_key)
            self._items.remove(best)
            return best

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)
