"""Multi-replica engine pool: real ``InferenceEngine`` replicas behind one
agent type, with policy-driven routing and live session migration.

PR 1's bridge made a *single* engine a NALAR component; this module makes N
of them (possibly heterogeneous configs) the instances of one agent type, so
the paper's two-level control machinery — ``route`` / ``route_weighted`` /
``migrate`` actions computed by the ``GlobalController`` — resolves to
concrete replicas instead of simulated instances:

* **Placement is KV-aware.**  Each replica is an ordinary ``AgentInstance``;
  the Router's precedence (pin → KV locality → managed-state locality →
  weighted table → least-ETA) applies unchanged, so a session's follow-up
  lands where its prefix KV lives without any pool-specific routing code.
* **Migration ships pages when it can, replays tokens when it must.**
  ``migrate(session_id, src, dst)`` physically rebuilds the session on the
  destination.  When both replicas run geometry-compatible paged pools, the
  source's K/V pages are exported *before* the registry frees them and
  imported at the destination (deduplicated against its prefix index), so
  ``warm_session`` finds the prefix resident and prefills only the
  transcript tail — a page transfer instead of a full re-prefill.
  Otherwise (heterogeneous configs, opaque caches, ``page_migration``
  off) the managed-state layer materializes the ``SessionTranscript`` at
  the destination node and the destination engine prefills it straight
  into its cache pool (``InferenceEngine.warm_session``).  Either way the
  ``KVRegistry`` re-homes reuse expectations and the session's next call
  is a warm continuation on the new replica.
* **In-flight futures are never broken.**  If the session has a call running
  on the source engine, the migration defers until it resolves
  (``EngineBridge.defer_until_idle``); queued same-session calls move with
  the session and execute on the destination, in order.  (Same-session
  serialization is per-bridge: a call issued concurrently — mid-migration,
  or routed cache-blind to another replica — may run cold in parallel.
  That is always *safe*: the engine's fallback-prompt path rebuilds context
  at admission; what is lost is the warm-cache saving, not correctness of
  completion.)
* **Retry behavior is consistent.**  A migration to a dead or unknown
  replica falls back to the least-loaded live replica; a repeated migration
  to the session's current home is a no-op (no second replay prefill).

Layering: like ``bridge.py``, this file sees both sides; ``repro.core``
still never imports serving.
"""

from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

from ..core.directives import Directives
from ..core.executor import EngineBackedMethod
from ..core.future import FutureState
from ..core.stubs import AgentSpec
from .bridge import EngineBridge, EngineMethod
from .engine import InferenceEngine
from .kv_cache import PagedKVPool
from .sampler import SamplingParams


class _UnboundPoolMethod(EngineBackedMethod):
    """Placeholder in the pool's ``AgentSpec``: every live replica gets its
    own per-instance ``EngineMethod``, so this only executes if an instance
    was provisioned outside ``register_engine_pool`` (e.g. a bare
    ``provision`` policy action).  Fail loudly instead of silently sharing
    another replica's engine."""

    def __init__(self, agent_type: str) -> None:
        self.agent_type = agent_type

    def capacity(self) -> int:
        return 1

    def launch(self, batch, controller) -> None:
        err = RuntimeError(
            f"instance {controller.inst.instance_id} of pool "
            f"{self.agent_type!r} has no engine replica bound; add replicas "
            f"through repro.serving.pool.register_engine_pool")
        for f in batch:
            controller.complete_async(f, error=err)


class EnginePool:
    """N engine replicas serving one agent type.

    Owned by the runtime via ``runtime.engine_backends[name]``; the
    ``ComponentController`` delegates session migration commands here (the
    global controller's ``migrate`` action), and benchmarks read
    ``telemetry()`` / ``migrations`` for the paper's prefill-token evidence.
    """

    def __init__(self, runtime, name: str) -> None:
        self.rt = runtime
        self.name = name
        self.bridges: Dict[str, EngineBridge] = {}   # instance_id -> bridge
        self._lock = threading.Lock()
        # audit log of completed physical migrations (benchmarks assert on it)
        self.migrations: List[Dict[str, Any]] = []
        self.stats: Dict[str, int] = {
            "migrations": 0, "migrations_deferred": 0,
            "migrations_fallback": 0, "migrations_noop": 0,
            "futures_rerouted": 0, "replayed_tokens": 0,
            "migrations_page_shipped": 0, "pages_shipped": 0,
            "replica_failures": 0, "failed_inflight": 0,
            "sessions_recovered": 0,
        }
        # page-shipping fast path for migrate (export/import K/V pages
        # instead of transcript-replay re-prefill); benchmarks/tests can
        # force the replay path by clearing this
        self.page_migration = True

    # -------------------------------------------------------------- replicas
    def add_replica(self, instance_id: str, bridge: EngineBridge) -> None:
        with self._lock:
            self.bridges[instance_id] = bridge

    def _bump(self, key: str, n: int = 1) -> None:
        # counters are hit from controller threads and pump threads alike
        with self._lock:
            self.stats[key] += n

    @property
    def instance_ids(self) -> List[str]:
        with self._lock:
            return list(self.bridges)

    def bridge_of(self, instance_id: str) -> Optional[EngineBridge]:
        with self._lock:
            return self.bridges.get(instance_id)

    def live_replicas(self) -> List[str]:
        out = []
        for iid in self.instance_ids:
            inst = self.rt.instance(iid)
            if inst is not None and inst.alive:
                out.append(iid)
        return out

    def drain(self, timeout: float = 5.0) -> int:
        """Graceful pool shutdown: drain every replica's bridge (stop
        admitting, wait for in-flight work, fail-fast leftovers).  Futures
        mid-stream when their replica's timeout hits fail like any other
        in-flight work — their chunk iterators wake with the failure, so
        HTTP streams and pipelined consumers terminate promptly instead of
        hanging on a half-delivered answer.  Returns total futures
        failed-fast (0 = clean drain)."""
        failed = 0
        for iid in self.instance_ids:
            bridge = self.bridge_of(iid)
            if bridge is not None:
                failed += bridge.drain(timeout)
        if failed:
            self._bump("failed_inflight", failed)
        return failed

    # ------------------------------------------------------- replica failure
    def on_replica_killed(self, instance_id: str) -> None:
        """Fault-injection hook: ``runtime.kill_instance(iid, hard=True)``.

        The replica's engine results will never arrive, so (1) every
        in-flight and bridge-queued future fails with ``InstanceDied`` and
        travels the retry ladder — with retries enabled, the global
        controller's RetryPolicy reroutes each one to a surviving replica;
        (2) every session whose KV cache lived on the dead replica is
        proactively recovered on a survivor by ``SessionTranscript`` replay
        (the PR-2 migration machinery with a fallback destination), so
        retried and follow-up calls resume warm instead of cold.  The pump
        is stopped so no zombie completion can race a retried attempt.
        """
        bridge = self.bridge_of(instance_id)
        if bridge is None:
            return
        n_failed = bridge.on_replica_killed(instance_id)
        recovered = 0
        for sid in self.rt.kv_registry.instance_sessions(instance_id):
            try:
                # empty destination -> _resolve_dst falls back to the
                # least-loaded surviving replica; replays the transcript
                if self.migrate_session(sid, instance_id, "") > 0:
                    recovered += 1
            except Exception:  # noqa: BLE001 — best-effort per session
                pass
        with self._lock:
            self.stats["replica_failures"] += 1
            self.stats["failed_inflight"] += n_failed
            self.stats["sessions_recovered"] += recovered

    # ------------------------------------------------------------- migration
    def _resolve_dst(self, dst_iid: str, avoid: str) -> Optional[str]:
        """Destination replica, with consistent-retry fallback: a dead or
        unknown destination becomes the least-loaded live replica."""
        inst = self.rt.instance(dst_iid)
        if inst is not None and inst.alive and self.bridge_of(dst_iid) is not None:
            return dst_iid
        now = self.rt.kernel.now()
        cands = [self.rt.instance(i) for i in self.instance_ids if i != avoid]
        cands = [i for i in cands if i is not None and i.alive]
        if not cands:
            return None
        self._bump("migrations_fallback")
        return min(cands, key=lambda i: i.load_score(now)).instance_id

    def migrate_session(self, session_id: str, src_iid: str,
                        dst_iid: str) -> int:
        """Move ``session_id`` from ``src_iid`` to ``dst_iid`` (Table 2
        ``migrate`` resolved against real replicas).

        Returns the number of futures re-routed plus one for the physical
        re-home, 0 for a no-op (already at the destination, no live
        destination, or the session lives on neither replica).  If the
        session has an in-flight call on the source, the move is scheduled
        to run the moment that call resolves and 1 is returned.

        Streaming composes with deferral for free: a partially-streamed
        in-flight call keeps streaming from the source until it completes
        (its chunks carry the source's owner fence), and only then does the
        session re-home — a consumer's chunk iterator never straddles two
        replicas mid-attempt.
        """
        if not session_id:
            return 0
        dst = self._resolve_dst(dst_iid, avoid=src_iid)
        if dst is None or dst == src_iid:
            self._bump("migrations_noop")
            return 0
        info = self.rt.kv_registry.lookup(session_id)
        home = info.instance_id if info is not None else None
        if home == dst:
            self._bump("migrations_noop")   # double-migrate: idempotent
            return 0
        if home is not None and home != src_iid and home in self.bridges:
            # stale command: the session has already moved elsewhere in the
            # pool; migrating it "from src" would race the real owner
            self._bump("migrations_noop")
            return 0

        src_bridge = self.bridge_of(src_iid)
        if src_bridge is not None:
            deferred = src_bridge.defer_until_idle(
                session_id,
                lambda queued: self._do_migrate(session_id, src_iid, dst,
                                                queued))
            if deferred:
                self._bump("migrations_deferred")
                return 1
        return self._do_migrate(session_id, src_iid, dst, [])

    def _do_migrate(self, sid: str, src_iid: str, dst_iid: str,
                    queued: List[Tuple[Any, Any, Any]]) -> int:
        """The physical move.  Runs with no same-session call in flight."""
        # A deferred move fires after an arbitrary delay (the in-flight call
        # ran to completion), so the destination chosen at schedule time may
        # have died in between — re-validate, with the same fallback.
        resolved = self._resolve_dst(dst_iid, avoid=src_iid)
        dst_ctrl = self.rt.controller_of(resolved) if resolved else None
        dst_bridge = self.bridge_of(resolved) if resolved else None
        if resolved is None or dst_ctrl is None or dst_bridge is None:
            # no live destination left: the session stays home and its
            # queued calls continue on the source, in order
            src_bridge = self.bridge_of(src_iid)
            for fut, controller, method in queued:
                try:
                    if src_bridge is None:
                        raise RuntimeError(
                            f"pool {self.name!r}: no live replica to run "
                            f"session {sid!r}")
                    src_bridge.submit_future(fut, controller, method)
                except BaseException as e:  # noqa: BLE001 — fail this call
                    controller.complete_async(fut, error=e)
            self._bump("migrations_noop")
            return 0
        dst_iid = resolved
        now = self.rt.kernel.now()

        # 0. Page-shipping fast path: snapshot the session's K/V pages at
        #    the source *before* the registry migrate frees them.  Only
        #    possible when both replicas run geometry-compatible paged
        #    pools, the destination can reuse a token-tagged prefix, and
        #    the source cache isn't opaque (no token provenance).
        transcript = dst_bridge.transcript.tokens(sid)
        payload = self._export_pages(src_iid, dst_bridge.engine, sid,
                                     transcript)

        # 1. Registry re-homes reuse expectations first: ``migrate`` moves
        #    the residency record and fires migrate_out at the source pool,
        #    freeing its pages.  (Must precede the replay — warm_session's
        #    ``touch`` would otherwise re-create the record at dst and turn
        #    the registry migrate into a no-op that never frees the source.)
        self.rt.kv_registry.migrate(sid, src_iid, dst_iid)

        # 2. State layer does the rebuild: reading the transcript through the
        #    destination bridge materializes it at the destination node.  If
        #    the page snapshot landed, warm_session finds the prefix already
        #    resident and prefills only the transcript tail; otherwise the
        #    destination engine prefills the full transcript straight into
        #    its session cache pool (touching the registry with the replayed
        #    count).  A follow-up racing this window hits the engine's
        #    fallback_prompt path — cold-at-admission is always safe.
        shipped = 0
        if payload is not None:
            if dst_bridge.engine.pool.import_session(sid, payload, now=now):
                shipped = int(payload["k"].shape[1])
        replayed = dst_bridge.engine.warm_session(sid, transcript)

        # 3. Any other managed state of the session follows it.
        self.rt.migrate_session_state(sid, self.name, dst_ctrl.inst.node_id)

        # 4. Routing re-home: new futures land on the destination.
        self.rt.router.pin(sid, self.name, dst_iid)

        # 5. Re-route work that was waiting behind the in-flight call:
        #    first the bridge's session queue (already launched, in order),
        #    then anything still sitting in the source controller's queue.
        src_ctrl = self.rt.controller_of(src_iid)
        ctl_queued: List[Any] = []
        if src_ctrl is not None:
            ctl_queued = src_ctrl.take_session_futures(sid)
        moved = 0
        for fut, _ctrl, _method in queued:
            moved += self._reroute(fut, src_ctrl, dst_ctrl)
        for fut in ctl_queued:
            moved += self._reroute(fut, src_ctrl, dst_ctrl)

        with self._lock:
            self.migrations.append(dict(
                session_id=sid, src=src_iid, dst=dst_iid,
                replayed_tokens=replayed, futures_moved=moved, at=now,
                mode="pages" if shipped else "replay",
                pages_shipped=shipped))
            self.stats["migrations"] += 1
            self.stats["futures_rerouted"] += moved
            self.stats["replayed_tokens"] += replayed
            if shipped:
                self.stats["migrations_page_shipped"] += 1
                self.stats["pages_shipped"] += shipped
        return moved + 1

    def _export_pages(self, src_iid: str, dst_engine: InferenceEngine,
                      sid: str, transcript: List[int]
                      ) -> Optional[Dict[str, Any]]:
        """Session K/V payload for page-shipping, or ``None`` when the
        replicas cannot exchange pages: ``page_migration`` off, either pool
        unpaged or geometry-incompatible, the destination engine unable to
        extend a resident prefix (sharing disabled), or the source cache
        opaque (no token provenance — the destination could not verify what
        the bytes cover, so the transcript replay is the safe path).

        The payload is trimmed to the longest source-cache prefix that
        matches the transcript: a multi-turn cache skips each turn's final
        generated token (sampled but never fed), so only the prefix up to
        the first such hole is worth shipping — the destination's
        ``warm_session`` prefills the rest from the transcript."""
        if not self.page_migration:
            return None
        src_bridge = self.bridge_of(src_iid)
        if src_bridge is None:
            return None
        src_pool = src_bridge.engine.pool
        dst_pool = dst_engine.pool
        if not (isinstance(src_pool, PagedKVPool)
                and isinstance(dst_pool, PagedKVPool)
                and getattr(dst_engine, "_prefix_share_ok", False)
                and src_pool.compatible_with(dst_pool)):
            return None
        try:
            payload = src_pool.export_session(sid)
        except Exception:  # noqa: BLE001 — fall back to transcript replay
            return None
        if (payload is None or not payload.get("tokens")
                or len(payload.get("token_ids") or ())
                != payload["tokens"]):
            return None
        ids = payload["token_ids"]
        common = 0
        for a, b in zip(ids, transcript):
            if int(a) != int(b):
                break
            common += 1
        if common == 0:
            return None
        if common < payload["tokens"]:
            pages = -(-common // payload["page_size"])     # ceil div
            payload = dict(payload, k=payload["k"][:, :pages],
                           v=payload["v"][:, :pages],
                           tokens=common, token_ids=list(ids[:common]))
        return payload

    def _reroute(self, fut, src_ctrl, dst_ctrl) -> int:
        """Hand one not-yet-executed session future to the destination."""
        if fut is None or fut.available:
            return 0
        if src_ctrl is not None:
            src_ctrl.detach_running(fut)
        fut._set_state(FutureState.PENDING)
        self.rt.telemetry.on_migration(
            fut, src_ctrl.inst.instance_id if src_ctrl else "",
            dst_ctrl.inst.instance_id, self.rt.kernel.now())
        dst_ctrl.submit(fut)
        return 1

    def cancel_inflight(self, fid: str, instance_id: str = "") -> bool:
        """Hedge-loser cancellation resolved to the owning replica bridge."""
        bridge = self.bridge_of(instance_id)
        if bridge is not None:
            return bridge.cancel_inflight(fid, instance_id)
        return False

    # ------------------------------------------------------------- telemetry
    def saturation_of(self, instance_id: str) -> float:
        """Wait-queue saturation of one replica (Router shed hook)."""
        bridge = self.bridge_of(instance_id)
        return bridge.engine.saturation() if bridge is not None else 0.0

    def instance_metrics(self, instance_id: str) -> Dict[str, Any]:
        """Per-replica engine gauges for the controller's metrics mirror."""
        bridge = self.bridge_of(instance_id)
        return bridge.instance_metrics(instance_id) if bridge else {}

    def telemetry(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"pool": self.name, "stats": dict(self.stats),
                               "replicas": {}}
        for iid in self.instance_ids:
            bridge = self.bridge_of(iid)
            if bridge is not None:
                out["replicas"][iid] = bridge.telemetry()
        return out


def register_engine_pool(runtime, name: str,
                         engines: List[InferenceEngine], *,
                         methods: Tuple[str, ...] = ("generate",),
                         sampling: Optional[SamplingParams] = None,
                         encode: Optional[Callable[..., List[int]]] = None,
                         decode: Optional[Callable] = None,
                         nodes: Optional[List[str]] = None,
                         resources: Optional[Dict[str, float]] = None):
    """Register ``len(engines)`` real-engine replicas as one agent type.

    Returns the stub.  Each engine becomes one NALAR agent instance with its
    own ``EngineBridge`` and pump thread; the ``EnginePool`` is installed as
    the agent type's backend (``runtime.engine_backends[name]``) so global
    ``migrate`` actions replay transcripts across replicas.  Replicas may be
    heterogeneous (different ``max_batch`` / ``max_seq`` / model configs):
    routing weights and ETAs are per-instance, and migration moves tokens
    rather than cache pages.

    Requires ``NalarRuntime(simulate=False)`` for the same reason as
    ``register_engine_agent``: engine completions arrive in wall-clock time.
    """
    from ..core.clock import RealTimeKernel
    if not isinstance(runtime.kernel, RealTimeKernel):
        raise RuntimeError(
            "engine pools need a real-time runtime; construct "
            "NalarRuntime(simulate=False) (the SimKernel's virtual time "
            "cannot wait on wall-clock engine completions)")
    if not engines:
        raise ValueError("engine pool needs at least one engine")

    pool = EnginePool(runtime, name)
    spec = AgentSpec(
        name=name,
        methods={mn: _UnboundPoolMethod(name) for mn in methods},
        directives=Directives(max_instances=len(engines), min_instances=1,
                              uses_managed_state=True,
                              resources=resources or {}))
    stub = runtime.register_agent(spec, nodes=nodes or list(runtime.nodes),
                                  instances=len(engines))
    iids = runtime.instances_of_type(name)
    if len(iids) != len(engines):
        raise RuntimeError(
            f"pool {name!r}: provisioned {len(iids)} of {len(engines)} "
            f"replicas (node resources exhausted?)")
    default_sampling = sampling or SamplingParams(max_new_tokens=16)
    for iid, engine in zip(iids, engines):
        inst = runtime.instance(iid)
        bridge = EngineBridge(runtime, engine, agent_type=name)
        bridge.attach(iid, inst.node_id)
        method = EngineMethod(bridge=bridge, sampling=default_sampling,
                              encode=encode, decode=decode)
        inst.methods = {mn: method for mn in methods}
        pool.add_replica(iid, bridge)
    runtime.engine_backends[name] = pool
    # publish each replica's mirror now that the backend is installed:
    # engine gauges (tier label, saturation) must reach the ClusterView
    # before first traffic, or an idle replica stays invisible to
    # tier/shed policies until something routes to it by accident
    for iid in iids:
        ctrl = runtime.controller_of(iid)
        if ctrl is not None:
            ctrl._publish_metrics()
    return stub
