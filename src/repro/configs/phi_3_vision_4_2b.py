"""phi-3-vision-4.2b [vlm] — [hf:microsoft/Phi-3-vision-128k-instruct].

32L d_model=3072 32H (GQA kv=32 = MHA) d_ff=8192 vocab=32064.
phi3-mini language backbone + CLIP vision tower; the vision tower +
projector are STUBBED (input_specs supplies patch embeddings).
576 image tokens (24x24 patches after projection).

long_500k uses the dense sliding-window carve-out (DESIGN.md §4).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="phi-3-vision-4.2b",
    family="vlm",
    source="hf:microsoft/Phi-3-vision-128k-instruct",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    norm_type="rms",
    mlp_type="swiglu",
    rope_theta=10_000.0,
    n_image_tokens=576,
    norm_eps=1e-5,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        arch_id="phi-3-vision-smoke",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab_size=512, n_image_tokens=16)
