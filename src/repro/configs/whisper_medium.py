"""whisper-medium [audio] — arXiv:2212.04356.

Enc-dec, 24 encoder + 24 decoder layers, d_model=1024 16H (MHA) d_ff=4096
vocab=51865.  The mel-spectrogram + conv frontend is a STUB (input_specs
supplies 1500 precomputed frame embeddings).  LayerNorm + GELU, no RoPE
(learned absolute positions).

long_500k: SKIPPED for this arch (DESIGN.md §4 — 30 s audio yields ~1500
encoder frames; a 524K-token decode is out of family scope).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-medium",
    family="audio",
    source="arXiv:2212.04356",
    n_layers=24,             # decoder layers
    n_encoder_layers=24,
    encoder_seq=1500,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    norm_type="layer",
    mlp_type="gelu",
    rope_pct=0.0,            # no rotary; positions are learned/absolute
    norm_eps=1e-5,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        arch_id="whisper-medium-smoke",
        n_layers=2, n_encoder_layers=2, encoder_seq=32,
        d_model=128, n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=512)
