"""qwen3-moe-235b-a22b [moe] — [hf:Qwen/Qwen3-30B-A3B family].

94L d_model=4096 64H (GQA kv=4) vocab=151936, 128 experts top-8,
expert FFN dim d_ff=1536, qk_norm.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-moe-235b-a22b",
    family="moe",
    source="hf:Qwen/Qwen3-235B-A22B (per hf:Qwen/Qwen3-30B-A3B card family)",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,               # expert FFN width (d_expert mirrors it)
    vocab_size=151936,
    norm_type="rms",
    mlp_type="swiglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    n_experts=128,
    top_k=8,
    d_expert=1536,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        arch_id="qwen3-moe-smoke",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=64, vocab_size=512, n_experts=4, top_k=2, d_expert=64)
