"""starcoder2-15b [dense] — arXiv:2402.19173.

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152, GQA, RoPE.
StarCoder2 uses LayerNorm and a GELU MLP (non-gated), sliding window 4096
in the published model; the window also enables the long_500k carve-out.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="starcoder2-15b",
    family="dense",
    source="arXiv:2402.19173",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    norm_type="layer",
    mlp_type="gelu",
    qk_norm=False,
    rope_theta=100_000.0,
    sliding_window=4096,
    norm_eps=1e-5,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        arch_id="starcoder2-15b-smoke",
        n_layers=2, d_model=192, n_heads=6, n_kv_heads=2,
        d_ff=384, vocab_size=512, sliding_window=64)
