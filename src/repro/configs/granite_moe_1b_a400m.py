"""granite-moe-1b-a400m [moe] — [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) vocab=49155, 32 experts top-8,
expert FFN dim d_ff=512.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-moe-1b-a400m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,                # expert FFN width
    vocab_size=49155,
    norm_type="rms",
    mlp_type="swiglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    n_experts=32,
    top_k=8,
    d_expert=512,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        arch_id="granite-moe-smoke",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab_size=512, n_experts=4, top_k=2, d_expert=64)
