"""Model/architecture configuration schema.

Every assigned architecture gets one ``src/repro/configs/<id>.py`` exporting
``CONFIG`` (exact published shape, source cited) and ``smoke_config()`` (a
reduced same-family variant for CPU tests: <=2 layers, d_model<=512,
<=4 experts).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    # identity
    arch_id: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""                 # citation (hf:... / arXiv:...)

    # transformer backbone
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0                # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    norm_type: str = "rms"           # rms | layer
    mlp_type: str = "swiglu"         # swiglu | gelu
    qk_norm: bool = False
    rope_theta: float = 10000.0
    rope_pct: float = 1.0            # fraction of head_dim rotated (stablelm: .25)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # sliding-window attention (dense long-context decode carve-out; also the
    # local-attention layers of hybrid archs)
    sliding_window: Optional[int] = None

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0                # expert FFN hidden dim
    capacity_factor: float = 1.25

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256

    # hybrid (RecurrentGemma): every `hybrid_period`-th layer is local
    # attention, the rest are RG-LRU recurrent blocks
    hybrid_period: int = 0           # 3 -> pattern (rec, rec, attn)
    rglru_width: int = 0             # recurrence width (d_model if 0)

    # encoder-decoder (whisper)
    n_encoder_layers: int = 0
    encoder_seq: int = 1500          # precomputed mel-frame embeddings (stub)

    # vlm
    n_image_tokens: int = 0          # precomputed patch embeddings (stub)

    dtype: str = "bfloat16"

    # ------------------------------------------------------------- derived
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM/hybrid natively; dense only when a
        sliding window is configured (see DESIGN.md §4)."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None)

    @property
    def has_decode(self) -> bool:
        """Encoder-only models have no decode step; all assigned archs do."""
        return True

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Parameter count (for 6ND model-FLOPs in the roofline; N_active for MoE).
    def param_count(self, active_only: bool = False) -> int:
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        Dh, H, Hkv = self.head_dim_, self.n_heads, self.n_kv_heads
        emb = V * D * (1 if self.tie_embeddings else 2)
        total = emb

        def attn_params() -> int:
            return D * H * Dh + 2 * D * Hkv * Dh + H * Dh * D

        def mlp_params(f: int) -> int:
            return (3 if self.mlp_type == "swiglu" else 2) * D * f

        if self.family == "ssm":
            # mamba2 block: in_proj (x, z, B, C, dt), conv, out_proj
            din = self.d_inner
            g = 1
            proj_in = D * (2 * din + 2 * g * self.ssm_state + self.n_ssm_heads)
            conv = (din + 2 * g * self.ssm_state) * self.ssm_conv
            out = din * D
            total += L * (proj_in + conv + out + 2 * D)
            return total
        if self.family == "hybrid":
            period = max(self.hybrid_period, 1)
            n_attn = L // period
            n_rec = L - n_attn
            w = self.rglru_width or D
            rec = D * w * 2 + w * 3 + w * D + self.ssm_conv * w  # gates+conv+proj
            total += n_attn * (attn_params() + mlp_params(F) + 2 * D)
            total += n_rec * (rec + mlp_params(F) + 2 * D)
            return total
        if self.family == "moe":
            e = self.top_k if active_only else self.n_experts
            per_layer = attn_params() + D * self.n_experts  # router
            per_layer += e * 3 * D * self.d_expert
            total += L * (per_layer + 2 * D)
            return total
        if self.family == "audio":
            enc = self.n_encoder_layers * (attn_params() + mlp_params(F) + 2 * D)
            dec = L * (2 * attn_params() + mlp_params(F) + 3 * D)  # +cross attn
            return total + enc + dec
        # dense / vlm backbone
        total += L * (attn_params() + mlp_params(F) + 2 * D)
        return total


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES: Tuple[InputShape, ...] = (
    InputShape("train_4k", 4_096, 256, "train"),
    InputShape("prefill_32k", 32_768, 32, "prefill"),
    InputShape("decode_32k", 32_768, 128, "decode"),
    InputShape("long_500k", 524_288, 1, "decode"),
)


def get_shape(name: str) -> InputShape:
    for s in INPUT_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown input shape {name!r}; have "
                   f"{[s.name for s in INPUT_SHAPES]}")
