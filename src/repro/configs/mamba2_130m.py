"""mamba2-130m [ssm] — arXiv:2405.21060 (SSD / state-space duality).

24L d_model=768, attention-free, vocab=50280, ssm_state=128.
d_inner = 2*768 = 1536, head_dim 64 -> 24 SSD heads, conv width 4.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-130m",
    family="ssm",
    source="arXiv:2405.21060",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    norm_type="rms",
    tie_embeddings=True,
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        arch_id="mamba2-130m-smoke",
        n_layers=2, d_model=128, vocab_size=512,
        ssm_state=32, ssm_head_dim=32, ssm_chunk=16)
