"""stablelm-1.6b [dense] — [hf:stabilityai/stablelm-2-1_6b].

24L d_model=2048 32H (GQA kv=32 = full MHA) d_ff=5632 vocab=100352.
StableLM-2 uses LayerNorm, SwiGLU MLP, and partial rotary (25% of head_dim).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="stablelm-1.6b",
    family="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    norm_type="layer",
    mlp_type="swiglu",
    qk_norm=False,
    rope_theta=10_000.0,
    rope_pct=0.25,
    norm_eps=1e-5,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        arch_id="stablelm-1.6b-smoke",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=352, vocab_size=512)
