"""recurrentgemma-9b [hybrid] — arXiv:2402.19427 (Griffin).

38L d_model=4096 16H (GQA kv=1, i.e. MQA) d_ff=12288 vocab=256000.
Pattern: two RG-LRU recurrent blocks then one local (sliding-window 2048)
attention block — the "1:2" ratio.  RG-LRU width = d_model.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-9b",
    family="hybrid",
    source="arXiv:2402.19427",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    norm_type="rms",
    mlp_type="swiglu",
    rope_theta=10_000.0,
    sliding_window=2048,
    hybrid_period=3,
    rglru_width=4096,
    ssm_conv=4,
)


def smoke_config() -> ModelConfig:
    # 3 layers = one full (rec, rec, attn) group
    return CONFIG.replace(
        arch_id="recurrentgemma-9b-smoke",
        n_layers=3, d_model=128, n_heads=4, n_kv_heads=1, head_dim=32,
        d_ff=256, vocab_size=512, sliding_window=32, rglru_width=128)
