"""Assigned architecture configs (public-literature pool) + input shapes.

``get_config(arch_id)`` returns the exact published configuration;
``get_smoke_config(arch_id)`` returns the reduced same-family variant used by
CPU smoke tests (<=2 layers, d_model<=512, <=4 experts).
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from .base import INPUT_SHAPES, InputShape, ModelConfig, get_shape

ARCH_IDS: List[str] = [
    "qwen3_0_6b",
    "stablelm_1_6b",
    "qwen3_1_7b",
    "starcoder2_15b",
    "recurrentgemma_9b",
    "mamba2_130m",
    "qwen3_moe_235b_a22b",
    "phi_3_vision_4_2b",
    "whisper_medium",
    "granite_moe_1b_a400m",
]

# accepted aliases (the assignment sheet uses dashes/dots)
_ALIASES: Dict[str, str] = {
    "qwen3-0.6b": "qwen3_0_6b",
    "stablelm-1.6b": "stablelm_1_6b",
    "qwen3-1.7b": "qwen3_1_7b",
    "starcoder2-15b": "starcoder2_15b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "mamba2-130m": "mamba2_130m",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "whisper-medium": "whisper_medium",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
}


def canonical(arch_id: str) -> str:
    return _ALIASES.get(arch_id, arch_id)


def _module(arch_id: str):
    name = canonical(arch_id)
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    return importlib.import_module(f".{name}", __name__)


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).smoke_config()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = ["ARCH_IDS", "INPUT_SHAPES", "InputShape", "ModelConfig",
           "all_configs", "canonical", "get_config", "get_shape",
           "get_smoke_config"]
