"""qwen3-1.7b [dense] — Qwen3 family [hf:Qwen/Qwen3-8B].

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936, qk_norm, GQA.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-1.7b",
    family="dense",
    source="hf:Qwen/Qwen3-8B (1.7B sibling)",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    norm_type="rms",
    mlp_type="swiglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        arch_id="qwen3-1.7b-smoke",
        n_layers=2, d_model=160, n_heads=4, n_kv_heads=2, head_dim=40,
        d_ff=320, vocab_size=512)
