"""qwen3-0.6b [dense] — Qwen3 family [hf:Qwen/Qwen3-8B].

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936, qk_norm, GQA.
Qwen3 uses head_dim=128 (decoupled from d_model/n_heads).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-0.6b",
    family="dense",
    source="hf:Qwen/Qwen3-8B (0.6B sibling)",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    norm_type="rms",
    mlp_type="swiglu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        arch_id="qwen3-0.6b-smoke",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512)
