"""AdamW + schedules in pure JAX (no optax in this environment).

The optimizer is a (init, update) pair over arbitrary pytrees, matching the
optax calling convention so it can be swapped later.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jnp.ndarray], jnp.ndarray]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0

    def init(self, params: Any) -> AdamWState:
        zeros = lambda p: jax.tree_util.tree_map(
            lambda x: jnp.zeros_like(x, dtype=jnp.float32), p)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=zeros(params), nu=zeros(params))

    def update(self, grads: Any, state: AdamWState,
               params: Any) -> Tuple[Any, AdamWState]:
        step = state.step + 1
        if self.grad_clip is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        lr = self.learning_rate(step)
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m, v):
            mhat = m / c1
            vhat = v / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:   # decay matrices only (norms/biases exempt)
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def cosine_schedule(peak_lr: float, warmup_steps: int,
                    total_steps: int, min_ratio: float = 0.1):
    def lr(step: jnp.ndarray) -> jnp.ndarray:
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = peak_lr * (min_ratio + (1 - min_ratio)
                         * 0.5 * (1 + jnp.cos(math.pi * prog)))
        return jnp.where(s < warmup_steps, warm, cos)

    return lr


def constant_schedule(lr_value: float):
    return lambda step: jnp.asarray(lr_value, jnp.float32)
