"""Synthetic token data pipeline.

Deterministic, seeded, shardable: each (step, shard) pair maps to a unique
counter-based PRNG stream, so any data shard can be regenerated anywhere —
which is what makes the pipeline compatible with NALAR-style migration and
with multi-host training (every host draws only its shard).

The "corpus" is a mixture of Zipf-distributed unigrams and short repeated
motifs, which gives the language models a learnable signal (loss drops well
below log V) without any external dataset.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    motif_len: int = 8
    n_motifs: int = 64
    motif_prob: float = 0.5


class Syntheticcorpus:
    """Counter-based synthetic corpus; host-side numpy for the input
    pipeline (the device never waits on Python)."""

    def __init__(self, cfg: DataConfig) -> None:
        self.cfg = cfg
        root = np.random.default_rng(cfg.seed)
        # fixed motif bank (the learnable structure)
        self.motifs = root.integers(
            0, cfg.vocab_size, size=(cfg.n_motifs, cfg.motif_len))
        # Zipf-ish unigram distribution
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks
        self.unigram = p / p.sum()

    def _sample_doc(self, rng: np.random.Generator, length: int) -> np.ndarray:
        out = np.empty(length + self.cfg.motif_len, dtype=np.int32)
        i = 0
        while i < length:
            if rng.random() < self.cfg.motif_prob:
                m = self.motifs[rng.integers(self.cfg.n_motifs)]
                out[i:i + self.cfg.motif_len] = m
                i += self.cfg.motif_len
            else:
                out[i] = rng.choice(self.cfg.vocab_size, p=self.unigram)
                i += 1
        return out[:length]

    def batch(self, step: int, shard: int = 0, n_shards: int = 1) -> Dict[str, np.ndarray]:
        """Batch for one (step, shard).  tokens[t+1] are labels[t]."""
        assert self.cfg.global_batch % n_shards == 0
        b = self.cfg.global_batch // n_shards
        rng = np.random.default_rng(
            (self.cfg.seed, step, shard))   # counter-based stream
        toks = np.stack([self._sample_doc(rng, self.cfg.seq_len + 1)
                         for _ in range(b)])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def iterate(self, start_step: int = 0, shard: int = 0,
                n_shards: int = 1) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step, shard, n_shards)
            step += 1


def extra_inputs(cfg, batch_size: int, seed: int = 0) -> Dict[str, np.ndarray]:
    """Stub modality-frontend inputs for vlm/audio families."""
    rng = np.random.default_rng(seed)
    out: Dict[str, np.ndarray] = {}
    if cfg.family == "vlm":
        out["image_embeds"] = rng.standard_normal(
            (batch_size, cfg.n_image_tokens, cfg.d_model)).astype(np.float32)
    if cfg.family == "audio":
        out["frames"] = rng.standard_normal(
            (batch_size, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
    return out
