"""Training loop: train_step factory, grad accumulation, metrics.

``make_train_step(model, opt)`` returns the jit-able pure function the
launcher and the multi-pod dry-run lower; ``train`` is the single-process
driver used by tests and the end-to-end example (train a ~100M model for a
few hundred steps on CPU).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.model import Model
from .data import DataConfig, Syntheticcorpus, extra_inputs
from .optimizer import AdamW, AdamWState, cosine_schedule, global_norm


def make_train_step(model: Model, opt: AdamW,
                    donate: bool = True) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state: AdamWState, batch):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        new_params, new_state = opt.update(grads, opt_state, params)
        metrics = {
            "loss": loss,
            "grad_norm": global_norm(grads),
            "lr": opt.learning_rate(new_state.step),
        }
        return new_params, new_state, metrics

    return train_step


def make_grad_accum_step(model: Model, opt: AdamW, n_micro: int) -> Callable:
    """Micro-batched step: batch leading dim = n_micro * micro_batch."""

    def step(params, opt_state, batch):
        def micro(i):
            return jax.tree_util.tree_map(
                lambda x: jax.lax.dynamic_slice_in_dim(
                    x, i * (x.shape[0] // n_micro), x.shape[0] // n_micro), batch)

        def body(carry, i):
            acc, loss_acc = carry
            loss, grads = jax.value_and_grad(model.loss_fn)(params, micro(i))
            acc = jax.tree_util.tree_map(jnp.add, acc, grads)
            return (acc, loss_acc + loss), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss), _ = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32)), jnp.arange(n_micro))
        grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
        new_params, new_state = opt.update(grads, opt_state, params)
        return new_params, new_state, {"loss": loss / n_micro,
                                       "grad_norm": global_norm(grads),
                                       "lr": opt.learning_rate(new_state.step)}

    return step


@dataclass
class TrainResult:
    losses: List[float] = field(default_factory=list)
    steps: int = 0
    wall_seconds: float = 0.0

    @property
    def first_loss(self) -> float:
        return self.losses[0] if self.losses else float("nan")

    @property
    def last_loss(self) -> float:
        return self.losses[-1] if self.losses else float("nan")


def train(model: Model, *, steps: int, batch_size: int, seq_len: int,
          peak_lr: float = 3e-4, warmup: int = 20, seed: int = 0,
          log_every: int = 10,
          log_fn: Optional[Callable[[int, Dict], None]] = None) -> Tuple[dict, TrainResult]:
    """Single-process training driver (CPU-scale)."""
    cfg = model.cfg
    opt = AdamW(learning_rate=cosine_schedule(peak_lr, warmup, steps))
    rng = jax.random.PRNGKey(seed)
    params = model.init(rng)
    opt_state = opt.init(params)
    corpus = Syntheticcorpus(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq_len, global_batch=batch_size,
        seed=seed))
    step_fn = jax.jit(make_train_step(model, opt))
    extras = extra_inputs(cfg, batch_size, seed)
    result = TrainResult()
    t0 = time.perf_counter()
    for step in range(steps):
        batch = dict(corpus.batch(step))
        batch.update(extras)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        result.losses.append(loss)
        if log_fn is not None and step % log_every == 0:
            log_fn(step, {k: float(v) for k, v in metrics.items()})
    result.steps = steps
    result.wall_seconds = time.perf_counter() - t0
    return params, result
