"""Pytree checkpointing (msgpack + zstd, zlib fallback).

Layout: a single ``.ckpt`` file holding {treedef-repr, flat arrays}.  Arrays
are serialized with dtype/shape headers; bf16 round-trips through uint16
views (msgpack has no bf16).  Restoration validates structure against a
template pytree, which is what makes NALAR-style retry-with-state safe: a
resumed worker either gets exactly the structure it expects or fails loudly.

``zstandard`` is optional: when absent, payloads compress with stdlib zlib.
Files are self-describing via a 4-byte magic, so either build can restore
checkpoints written by the other (as long as the needed codec is present).
"""

from __future__ import annotations

import os
import zlib
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # pragma: no cover - depends on environment
    zstandard = None

_MAGIC_ZSTD = b"NLZS"
_MAGIC_ZLIB = b"NLZL"


def _compress(packed: bytes) -> bytes:
    if zstandard is not None:
        return _MAGIC_ZSTD + zstandard.ZstdCompressor(level=3).compress(packed)
    return _MAGIC_ZLIB + zlib.compress(packed, level=6)


def _decompress(comp: bytes) -> bytes:
    magic, body = comp[:4], comp[4:]
    if magic == _MAGIC_ZSTD:
        if zstandard is None:
            raise RuntimeError(
                "checkpoint was written with zstd but zstandard is not "
                "installed; install it or re-save with the zlib codec")
        return zstandard.ZstdDecompressor().decompress(body)
    if magic == _MAGIC_ZLIB:
        return zlib.decompress(body)
    # legacy frame (pre-magic): raw zstd stream
    if zstandard is not None:
        return zstandard.ZstdDecompressor().decompress(comp)
    raise RuntimeError("unrecognized checkpoint framing (legacy zstd file "
                       "without zstandard installed?)")


def _encode_array(x: Any) -> Dict[str, Any]:
    arr = np.asarray(x)
    if arr.dtype == jnp.bfloat16:
        return {"dtype": "bfloat16", "shape": list(arr.shape),
                "data": arr.view(np.uint16).tobytes()}
    return {"dtype": arr.dtype.str, "shape": list(arr.shape),
            "data": arr.tobytes()}


def _decode_array(d: Dict[str, Any]) -> np.ndarray:
    shape = tuple(d["shape"])
    if d["dtype"] == "bfloat16":
        raw = np.frombuffer(d["data"], np.uint16).reshape(shape)
        return raw.view(jnp.bfloat16)
    return np.frombuffer(d["data"], np.dtype(d["dtype"])).reshape(shape)


def save(path: str, tree: Any) -> int:
    """Returns bytes written."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payload = {
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "leaves": [_encode_array(x) for x in leaves],
    }
    packed = msgpack.packb(payload, use_bin_type=True)
    comp = _compress(packed)
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(comp)
    os.replace(tmp, path)   # atomic
    return len(comp)


def restore(path: str, template: Any) -> Any:
    with open(path, "rb") as f:
        comp = f.read()
    packed = _decompress(comp)
    payload = msgpack.unpackb(packed, raw=False)
    leaves, treedef = jax.tree_util.tree_flatten(template)
    if payload["n_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {payload['n_leaves']} leaves; template expects "
            f"{len(leaves)} — structure mismatch")
    if payload["treedef"] != str(treedef):
        raise ValueError("checkpoint treedef differs from template treedef")
    out: List[np.ndarray] = []
    for tpl, enc in zip(leaves, payload["leaves"]):
        arr = _decode_array(enc)
        tpl_arr = np.asarray(tpl) if not hasattr(tpl, "shape") else tpl
        if tuple(arr.shape) != tuple(tpl_arr.shape):
            raise ValueError(f"leaf shape {arr.shape} != template "
                             f"{tuple(tpl_arr.shape)}")
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def exists(path: str) -> bool:
    return os.path.exists(path)
