from .data import DataConfig, Syntheticcorpus, extra_inputs
from .optimizer import AdamW, AdamWState, constant_schedule, cosine_schedule, global_norm
from .train import TrainResult, make_grad_accum_step, make_train_step, train
from . import checkpoint

__all__ = ["AdamW", "AdamWState", "DataConfig", "Syntheticcorpus",
           "TrainResult", "checkpoint", "constant_schedule",
           "cosine_schedule", "extra_inputs", "global_norm",
           "make_grad_accum_step", "make_train_step", "train"]
