"""Activation-sharding context: lets launchers install
``with_sharding_constraint`` hints on named activations without the model
code importing mesh state.

Model code calls ``constrain(x, "logits")``; outside a mesh context (CPU
tests) it's a no-op.  The dry-run/launchers install NamedShardings keyed by
activation kind.  Constraints are rank-checked so one kind can safely cover
call sites with different ranks (only matching ranks are applied).
"""

from __future__ import annotations

import threading
from typing import Any, Dict, Optional

_state = threading.local()


def set_activation_shardings(mapping: Optional[Dict[str, Any]]) -> None:
    _state.mapping = mapping or {}


def get_activation_shardings() -> Dict[str, Any]:
    return getattr(_state, "mapping", {})


def constrain(x, kind: str):
    import jax
    sh = get_activation_shardings().get(kind)
    if sh is None:
        return x
    spec = sh.spec if hasattr(sh, "spec") else sh
    if len(spec) != x.ndim:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, sh)
    except (ValueError, TypeError):   # no mesh context
        return x


class activation_shardings:
    """Context manager form."""

    def __init__(self, mapping: Dict[str, Any]) -> None:
        self.mapping = mapping

    def __enter__(self):
        self._prev = get_activation_shardings()
        set_activation_shardings(self.mapping)
        return self

    def __exit__(self, *exc):
        set_activation_shardings(self._prev)
