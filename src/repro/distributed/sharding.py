"""Sharding rules: parameter / batch / cache PartitionSpecs per family.

Mesh axes (launch/mesh.py):
    single-pod : ("data", "model") = (16, 16)          256 chips
    multi-pod  : ("pod", "data", "model") = (2,16,16)  512 chips

Strategy (DESIGN.md §5):
  * training  — Megatron tensor parallelism over "model" (attention heads,
    FFN hidden, expert FFN width) + FSDP over "data" on a second large dim
    (the optimizer state of 15B+ models must not be replicated); batch over
    ("pod","data").
  * serving   — tensor parallelism over "model"; weights replicated over
    "data" (no optimizer state); batch over ("pod","data"); MoE experts
    over "data" with expert-FFN width over "model".
  * decode    — KV cache: batch over ("pod","data") when divisible, KV
    length over "model" (flash-decoding style partial softmax); for
    global_batch=1 long-context, KV length additionally shards over "data"
    (context parallelism — a beyond-paper optimization, EXPERIMENTS.md §Perf).

Every rule degrades to replication when a dimension isn't divisible by the
axis size (e.g. 4-8 KV heads never shard over model=16; granite's vocab
49155 is odd, so its embedding shards d_model instead).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import InputShape, ModelConfig


def axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        out = 1
        for n in name:
            out *= axis_size(mesh, n)
        return out
    return mesh.shape[name] if name in mesh.shape else 1


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def _div(dim: int, mesh: Mesh, name) -> bool:
    n = axis_size(mesh, name)
    return n > 1 and dim % n == 0 and dim >= n


class ShardingRules:
    """Builds PartitionSpec trees for a (cfg, mesh, mode)."""

    def __init__(self, cfg: ModelConfig, mesh: Mesh, mode: str = "train") -> None:
        assert mode in ("train", "serve")
        self.cfg = cfg
        self.mesh = mesh
        self.mode = mode
        self.batch = batch_axes(mesh)

    # ------------------------------------------------------------ helpers
    def _fsdp(self, dim: int):
        """Secondary (FSDP) axis for training; None when serving."""
        if self.mode == "train" and _div(dim, self.mesh, "data"):
            return "data"
        return None

    def _model(self, dim: int):
        return "model" if _div(dim, self.mesh, "model") else None

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    # ------------------------------------------------------- param specs
    def param_spec(self, path: Tuple[str, ...], shape: Tuple[int, ...]) -> P:
        """Rule table keyed on the leaf name (+ context)."""
        name = path[-1]
        stacked = len(path) >= 2 and path[0] in (
            "layers", "rec_layers", "att_layers", "enc_layers", "dec_layers")
        L = (None,) if stacked else ()
        d = shape[len(L):]  # dims after the layer-stack dim

        def spec(*axes) -> P:
            return P(*L, *axes)

        # ---- embeddings ----
        # NOTE: never FSDP the d_model dim of embedding tables.  The unembed
        # contraction x[...,d] @ W[v,d] with d sharded over "data" (which
        # also shards the batch) forces GSPMD to materialize replicated
        # [B,S,V] logits — measured as 3 x ~40 GB per-device collectives on
        # qwen3-0.6b train_4k (EXPERIMENTS.md §Perf iteration 1).
        if path[0] == "embed":
            if name == "tok":     # [V, D]
                if _div(shape[0], self.mesh, "model"):
                    return P("model", None)
                return P(None, self._model(shape[1]))
            if name == "out":     # [D, V]
                if _div(shape[1], self.mesh, "model"):
                    return P(None, "model")
                return P(self._model(shape[0]), None)
        if name == "enc_pos":
            return P(None, None)

        # ---- attention ----
        if len(path) >= 2 and path[-2] in ("attn", "xattn"):
            if name == "wq":      # [D, H, Dh]
                return spec(self._fsdp(d[0]), self._model(d[1]), None)
            if name in ("wk", "wv"):
                if _div(d[1], self.mesh, "model"):
                    return spec(self._fsdp(d[0]), "model", None)
                return spec(self._fsdp(d[0]), None, None)
            if name == "wo":      # [H, Dh, D]
                return spec(self._model(d[0]), None, self._fsdp(d[2]))
            if name in ("q_norm", "k_norm"):
                return spec(None)

        # ---- dense MLP ----
        if name in ("w_gate", "w_up") and len(d) == 2:   # [D, F]
            return spec(self._fsdp(d[0]), self._model(d[1]))
        if name == "w_down" and len(d) == 2:             # [F, D]
            return spec(self._model(d[0]), self._fsdp(d[1]))

        # ---- MoE experts ----
        # Experts shard over "model"; tokens/groups shard over "data", so
        # dispatch/combine einsums stay shard-local (each data shard routes
        # its own token groups to its model-shard experts).  Sharding E over
        # "data" instead collides with the token sharding and GSPMD
        # all-reduces the full [E,C,D] expert buffer per group x layer —
        # measured at 7.8e14 B/device on qwen3-moe prefill_32k (§Perf iter
        # 2).  Training adds FSDP on the expert width for optimizer memory.
        if name == "router":                              # [D, E]
            return spec(None, None)
        if name in ("w_gate", "w_up") and len(d) == 3:    # [E, D, F]
            e_ax = self._model(d[0])
            return spec(e_ax, None, self._fsdp(d[2]))
        if name == "w_down" and len(d) == 3:              # [E, F, D]
            e_ax = self._model(d[0])
            return spec(e_ax, self._fsdp(d[1]), None)

        # ---- SSM (mamba2): small model, replicate weights ----
        if name in ("in_proj", "conv_w", "conv_b", "A_log", "D_skip",
                    "dt_bias", "gate_norm", "out_proj"):
            if name == "out_proj":   # [din, D]
                return spec(self._model(d[0]), None)
            if name == "in_proj":    # [D, X]
                return spec(None, None)
            return spec(*(None,) * len(d))

        # ---- hybrid (RG-LRU): shard recurrence width over model ----
        if name in ("w_rnn_in", "w_gate_in"):             # [D, W]
            return spec(self._fsdp(d[0]), self._model(d[1]))
        if name in ("w_a", "w_x"):                        # [W, W]
            return spec(None, self._model(d[1]))
        if name in ("b_a", "b_x", "lam"):                 # [W]
            return spec(self._model(d[0]))
        if name == "w_out":                               # [W, D]
            return spec(self._model(d[0]), self._fsdp(d[1]))

        # hybrid conv over sharded width
        if name in ("conv_w",):                           # [K, W]
            return spec(None, self._model(d[1]))
        if name == "conv_b":
            return spec(self._model(d[0]))

        # ---- norms / scalars / anything else: replicate ----
        return spec(*(None,) * len(d))

    def param_specs(self, shapes: Any) -> Any:
        def visit(path, leaf):
            names = tuple(p.key if hasattr(p, "key") else str(p) for p in path)
            return self.param_spec(names, tuple(leaf.shape))

        return jax.tree_util.tree_map_with_path(visit, shapes)

    def param_shardings(self, shapes: Any) -> Any:
        return jax.tree_util.tree_map(self.named, self.param_specs(shapes),
                                      is_leaf=lambda x: isinstance(x, P))

    # ------------------------------------------------------- batch specs
    def batch_spec(self, shape: InputShape) -> Dict[str, P]:
        b = self.batch if shape.global_batch % axis_size(self.mesh, self.batch) == 0 \
            else (self.batch[-1] if shape.global_batch % axis_size(self.mesh, "data") == 0
                  else None)
        if shape.kind == "train":
            out = {"tokens": P(b, None), "labels": P(b, None)}
        elif shape.kind == "prefill":
            out = {"tokens": P(b, None)}
        else:
            out = {"token": P(b)}
        # stub frontend inputs
        if self.cfg.family == "vlm" and shape.kind != "decode":
            out["image_embeds"] = P(b, None, None)
        if self.cfg.family == "audio" and shape.kind != "decode":
            out["frames"] = P(b, None, None)
        return out

    # ------------------------------------------------------- cache specs
    def cache_specs(self, cache_shapes: Any, shape: InputShape) -> Any:
        """Specs for the decode KV/state cache."""
        B = shape.global_batch
        b_ax = None
        if B % axis_size(self.mesh, self.batch) == 0:
            b_ax = self.batch
        elif B % axis_size(self.mesh, "data") == 0:
            b_ax = "data"
        long_ctx = B == 1   # long_500k: context parallelism over "data"

        def visit(path, leaf):
            names = tuple(p.key if hasattr(p, "key") else str(p) for p in path)
            name = names[-1]
            shp = tuple(leaf.shape)
            if name == "pos":
                return P(b_ax)
            if name in ("k", "v", "xk", "xv"):
                # [L, B, C, Hkv, Dh].  KV-length sharding (flash-decoding
                # style) only pays when the batch can't shard (B == 1
                # long-context): for batched decode, scattering the per-seq
                # ring-buffer update into a model-sharded C dim makes GSPMD
                # fully rematerialize the cache every step (§Perf iter 3).
                seq_axes = []
                if long_ctx:
                    if _div(shp[2], self.mesh, "data"):
                        seq_axes.append("data")
                    rem = shp[2] // (axis_size(self.mesh, "data")
                                     if "data" in seq_axes else 1)
                    if _div(rem, self.mesh, "model"):
                        seq_axes.append("model")
                else:
                    # prefer head sharding over model when it divides
                    if _div(shp[3], self.mesh, "model"):
                        return P(None, b_ax, None, "model", None)
                seq = tuple(seq_axes) if seq_axes else None
                return P(None, b_ax, seq, None, None)
            if name == "conv":
                # [n, B, K-1, W] (hybrid) or [L, B, K-1, conv_dim] (ssm)
                w_ax = self._model(shp[3]) if self.cfg.family == "hybrid" else None
                return P(None, b_ax, None, w_ax)
            if name == "h":      # [n, B, W]
                return P(None, b_ax, self._model(shp[2]))
            if name == "ssm":    # [L, B, H, P, N]
                return P(None, b_ax, None, None, None)
            return P(*(None,) * len(shp))

        return jax.tree_util.tree_map_with_path(visit, cache_shapes)

    # ---------------------------------------------------- optimizer state
    def opt_specs(self, param_specs: Any) -> Any:
        """AdamWState(step, mu, nu): moments mirror the param specs."""
        from ..training.optimizer import AdamWState
        return AdamWState(step=P(), mu=param_specs, nu=param_specs)

    # ----------------------------------------------------------- outputs
    def logits_spec(self, shape: InputShape) -> P:
        b = self.batch if shape.global_batch % axis_size(self.mesh, self.batch) == 0 else None
        v_ax = "model" if _div(self.cfg.vocab_size, self.mesh, "model") else None
        if shape.kind == "train":
            return P(b, None, v_ax)
        return P(b, v_ax)


def to_sds(shapes: Any, shardings: Any) -> Any:
    """Attach NamedShardings to a ShapeDtypeStruct tree."""
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)
