"""Pure-jnp oracle: delegates to the model's chunked SSD reference."""

from __future__ import annotations

import jax.numpy as jnp

from ...models.ssm import ssd_chunked


def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
            Bm: jnp.ndarray, Cm: jnp.ndarray, *, chunk: int = 128) -> jnp.ndarray:
    y, _final = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    return y


def ssd_sequential_ref(x, dt, A, Bm, Cm):
    """Token-by-token recurrence — the ground truth both chunked forms
    must match: h_t = exp(-dt_t A) h_{t-1} + dt_t x_t B_t^T; y_t = C_t h_t."""
    import jax
    import jax.numpy as jnp
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp                  # [B,H,P], [B,H], [B,N], [B,N]
        decay = jnp.exp(-dtt * A[None, :])     # [B,H]
        upd = jnp.einsum("bh,bhp,bn->bhpn", dtt, xt, bt)
        h = h * decay[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", ct, h)
        return h, y

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          Bm.transpose(1, 0, 2).astype(jnp.float32),
          Cm.transpose(1, 0, 2).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)      # [B,S,H,P]
