"""Mamba2 SSD (state-space dual) chunk-scan Pallas TPU kernel.

The SSD dual form is TPU-friendly by construction: each chunk contributes
an attention-like [Q, Q] block (MXU matmuls) plus a rank-N state update,
and chunks chain through a tiny [P, N] recurrent state.  The kernel maps
one (batch, head) pair per grid row and walks chunks sequentially with the
inter-chunk state in VMEM scratch — the same persistent-scratch pattern the
flash kernel uses for its running softmax.

Inputs are pre-projected per head:
    x  [B, H, S, P]   inputs       dt [B, H, S]   step sizes (>0)
    Bm [B, S, N]      input proj   Cm [B, S, N]   output proj
    A  [H]            positive decay rates

Block sizes: Q (chunk) x P (head dim) and Q x N tiles; Q=128..256 keeps
everything MXU-aligned (P=64, N=128 in mamba2-130m).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_scr, *,
                chunk: int):
    h = pl.program_id(1)
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    A = a_ref[h]                                         # scalar rate > 0
    x = x_ref[0, 0, 0].astype(jnp.float32)               # [Q, P]
    dt = dt_ref[0, 0, 0].astype(jnp.float32)             # [1, Q] (lane-major)
    Bm = b_ref[0, 0].astype(jnp.float32)                 # [Q, N]
    Cm = c_ref[0, 0].astype(jnp.float32)                 # [Q, N]

    log_a = -dt[0] * A                                   # [Q]
    cum = jnp.cumsum(log_a)                              # [Q]

    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for j <= i
    diff = cum[:, None] - cum[None, :]
    Q = x.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(jj <= ii, jnp.exp(diff), 0.0)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    M = scores * L                                       # [Q, Q]
    xdt = x * dt[0][:, None]                             # [Q, P]
    y = jax.lax.dot(M, xdt, preferred_element_type=jnp.float32)

    # inter-chunk: y_i += (C_i . S_in) * exp(cum_i)
    state = state_scr[...]                               # [N, P]
    y = y + jax.lax.dot(Cm, state,
                        preferred_element_type=jnp.float32) * jnp.exp(cum)[:, None]

    # state update: S_out = exp(cum_Q) S_in + sum_j exp(cum_Q - cum_j) B_j (dt_j x_j)^T
    total = cum[-1]
    decay_to_end = jnp.exp(total - cum)                  # [Q]
    state_scr[...] = (state * jnp.exp(total)
                      + jax.lax.dot_general(
                          Bm * decay_to_end[:, None], xdt,
                          (((0,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32))
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)


def ssd_chunk_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                   Bm: jnp.ndarray, Cm: jnp.ndarray, *, chunk: int = 128,
                   interpret: bool = True):
    """x: [B,S,H,P]; dt: [B,S,H]; A: [H]; Bm/Cm: [B,S,N] -> y [B,S,H,P]."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, f"seq {S} % chunk {Q} != 0"
    nc = S // Q

    xt = x.transpose(0, 2, 1, 3).reshape(Bsz, H, nc, Q, P)
    dtt = dt.transpose(0, 2, 1).reshape(Bsz, H, nc, 1, Q)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    kernel = functools.partial(_ssd_kernel, chunk=Q)
    grid = (Bsz, H, nc)
    y = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, 1, Q, P),
                             lambda b, h, c, a: (b, h, c, 0, 0)),
                pl.BlockSpec((1, 1, 1, 1, Q),
                             lambda b, h, c, a: (b, h, c, 0, 0)),
                pl.BlockSpec((1, 1, Q, N), lambda b, h, c, a: (b, c, 0, 0)),
                pl.BlockSpec((1, 1, Q, N), lambda b, h, c, a: (b, c, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, 1, Q, P),
                                   lambda b, h, c, a: (b, h, c, 0, 0)),
            scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((Bsz, H, nc, Q, P), x.dtype),
        interpret=interpret,
    )(A.astype(jnp.float32), xt, dtt, Bc, Cc)
    return y.reshape(Bsz, H, S, P).transpose(0, 2, 1, 3)
