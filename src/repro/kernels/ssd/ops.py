"""jit'd wrapper for the SSD kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ssd import ssd_chunk_scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_fused(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
              Bm: jnp.ndarray, Cm: jnp.ndarray, *, chunk: int = 128) -> jnp.ndarray:
    return ssd_chunk_scan(x, dt, A, Bm, Cm, chunk=chunk,
                          interpret=not _on_tpu())
