"""Grouped expert-FFN Pallas TPU kernel (the MoE MXU hot-spot).

Computes, for every expert e over its capacity buffer:

    out[e] = (silu(x[e] @ w_gate[e]) * (x[e] @ w_up[e])) @ w_down[e]

as one fused kernel: grid (E, C/BC, F/BF) with the F (expert hidden) dim
innermost/sequential; the [BC, D] output accumulator lives in VMEM scratch
across F tiles, so the three matmuls of the SwiGLU never round-trip the
[C, F] activation through HBM.  Block shapes are MXU-aligned (BC=128,
BF=128 by default; D rides along whole).

This pairs with the dispatch/combine layer above it: dispatch produces the
[E, C, D] buffers (shard-local after §Perf iteration 2), this kernel is the
per-shard compute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _moe_ffn_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_scr):
    fi = pl.program_id(2)
    nf = pl.num_programs(2)

    @pl.when(fi == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0].astype(jnp.float32)          # [BC, D]
    wg = wg_ref[0].astype(jnp.float32)        # [D, BF]
    wu = wu_ref[0].astype(jnp.float32)
    wd = wd_ref[0].astype(jnp.float32)        # [BF, D]
    g = jax.lax.dot(x, wg, preferred_element_type=jnp.float32)
    u = jax.lax.dot(x, wu, preferred_element_type=jnp.float32)
    h = (g * jax.nn.sigmoid(g)) * u           # silu(g) * u, [BC, BF]
    acc_scr[...] += jax.lax.dot(h, wd, preferred_element_type=jnp.float32)

    @pl.when(fi == nf - 1)
    def _fin():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def moe_ffn(xe: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
            w_down: jnp.ndarray, *, block_c: int = 128, block_f: int = 128,
            interpret: bool = True) -> jnp.ndarray:
    """xe: [E, C, D]; w_gate/w_up: [E, D, F]; w_down: [E, F, D] -> [E, C, D]."""
    E, C, D = xe.shape
    F = w_gate.shape[-1]
    bc = min(block_c, C)
    bf = min(block_f, F)
    pad_c = (-C) % bc
    pad_f = (-F) % bf
    if pad_c:
        xe = jnp.pad(xe, ((0, 0), (0, pad_c), (0, 0)))
    if pad_f:
        w_gate = jnp.pad(w_gate, ((0, 0), (0, 0), (0, pad_f)))
        w_up = jnp.pad(w_up, ((0, 0), (0, 0), (0, pad_f)))
        w_down = jnp.pad(w_down, ((0, 0), (0, pad_f), (0, 0)))
    Cp, Fp = C + pad_c, F + pad_f
    grid = (E, Cp // bc, Fp // bf)
    out = pl.pallas_call(
        _moe_ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, D), lambda e, c, f: (e, c, 0)),
            pl.BlockSpec((1, D, bf), lambda e, c, f: (e, 0, f)),
            pl.BlockSpec((1, D, bf), lambda e, c, f: (e, 0, f)),
            pl.BlockSpec((1, bf, D), lambda e, c, f: (e, f, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc, D), lambda e, c, f: (e, c, 0)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, D), xe.dtype),
        scratch_shapes=[pltpu.VMEM((bc, D), jnp.float32)],
        interpret=interpret,
    )(xe, w_gate, w_up, w_down)
    return out[:, :C]
