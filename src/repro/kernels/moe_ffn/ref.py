"""Pure-jnp oracle: the model's expert FFN."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_ffn_ref(xe: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
                w_down: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe.astype(jnp.float32),
                               w_gate.astype(jnp.float32)))
    h = h * jnp.einsum("ecd,edf->ecf", xe.astype(jnp.float32),
                       w_up.astype(jnp.float32))
    return jnp.einsum("ecf,efd->ecd", h,
                      w_down.astype(jnp.float32)).astype(xe.dtype)
