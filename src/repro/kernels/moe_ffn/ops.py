"""jit'd wrapper for the grouped expert-FFN kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .moe_ffn import moe_ffn


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_c", "block_f"))
def moe_ffn_fused(xe: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
                  w_down: jnp.ndarray, *, block_c: int = 128,
                  block_f: int = 128) -> jnp.ndarray:
    return moe_ffn(xe, w_gate, w_up, w_down, block_c=block_c,
                   block_f=block_f, interpret=not _on_tpu())
