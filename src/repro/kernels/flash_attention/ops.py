"""jit'd public wrapper for the flash-attention kernel.

Model code calls ``flash_attention(q, k, v)`` with [B, S, H, D] layouts;
this wrapper folds (B, H) -> BH (the kernel's batch grid dim), picks
interpret mode off-TPU, and restores the layout.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "block_q", "block_k"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128) -> jnp.ndarray:
    """q/k/v: [B, S, H, D] (k/v already GQA-expanded to H heads)."""
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, Skv, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, Skv, D)
    out = flash_attention_bhsd(qf, kf, vf, causal=causal, window=window,
                               scale=scale, block_q=block_q, block_k=block_k,
                               interpret=not _on_tpu())
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
