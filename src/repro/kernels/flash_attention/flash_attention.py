"""Flash attention (prefill/training) Pallas TPU kernel.

TPU adaptation notes (DESIGN.md §2): the GPU flash-attention formulation
(warps, shared-memory tiles) is re-thought for the TPU memory hierarchy —
BlockSpec tiles stage q/k/v HBM->VMEM in MXU-aligned blocks (q: BQ x Dh,
k/v: BK x Dh with BQ=BK=128 by default); the running-softmax state (m, l,
acc) lives in VMEM scratch that persists across the sequential innermost
grid dimension (TPU grids execute in order, which replaces the GPU's
explicit software pipeline across KV tiles).

Grid: (batch*heads, Sq/BQ, Skv/BK); the KV dim is innermost/sequential.
Causal and sliding-window masking are applied per-tile; fully-masked tiles
short-circuit via pl.when (on TPU this skips the DMA+MXU work).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, kv_len: int,
                  causal: bool, window: Optional[int]):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # tile-level reachability (static grid; dynamic predicate)
    reachable = jnp.asarray(True)
    if causal:
        reachable = reachable & (k_start <= q_start + block_q - 1)
    if window is not None:
        reachable = reachable & (k_start + block_k - 1 >= q_start - window + 1)

    @pl.when(reachable)
    def _compute():
        q = q_ref[0].astype(jnp.float32)              # [BQ, D]
        k = k_ref[0].astype(jnp.float32)              # [BK, D]
        v = v_ref[0].astype(jnp.float32)              # [BK, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < kv_len
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                            # [BQ, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)               # [BQ, 1]
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, ...] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                         causal: bool = True, window: Optional[int] = None,
                         scale: Optional[float] = None,
                         block_q: int = DEFAULT_BLOCK_Q,
                         block_k: int = DEFAULT_BLOCK_K,
                         interpret: bool = True) -> jnp.ndarray:
    """q: [BH, Sq, D]; k/v: [BH, Skv, D] (GQA already expanded).  -> [BH,Sq,D]."""
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    scale = D ** -0.5 if scale is None else scale
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    pad_q = (-Sq) % bq
    pad_k = (-Skv) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    Sq_p, Skv_p = Sq + pad_q, Skv + pad_k
    grid = (BH, Sq_p // bq, Skv_p // bk)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=bq, block_k=bk, kv_len=Skv,
        causal=causal, window=window)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq_p, D), q.dtype),
        scratch_shapes=[
            # running softmax state, persistent across the sequential kv dim
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
