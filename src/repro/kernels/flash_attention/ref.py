"""Pure-jnp oracle for flash attention."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: Optional[int] = None,
                  scale: Optional[float] = None) -> jnp.ndarray:
    """q: [BH, Sq, D]; k/v: [BH, Skv, D] -> [BH, Sq, D]. f32 softmax."""
    D = q.shape[-1]
    scale = D ** -0.5 if scale is None else scale
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    Sq, Skv = q.shape[1], k.shape[1]
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    logits = jnp.where(mask[None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)
