"""Decode attention Pallas TPU kernels (the serving hot-spot).

Two kernels:

1. ``decode_ring_kernel`` — single-token attention over the model's dense
   per-slot ring-buffer cache [B, C, Hkv, Dh] with per-sequence positions
   and optional sliding window.  This is the kernel behind
   ``layers.decode_attention(impl="pallas")``.

2. ``paged_decode_kernel`` — attention over the engine's paged pool
   ([n_pages, page, Hkv, Dh]) indexed through per-sequence page tables,
   using PrefetchScalarGridSpec so the page table is available to the
   BlockSpec index_map (the TPU-native equivalent of vLLM's block tables:
   pages stage HBM->VMEM by table lookup, no gather materialization).

TPU adaptation (DESIGN.md §2): vLLM's GPU kernel assigns a warp per head
and 16-token blocks; here the unit of work is a (batch, kv-head) grid cell
with KV staged in MXU-aligned [page, Dh] tiles and the GQA group (n_rep
query heads) processed as one [n_rep, Dh] matmul per tile — the MXU eats
the whole query group at once, which is the systolic-array-friendly
reformulation of the warp-per-head design.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


# ---------------------------------------------------------------- ring cache
def _ring_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                 *, scale: float, block_k: int, cache_len: int,
                 window: Optional[int]):
    """Grid: (B, Hkv, C/BK).  q_ref: [1, 1, n_rep, D]; k/v: [1, BK, 1, D]."""
    b = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    pos = pos_ref[b]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                 # [n_rep, D]
    k = k_ref[0, :, 0].astype(jnp.float32)              # [BK, D]
    v = v_ref[0, :, 0].astype(jnp.float32)              # [BK, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    slots = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    if window is not None:
        age = (pos % cache_len - slots) % cache_len
        valid = age < jnp.minimum(window, pos + 1)
    else:
        valid = slots <= pos
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _fin():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


def decode_ring(q: jnp.ndarray, cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                pos: jnp.ndarray, *, scale: float, n_rep: int,
                window: Optional[int] = None, block_k: int = 128,
                interpret: bool = True) -> jnp.ndarray:
    """q: [B, 1, H, D]; cache: [B, C, Hkv, D]; pos: [B] -> [B, 1, H, D]."""
    B, C, Hkv, D = cache_k.shape
    H = Hkv * n_rep
    qg = q[:, 0].reshape(B, Hkv, n_rep, D)
    bk = min(block_k, C)
    pad = (-C) % bk
    if pad:
        cache_k = jnp.pad(cache_k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cache_v = jnp.pad(cache_v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Cp = C + pad
    # padded slots never hold valid entries: slot >= C > pos (no window) and
    # age >= window (window case) because the ring arithmetic uses cache_len=C
    kernel = functools.partial(_ring_kernel, scale=scale, block_k=bk,
                               cache_len=C, window=window)
    grid = (B, Hkv, Cp // bk)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, n_rep, D), lambda b, h, j, pos: (b, h, 0, 0)),
                pl.BlockSpec((1, bk, 1, D), lambda b, h, j, pos: (b, j, h, 0)),
                pl.BlockSpec((1, bk, 1, D), lambda b, h, j, pos: (b, j, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, n_rep, D),
                                   lambda b, h, j, pos: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((n_rep, 1), jnp.float32),
                pltpu.VMEM((n_rep, 1), jnp.float32),
                pltpu.VMEM((n_rep, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, n_rep, D), q.dtype),
        interpret=interpret,
    )(pos.astype(jnp.int32), qg, cache_k, cache_v)
    return out.reshape(B, 1, H, D)


# --------------------------------------------------------------- paged cache
def _paged_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float, page: int):
    """Grid: (B, Hkv, max_pages).  Page j of sequence b is pool page
    pt_ref[b, j] (the index_map already staged it into k_ref/v_ref)."""
    b = pl.program_id(0)
    ji = pl.program_id(2)
    nj = pl.num_programs(2)
    length = len_ref[b]

    @pl.when(ji == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid_page = pt_ref[b, ji] >= 0

    @pl.when(valid_page)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # [n_rep, D]
        k = k_ref[0, :, 0].astype(jnp.float32)          # [page, D]
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        tok = ji * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(tok < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ji == nj - 1)
    def _fin():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


def paged_decode(q: jnp.ndarray, k_pages: jnp.ndarray, v_pages: jnp.ndarray,
                 page_table: jnp.ndarray, lengths: jnp.ndarray, *,
                 scale: float, n_rep: int,
                 interpret: bool = True) -> jnp.ndarray:
    """q: [B, H, D]; pages: [n_pages, page, Hkv, D];
    page_table: [B, max_pages] (pool indices, -1 = unused);
    lengths: [B] valid tokens.  -> [B, H, D].
    """
    B, H, D = q.shape
    n_pages, page, Hkv, _ = k_pages.shape
    max_pages = page_table.shape[1]
    qg = q.reshape(B, Hkv, n_rep, D)

    def kv_index(b, h, j, pt, lens):
        # table lookup inside the index_map: the DMA fetches exactly the
        # page this grid cell needs (clamped for padded slots)
        p = jnp.maximum(pt[b, j], 0)
        return (p, 0, h, 0)

    kernel = functools.partial(_paged_kernel, scale=scale, page=page)
    grid = (B, Hkv, max_pages)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, n_rep, D),
                             lambda b, h, j, pt, lens: (b, h, 0, 0)),
                pl.BlockSpec((1, page, 1, D), kv_index),
                pl.BlockSpec((1, page, 1, D), kv_index),
            ],
            out_specs=pl.BlockSpec((1, 1, n_rep, D),
                                   lambda b, h, j, pt, lens: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((n_rep, 1), jnp.float32),
                pltpu.VMEM((n_rep, 1), jnp.float32),
                pltpu.VMEM((n_rep, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, n_rep, D), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32),
      qg, k_pages, v_pages)
    return out.reshape(B, H, D)


# -------------------------------------------------------- paged chunk decode
def _paged_chunk_kernel(pt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                        m_scr, l_scr, acc_scr, *, scale: float, page: int,
                        n_rep: int):
    """Grid: (B, Hkv, max_pages).  q_ref: [1, 1, R, D] with R = T*n_rep query
    rows; row r belongs to chunk token ``r // n_rep`` at logical position
    ``pos[b] + r // n_rep``.  The chunk's own K/V is already scattered into
    the pages (write-then-attend), so per-row causal masking
    ``tok <= pos + t`` is the only mask needed."""
    b = pl.program_id(0)
    ji = pl.program_id(2)
    nj = pl.num_programs(2)
    pos = pos_ref[b]

    @pl.when(ji == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    valid_page = pt_ref[b, ji] >= 0

    @pl.when(valid_page)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # [R, D]
        k = k_ref[0, :, 0].astype(jnp.float32)          # [page, D]
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        tok = ji * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        qpos = pos + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // n_rep
        s = jnp.where(tok <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ji == nj - 1)
    def _fin():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


def paged_decode_chunk(q: jnp.ndarray, k_pages: jnp.ndarray,
                       v_pages: jnp.ndarray, page_table: jnp.ndarray,
                       pos: jnp.ndarray, *, scale: float, n_rep: int,
                       interpret: bool = True) -> jnp.ndarray:
    """Chunk-extended paged decode: q [B, T, H, D] over pool pages.

    ``pos`` [B] is each sequence's first chunk position; chunk token t
    queries positions <= pos+t.  The caller must have scattered the chunk's
    K/V into the pages already.  Rows past a sequence's valid length attend
    unwritten positions and return garbage — callers discard them (the
    engine reads row ``valid_len[b]-1`` only).  -> [B, T, H, D].
    """
    B, T, H, D = q.shape
    n_pages, page, Hkv, _ = k_pages.shape
    max_pages = page_table.shape[1]
    R = T * n_rep
    # head h = kv*n_rep + rep (repeat_kv layout); row r = t*n_rep + rep
    qg = (q.reshape(B, T, Hkv, n_rep, D)
          .transpose(0, 2, 1, 3, 4).reshape(B, Hkv, R, D))

    def kv_index(b, h, j, pt, pos_):
        p = jnp.maximum(pt[b, j], 0)
        return (p, 0, h, 0)

    kernel = functools.partial(_paged_chunk_kernel, scale=scale, page=page,
                               n_rep=n_rep)
    grid = (B, Hkv, max_pages)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, R, D),
                             lambda b, h, j, pt, pos_: (b, h, 0, 0)),
                pl.BlockSpec((1, page, 1, D), kv_index),
                pl.BlockSpec((1, page, 1, D), kv_index),
            ],
            out_specs=pl.BlockSpec((1, 1, R, D),
                                   lambda b, h, j, pt, pos_: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((R, 1), jnp.float32),
                pltpu.VMEM((R, 1), jnp.float32),
                pltpu.VMEM((R, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, R, D), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), pos.astype(jnp.int32),
      qg, k_pages, v_pages)
    return (out.reshape(B, Hkv, T, n_rep, D)
            .transpose(0, 2, 1, 3, 4).reshape(B, T, H, D))
