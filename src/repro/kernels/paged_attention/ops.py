"""jit'd wrappers for the decode kernels."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .paged_attention import decode_ring, paged_decode, paged_decode_chunk


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("window", "scale", "n_rep"))
def decode_attention_kernel(q: jnp.ndarray, cache_k: jnp.ndarray,
                            cache_v: jnp.ndarray, pos: jnp.ndarray, *,
                            window: Optional[int], scale: float,
                            n_rep: int) -> jnp.ndarray:
    """Drop-in for models.layers.decode_attention (impl='pallas')."""
    return decode_ring(q, cache_k, cache_v, pos, scale=scale, n_rep=n_rep,
                       window=window, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("scale", "n_rep"))
def paged_decode_attention(q: jnp.ndarray, k_pages: jnp.ndarray,
                           v_pages: jnp.ndarray, page_table: jnp.ndarray,
                           lengths: jnp.ndarray, *, scale: float,
                           n_rep: int) -> jnp.ndarray:
    """Engine-side paged decode over the KV pool (vLLM block-table analogue)."""
    return paged_decode(q, k_pages, v_pages, page_table, lengths,
                        scale=scale, n_rep=n_rep, interpret=not _on_tpu())


def paged_decode_chunk_attention(q: jnp.ndarray, k_pages: jnp.ndarray,
                                 v_pages: jnp.ndarray,
                                 page_table: jnp.ndarray, pos: jnp.ndarray,
                                 *, scale: float, n_rep: int) -> jnp.ndarray:
    """Chunk-extended paged decode (q [B,T,H,D]) over the KV pool.

    Not jitted here: callers invoke it inside an already-jitted layer scan
    (``models.transformer.decode_chunk_paged`` with ``kernel=True``)."""
    return paged_decode_chunk(q, k_pages, v_pages, page_table, pos,
                              scale=scale, n_rep=n_rep,
                              interpret=not _on_tpu())
