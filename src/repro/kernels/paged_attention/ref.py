"""Pure-jnp oracles for the decode kernels."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def decode_ring_ref(q: jnp.ndarray, cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                    pos: jnp.ndarray, *, scale: float, n_rep: int,
                    window: Optional[int] = None) -> jnp.ndarray:
    """Identical math to models.layers.decode_attention (xla path)."""
    B, C, Hkv, D = cache_k.shape

    def rep(x):
        return jnp.broadcast_to(x[:, :, :, None, :], (B, C, Hkv, n_rep, D)
                                ).reshape(B, C, Hkv * n_rep, D)

    k, v = rep(cache_k), rep(cache_v)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    slots = jnp.arange(C)
    if window is not None:
        age = (pos[:, None] % C - slots[None, :]) % C
        valid = age < jnp.minimum(window, pos[:, None] + 1)
    else:
        valid = slots[None, :] <= pos[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)


def paged_decode_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                     v_pages: jnp.ndarray, page_table: jnp.ndarray,
                     lengths: jnp.ndarray, *, scale: float,
                     n_rep: int) -> jnp.ndarray:
    """Gather pages densely, then plain attention."""
    B, H, D = q.shape
    n_pages, page, Hkv, _ = k_pages.shape
    max_pages = page_table.shape[1]
    pt = jnp.maximum(page_table, 0)
    k = k_pages[pt].reshape(B, max_pages * page, Hkv, D)
    v = v_pages[pt].reshape(B, max_pages * page, Hkv, D)
    out = decode_ring_ref(q[:, None], k, v, lengths - 1, scale=scale,
                          n_rep=n_rep, window=None)
    # mask by real length: decode_ring_ref valid = slots <= pos = length-1 ✓
    return out[:, 0]


def paged_decode_chunk_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                           v_pages: jnp.ndarray, page_table: jnp.ndarray,
                           pos: jnp.ndarray, *, scale: float,
                           n_rep: int) -> jnp.ndarray:
    """Gather pages densely, then per-row causal attention (q [B,T,H,D];
    chunk token t attends positions <= pos[b]+t)."""
    B, T, H, D = q.shape
    n_pages, page, Hkv, _ = k_pages.shape
    max_pages = page_table.shape[1]
    pt = jnp.maximum(page_table, 0)
    C = max_pages * page

    def rep(pages):
        x = pages[pt].reshape(B, C, Hkv, D)
        return jnp.broadcast_to(x[:, :, :, None, :], (B, C, Hkv, n_rep, D)
                                ).reshape(B, C, Hkv * n_rep, D)

    k, v = rep(k_pages), rep(v_pages)
    logits = jnp.einsum("bthd,bkhd->bhtk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = pos[:, None] + jnp.arange(T)[None, :]           # [B,T]
    valid = jnp.arange(C)[None, None, :] <= qpos[:, :, None]
    logits = jnp.where(valid[:, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhtk,bkhd->bthd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)
