"""RG-LRU linear-recurrence scan Pallas TPU kernel.

The paper family (Griffin/RecurrentGemma) ships a custom GPU scan kernel;
the TPU-native adaptation is a *blocked sequential scan*: grid over
(batch, width-blocks, seq-chunks) with the hidden state h [1, BW] resident
in VMEM scratch across the sequential seq-chunk dimension.  Within a chunk
the recurrence h_t = a_t h_{t-1} + b_t is unrolled over VPU lanes (the
recurrence is elementwise/diagonal, so the width dim vectorizes perfectly
and shards over the `model` mesh axis at the layer above).

Layout: a, b are [B, S, W] with W padded to the 128-lane register width;
chunks of T_CHUNK=256 keep the VMEM working set (2 x BW x T_CHUNK x 4B)
well under budget while amortizing grid overhead.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

T_CHUNK = 256
W_BLOCK = 256


def _rglru_kernel(a_ref, b_ref, h0_ref, y_ref, h_scr, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)       # [chunk, BW]
    b = b_ref[0].astype(jnp.float32)
    h = h_scr[...]                          # [1, BW]

    def body(t, carry):
        h, = carry
        h = a[t][None, :] * h + b[t][None, :]
        y_ref[0, t, :] = h[0].astype(y_ref.dtype)
        return (h,)

    (h,) = jax.lax.fori_loop(0, chunk, body, (h,))
    h_scr[...] = h


def rglru_scan_blocked(a: jnp.ndarray, b: jnp.ndarray,
                       h0: jnp.ndarray = None, *, chunk: int = T_CHUNK,
                       w_block: int = W_BLOCK,
                       interpret: bool = True) -> jnp.ndarray:
    """h_t = a_t * h_{t-1} + b_t  (elementwise over W).

    a, b: [B, S, W]; h0: [B, W] or None.  Returns h: [B, S, W].
    """
    B, S, W = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)
    bw = min(w_block, W)
    tc = min(chunk, S)
    pad_w = (-W) % bw
    pad_s = (-S) % tc
    if pad_w:
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad_w)))
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pad_w)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_w)))
    if pad_s:
        # pad with a=1, b=0 (identity steps) at the END
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, 0)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, 0)))
    Wp, Sp = W + pad_w, S + pad_s
    h0 = h0[:, None, :]                       # [B, 1, Wp]

    kernel = functools.partial(_rglru_kernel, chunk=tc)
    grid = (B, Wp // bw, Sp // tc)            # seq chunks sequential (last)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tc, bw), lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((1, tc, bw), lambda bi, wi, ci: (bi, ci, wi)),
            pl.BlockSpec((1, 1, bw), lambda bi, wi, ci: (bi, 0, wi)),
        ],
        out_specs=pl.BlockSpec((1, tc, bw), lambda bi, wi, ci: (bi, ci, wi)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, Wp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return out[:, :S, :W]
