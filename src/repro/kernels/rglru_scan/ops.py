"""jit'd wrapper used by models.rglru (rglru_impl='pallas')."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .rglru_scan import rglru_scan_blocked


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@jax.jit
def rglru_scan_fused(a: jnp.ndarray, gated: jnp.ndarray,
                     h0: jnp.ndarray = None) -> jnp.ndarray:
    """a: per-step decay exp(log_a); gated: input term.  [B,S,W] -> [B,S,W]."""
    return rglru_scan_blocked(a, gated, h0, interpret=not _on_tpu())
