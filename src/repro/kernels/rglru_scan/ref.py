"""Pure-jnp oracle: first-order linear recurrence via associative scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a: jnp.ndarray, b: jnp.ndarray,
                   h0: jnp.ndarray = None) -> jnp.ndarray:
    """h_t = a_t h_{t-1} + b_t.  a, b: [B, S, W] -> h: [B, S, W] (f32)."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h
