"""VLM backbone (phi-3-vision) — the language decoder that consumes stubbed
vision embeddings.

Per the assignment carve-out, the CLIP/SigLIP vision tower + projector are a
STUB: ``input_specs`` provides precomputed patch embeddings [B, T_img, D].
This module fuses them with text-token embeddings (image prefix + text, the
phi-3-vision interleave simplified to a single leading image) and defers to
the dense transformer backbone for everything else — including the KV cache,
whose image-prefix pages are exactly the session state NALAR's K,V registry
manages (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from . import transformer as T

init_params = T.init_params
init_cache = T.init_cache


def fuse(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
         image_embeds: Optional[jnp.ndarray]) -> jnp.ndarray:
    """[B,S_txt] tokens + [B,T_img,D] patch embeddings -> [B,T_img+S_txt,D]."""
    tok = L.embed(tokens, params["embed"]).astype(cfg.jnp_dtype)
    if image_embeds is None:
        return tok
    return jnp.concatenate([image_embeds.astype(cfg.jnp_dtype), tok], axis=1)


def forward(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
            image_embeds: Optional[jnp.ndarray] = None,
            attention_impl: str = "xla", return_aux: bool = False,
            remat: bool = False, unembed: bool = True):
    x = fuse(params, cfg, tokens, image_embeds)
    return T.forward(params, cfg, None, inputs_embeds=x,
                     attention_impl=attention_impl, return_aux=return_aux,
                     remat=remat, unembed=unembed)


def prefill(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
            image_embeds: Optional[jnp.ndarray] = None,
            attention_impl: str = "xla", **kw) -> Tuple[jnp.ndarray, dict]:
    x = fuse(params, cfg, tokens, image_embeds)
    return T.prefill(params, cfg, None, inputs_embeds=x,
                     attention_impl=attention_impl, **kw)


decode_step = T.decode_step   # decode is text-only once the prefix is cached
# ... and so are the fused chunk steps: the image prefix enters the cache
# (or the KV pool's pages, for the paged-native engine) at prefill, after
# which chunked/paged decode is indistinguishable from the dense backbone
decode_chunk = T.decode_chunk
decode_chunk_paged = T.decode_chunk_paged


def text_loss_mask(cfg: ModelConfig, batch: int, text_len: int) -> jnp.ndarray:
    """Loss positions: only text tokens train (image prefix is masked)."""
    img = jnp.zeros((batch, cfg.n_image_tokens), bool)
    txt = jnp.ones((batch, text_len), bool)
    return jnp.concatenate([img, txt], axis=1)
