"""RecurrentGemma / Griffin hybrid — arXiv:2402.19427.

Layer pattern (period 3): two *recurrent blocks* then one *local sliding-
window attention* block.  A recurrent block is:

    norm -> [branch A: linear -> causal conv(4) -> RG-LRU]
            [branch B: linear -> GeLU]
            A * B -> linear out

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)          recurrence gate
    i_t = sigmoid(W_x x_t + b_x)          input gate
    a_t = exp(c * r_t * log sigmoid(Lam)) = sigmoid(Lam)^(c*r_t),  c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill evaluates the recurrence with ``jax.lax.associative_scan``
(first-order linear recurrences compose associatively), which is the
TPU-native adaptation of the paper's custom GPU scan kernel; decode is a
single fused update with O(1) state.  The sequence dim stays unsharded for
the scan — state/width shards over `model` (see distributed/sharding.py).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L

_C_RGLRU = 8.0


# ----------------------------------------------------------------- RG-LRU
def init_rglru(rng, cfg: ModelConfig) -> dict:
    W = cfg.rglru_width or cfg.d_model
    k = jax.random.split(rng, 2)
    s = (1.0 / W) ** 0.5
    # Lambda init so that a = sigmoid(Lam) in (0.9, 0.999) (paper init)
    u = jax.random.uniform(k[0], (W,), minval=0.9, maxval=0.999)
    lam = jnp.log(u / (1 - u))
    return {
        "w_a": (jax.random.normal(k[0], (W, W)) * s).astype(cfg.jnp_dtype),
        "b_a": jnp.zeros((W,), cfg.jnp_dtype),
        "w_x": (jax.random.normal(k[1], (W, W)) * s).astype(cfg.jnp_dtype),
        "b_x": jnp.zeros((W,), cfg.jnp_dtype),
        "lam": lam.astype(jnp.float32),
    }


def _rglru_gates(x: jnp.ndarray, p: dict) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (log_a [B,S,W] <=0, gated input [B,S,W]) in f32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32) + p["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ p["w_x"].astype(jnp.float32) + p["b_x"].astype(jnp.float32))
    log_a = _C_RGLRU * r * jax.nn.log_sigmoid(p["lam"])[None, None, :]
    a2 = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * (i * xf)
    return log_a, gated


def rglru_scan(x: jnp.ndarray, p: dict, h0: Optional[jnp.ndarray] = None,
               impl: str = "associative") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence RG-LRU.  x: [B,S,W].  Returns (y [B,S,W], h_S [B,W])."""
    log_a, gated = _rglru_gates(x, p)
    if impl == "pallas":
        from ..kernels.rglru_scan.ops import rglru_scan_fused
        y = rglru_scan_fused(jnp.exp(log_a), gated, h0)
        return y.astype(x.dtype), y[:, -1].astype(jnp.float32)
    a = jnp.exp(log_a)
    if h0 is not None:
        # fold the carried state into the first step's input
        gated = gated.at[:, 0].add(a[:, 0] * h0)
    # h_t = a_t h_{t-1} + b_t  ==  associative combine (a, b)
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_step(x: jnp.ndarray, p: dict, h: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token update.  x: [B,1,W], h: [B,W] f32."""
    log_a, gated = _rglru_gates(x, p)
    a = jnp.exp(log_a[:, 0])
    h_new = a * h + gated[:, 0]
    return h_new.astype(x.dtype)[:, None, :], h_new


# -------------------------------------------------------- recurrent block
def init_recurrent_block(rng, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    W = cfg.rglru_width or D
    K = cfg.ssm_conv
    k = jax.random.split(rng, 4)
    s = lambda i, o: (2.0 / (i + o)) ** 0.5
    return {
        "norm": L.init_norm(cfg),
        "w_rnn_in": (jax.random.normal(k[0], (D, W)) * s(D, W)).astype(cfg.jnp_dtype),
        "w_gate_in": (jax.random.normal(k[1], (D, W)) * s(D, W)).astype(cfg.jnp_dtype),
        "conv_w": (jax.random.normal(k[2], (K, W)) * 0.2).astype(cfg.jnp_dtype),
        "conv_b": jnp.zeros((W,), cfg.jnp_dtype),
        "rglru": init_rglru(k[3], cfg),
        "w_out": (jax.random.normal(k[3], (W, D)) * s(W, D)).astype(cfg.jnp_dtype),
        "mlp_norm": L.init_norm(cfg),
        "mlp": L.init_mlp(jax.random.fold_in(rng, 7), cfg),
    }


def _conv_step(x: jnp.ndarray, conv_state: jnp.ndarray, w: jnp.ndarray,
               b: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token causal conv via ring state [B, K-1, W]."""
    window = jnp.concatenate([conv_state, x], axis=1)
    out = jnp.einsum("bkc,kc->bc", window, w) + b
    return out[:, None, :], window[:, 1:]


def recurrent_block(x: jnp.ndarray, p: dict, cfg: ModelConfig,
                    conv_state=None, h_state=None, single_step: bool = False,
                    rglru_impl: str = "associative"):
    """Returns (x_out, new_conv_state, new_h_state)."""
    h = L.apply_norm(x, p["norm"], cfg)
    rnn_in = h @ p["w_rnn_in"]
    gate = jax.nn.gelu(h @ p["w_gate_in"])
    if single_step:
        conv_out, new_conv = _conv_step(rnn_in, conv_state, p["conv_w"], p["conv_b"])
        y, new_h = rglru_step(conv_out, p["rglru"], h_state)
    else:
        K = p["conv_w"].shape[0]
        S = rnn_in.shape[1]
        conv_out = sum(jnp.pad(rnn_in, ((0, 0), (K - 1, 0), (0, 0)))
                       [:, i:i + S, :] * p["conv_w"][i] for i in range(K))
        conv_out = conv_out + p["conv_b"]
        y, new_h = rglru_scan(conv_out, p["rglru"], h0=h_state, impl=rglru_impl)
        new_conv = jnp.pad(rnn_in, ((0, 0), (max(0, K - 1 - S), 0), (0, 0)))[:, -(K - 1):]
    out = (y * gate) @ p["w_out"]
    x = x + out
    h2 = L.apply_norm(x, p["mlp_norm"], cfg)
    x = x + L.mlp_block(h2, p["mlp"], cfg)
    return x, new_conv, new_h


# ------------------------------------------------------------ full model
def _layer_kinds(cfg: ModelConfig) -> list:
    """'r' or 'a' per layer: every `period`-th layer (1-based) is attention."""
    period = cfg.hybrid_period
    return ["a" if (i + 1) % period == 0 else "r" for i in range(cfg.n_layers)]


def init_params(rng, cfg: ModelConfig) -> dict:
    kinds = _layer_kinds(cfg)
    ke, kr, ka = jax.random.split(rng, 3)
    rec_idx = [i for i, k in enumerate(kinds) if k == "r"]
    att_idx = [i for i, k in enumerate(kinds) if k == "a"]
    rec_rngs = jax.random.split(kr, max(len(rec_idx), 1))
    att_rngs = jax.random.split(ka, max(len(att_idx), 1))

    def init_attn_layer(r):
        k1, k2 = jax.random.split(r)
        return {
            "attn_norm": L.init_norm(cfg),
            "attn": L.init_attention(k1, cfg),
            "mlp_norm": L.init_norm(cfg),
            "mlp": L.init_mlp(k2, cfg),
        }

    return {
        "embed": L.init_embedding(ke, cfg),
        "rec_layers": jax.vmap(lambda r: init_recurrent_block(r, cfg))(rec_rngs),
        "att_layers": jax.vmap(init_attn_layer)(att_rngs),
        "final_norm": L.init_norm(cfg),
    }


def _take(tree, i):
    return jax.tree_util.tree_map(lambda a: a[i], tree)


def _group_layout(cfg: ModelConfig):
    """(rec_per_group, n_groups, n_tail): layers = n_groups x ((p-1) rec +
    1 attn) followed by n_tail trailing rec layers."""
    p = cfg.hybrid_period
    rpg = p - 1
    ng = cfg.n_layers // p
    n_tail = cfg.n_layers - ng * p
    return rpg, ng, n_tail


def _group_params(params: dict, cfg: ModelConfig):
    """Slice the stacked per-kind params into scan-able group stacks.

    rec slot j of group g is rec_layers[g*rpg + j]; scanning over groups
    (instead of Python-unrolling 38 layers) keeps the HLO depth-independent
    — recurrentgemma-9b train compile drops ~4x (EXPERIMENTS.md §Scale).
    """
    rpg, ng, n_tail = _group_layout(cfg)
    recs = tuple(jax.tree_util.tree_map(lambda a: a[j:ng * rpg:rpg],
                                        params["rec_layers"])
                 for j in range(rpg))
    attn = jax.tree_util.tree_map(lambda a: a[:ng], params["att_layers"])
    tail = jax.tree_util.tree_map(lambda a: a[ng * rpg:],
                                  params["rec_layers"])
    return recs, attn, tail


def forward(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
            attention_impl: str = "xla", remat: bool = False,
            unembed: bool = True) -> jnp.ndarray:
    x = L.embed(tokens, params["embed"]).astype(cfg.jnp_dtype)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    rpg, ng, n_tail = _group_layout(cfg)
    recs, attn, tail = _group_params(params, cfg)

    def attn_sub(x, p):
        h = L.apply_norm(x, p["attn_norm"], cfg)
        x = x + L.attention_block(h, p["attn"], cfg, positions,
                                  window=cfg.sliding_window,
                                  attention_impl=attention_impl)
        h = L.apply_norm(x, p["mlp_norm"], cfg)
        return x + L.mlp_block(h, p["mlp"], cfg)

    def group_body(x, xs):
        rec_ps, attn_p = xs[:-1], xs[-1]
        for rp in rec_ps:
            x, _, _ = recurrent_block(x, rp, cfg)
        return attn_sub(x, attn_p)

    if remat:
        group_body = jax.checkpoint(group_body)

    def group(x, xs):
        return group_body(x, xs), None

    if ng:
        x, _ = jax.lax.scan(group, x, (*recs, attn))
    if n_tail:
        x, _ = jax.lax.scan(lambda x, rp: (recurrent_block(x, rp, cfg)[0],
                                           None), x, tail)
    x = L.apply_norm(x, params["final_norm"], cfg)
    return L.unembed(x, params["embed"], cfg) if unembed else x


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    kinds = _layer_kinds(cfg)
    n_rec = kinds.count("r")
    n_att = kinds.count("a")
    W = cfg.rglru_width or cfg.d_model
    K = cfg.ssm_conv
    window = min(cfg.sliding_window or max_seq, max_seq)
    return {
        "conv": jnp.zeros((n_rec, batch, K - 1, W), cfg.jnp_dtype),
        "h": jnp.zeros((n_rec, batch, W), jnp.float32),
        "k": jnp.zeros((n_att, batch, window, cfg.n_kv_heads, cfg.head_dim_),
                       cfg.jnp_dtype),
        "v": jnp.zeros((n_att, batch, window, cfg.n_kv_heads, cfg.head_dim_),
                       cfg.jnp_dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def prefill(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
            attention_impl: str = "xla",
            pad_cache_to: Optional[int] = None) -> Tuple[jnp.ndarray, dict]:
    x = L.embed(tokens, params["embed"]).astype(cfg.jnp_dtype)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    Wn = cfg.sliding_window
    C = min(S, Wn) if Wn else S
    rpg, ng, n_tail = _group_layout(cfg)
    recs, attn, tail = _group_params(params, cfg)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    mask = L.causal_mask(S, S, 0, Wn)

    def attn_sub(x, p):
        h = L.apply_norm(x, p["attn_norm"], cfg)
        q, k, v = L.attention_qkv(h, p["attn"], cfg, positions)
        o = L.full_attention(q, L.repeat_kv(k, n_rep), L.repeat_kv(v, n_rep),
                             causal=True, window=Wn,
                             scale=cfg.head_dim_ ** -0.5,
                             impl=attention_impl)
        x = x + jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
        h = L.apply_norm(x, p["mlp_norm"], cfg)
        x = x + L.mlp_block(h, p["mlp"], cfg)
        kc, vc = k[:, -C:], v[:, -C:]
        if Wn:
            shift = S % C
            kc = jnp.roll(kc, shift, axis=1)
            vc = jnp.roll(vc, shift, axis=1)
        return x, kc, vc

    def group(x, xs):
        rec_ps, attn_p = xs[:-1], xs[-1]
        convs, hs = [], []
        for rp in rec_ps:
            x, conv_st, h_st = recurrent_block(x, rp, cfg)
            convs.append(conv_st)
            hs.append(h_st)
        x, kc, vc = attn_sub(x, attn_p)
        return x, (jnp.stack(convs, 0), jnp.stack(hs, 0), kc, vc)

    if ng:
        x, (conv_g, h_g, ks, vs) = jax.lax.scan(group, x, (*recs, attn))
        # [ng, rpg, ...] -> layer order [ng*rpg, ...]
        conv_flat = conv_g.reshape(-1, *conv_g.shape[2:])
        h_flat = h_g.reshape(-1, *h_g.shape[2:])
    else:
        B = x.shape[0]
        W = cfg.rglru_width or cfg.d_model
        conv_flat = jnp.zeros((0, B, cfg.ssm_conv - 1, W), cfg.jnp_dtype)
        h_flat = jnp.zeros((0, B, W), jnp.float32)
        ks = vs = jnp.zeros((0, x.shape[0], C, cfg.n_kv_heads,
                             cfg.head_dim_), cfg.jnp_dtype)
    if n_tail:
        def tail_step(x, rp):
            x, conv_st, h_st = recurrent_block(x, rp, cfg)
            return x, (conv_st, h_st)

        x, (conv_t, h_t) = jax.lax.scan(tail_step, x, tail)
        conv_flat = jnp.concatenate([conv_flat, conv_t])
        h_flat = jnp.concatenate([h_flat, h_t])
    x = L.apply_norm(x[:, -1:], params["final_norm"], cfg)
    logits = L.unembed(x[:, 0], params["embed"], cfg)
    ks_s, vs_s = L.pad_cache_seq(ks, vs, C, Wn, pad_cache_to)
    cache = {
        "conv": conv_flat, "h": h_flat,
        "k": ks_s, "v": vs_s,
        "pos": jnp.full((tokens.shape[0],), S, jnp.int32),
    }
    return logits, cache


def decode_step(params: dict, cfg: ModelConfig, token: jnp.ndarray,
                cache: dict) -> Tuple[jnp.ndarray, dict]:
    B = token.shape[0]
    pos = jnp.broadcast_to(cache["pos"], (B,))
    x = L.embed(token[:, None], params["embed"]).astype(cfg.jnp_dtype)
    positions = pos[:, None]
    Wn = cfg.sliding_window
    rpg, ng, n_tail = _group_layout(cfg)
    recs, attn, tail = _group_params(params, cfg)
    # cache layout: conv/h rows [g*rpg + j] for group g slot j, tail at end
    conv_g = cache["conv"][:ng * rpg].reshape(ng, rpg, *cache["conv"].shape[1:])
    h_g = cache["h"][:ng * rpg].reshape(ng, rpg, *cache["h"].shape[1:])
    conv_tail = cache["conv"][ng * rpg:]
    h_tail = cache["h"][ng * rpg:]

    def group(x, xs):
        rec_ps = xs[:rpg]
        attn_p, conv_in, h_in, ck, cv = xs[rpg:]
        convs, hs = [], []
        for j, rp in enumerate(rec_ps):
            x, conv_st, h_st = recurrent_block(
                x, rp, cfg, conv_state=conv_in[j], h_state=h_in[j],
                single_step=True)
            convs.append(conv_st)
            hs.append(h_st)
        h = L.apply_norm(x, attn_p["attn_norm"], cfg)
        q, k, v = L.attention_qkv(h, attn_p["attn"], cfg, positions)
        ck, cv = L.kv_cache_update(ck, cv, k, v, pos, Wn)
        o = L.decode_attention(q, ck, cv, pos, cfg, window=Wn)
        x = x + jnp.einsum("bshk,hkd->bsd", o, attn_p["attn"]["wo"])
        h = L.apply_norm(x, attn_p["mlp_norm"], cfg)
        x = x + L.mlp_block(h, attn_p["mlp"], cfg)
        return x, (jnp.stack(convs, 0), jnp.stack(hs, 0), ck, cv)

    if ng:
        x, (conv_og, h_og, ks, vs) = jax.lax.scan(
            group, x, (*recs, attn, conv_g, h_g, cache["k"], cache["v"]))
        conv_flat = conv_og.reshape(-1, *conv_og.shape[2:])
        h_flat = h_og.reshape(-1, *h_og.shape[2:])
    else:
        conv_flat = cache["conv"][:0]
        h_flat = cache["h"][:0]
        ks, vs = cache["k"], cache["v"]
    if n_tail:
        def tail_step(x, xs):
            rp, conv_in, h_in = xs
            x, conv_st, h_st = recurrent_block(
                x, rp, cfg, conv_state=conv_in, h_state=h_in,
                single_step=True)
            return x, (conv_st, h_st)

        x, (conv_t, h_t) = jax.lax.scan(tail_step, x,
                                        (tail, conv_tail, h_tail))
        conv_flat = jnp.concatenate([conv_flat, conv_t])
        h_flat = jnp.concatenate([h_flat, h_t])
    x = L.apply_norm(x, params["final_norm"], cfg)
    logits = L.unembed(x[:, 0], params["embed"], cfg)
    return logits, {
        "conv": conv_flat, "h": h_flat,
        "k": ks, "v": vs, "pos": pos + 1,
    }


def decode_chunk(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
                 valid_len: jnp.ndarray, cache: dict):
    """T tokens ([B,T]) in one compiled forward: an in-jit scan of masked
    single steps (see ``ssm.decode_chunk`` — same rationale: the RG-LRU
    recurrence is sequential, the win is one dispatch per engine step).
    Token ``t`` advances sequence ``b`` iff ``t < valid_len[b]``; masked-out
    rows keep their conv/h/KV state and position untouched.  Returns
    (logits [B,T,V], cache)."""
    T = tokens.shape[1]

    def outer(cache, xs):
        tok, t = xs
        logits, new = decode_step(params, cfg, tok, cache)
        mask = t < valid_len                                   # [B]
        out = {}
        for key in new:
            ax = 0 if key == "pos" else 1       # batch axis per leaf
            shp = [1] * new[key].ndim
            shp[ax] = new[key].shape[ax]
            out[key] = jnp.where(mask.reshape(shp), new[key], cache[key])
        return out, logits

    cache, logits = jax.lax.scan(
        outer, cache, (jnp.moveaxis(tokens, 0, 1), jnp.arange(T)))
    return jnp.moveaxis(logits, 0, 1), cache
