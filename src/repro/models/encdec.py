"""Whisper-style encoder-decoder backbone — arXiv:2212.04356.

Per the assignment carve-out, the modality frontend (mel-spectrogram +
2-conv feature extractor) is a STUB: ``input_specs`` supplies precomputed
frame embeddings [B, T_enc, D].  This module implements everything after it:
bidirectional encoder, causal decoder with cross-attention, learned absolute
positions (Whisper uses sinusoidal enc / learned dec; both are stand-ins
here), LayerNorm + GELU.

Decode caches: decoder self-attention KV (grows with generated length) plus
the cross-attention K/V computed once from the encoder output.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L


def _init_xattn(rng, cfg: ModelConfig) -> dict:
    # cross-attention has full heads on both sides (Whisper is MHA)
    return L.init_attention(rng, cfg)


def _init_enc_layer(rng, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(rng)
    return {
        "attn_norm": L.init_norm(cfg),
        "attn": L.init_attention(k1, cfg),
        "mlp_norm": L.init_norm(cfg),
        "mlp": L.init_mlp(k2, cfg),
    }


def _init_dec_layer(rng, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "attn_norm": L.init_norm(cfg),
        "attn": L.init_attention(k1, cfg),
        "xattn_norm": L.init_norm(cfg),
        "xattn": _init_xattn(k2, cfg),
        "mlp_norm": L.init_norm(cfg),
        "mlp": L.init_mlp(k3, cfg),
    }


def init_params(rng, cfg: ModelConfig) -> dict:
    ke, kenc, kdec, kp = jax.random.split(rng, 4)
    enc_rngs = jax.random.split(kenc, cfg.n_encoder_layers)
    dec_rngs = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": L.init_embedding(ke, cfg),
        "enc_pos": (jax.random.normal(kp, (cfg.encoder_seq, cfg.d_model))
                    * 0.02).astype(cfg.jnp_dtype),
        "enc_layers": jax.vmap(lambda r: _init_enc_layer(r, cfg))(enc_rngs),
        "enc_norm": L.init_norm(cfg),
        "dec_layers": jax.vmap(lambda r: _init_dec_layer(r, cfg))(dec_rngs),
        "final_norm": L.init_norm(cfg),
    }


def encode(params: dict, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: precomputed conv-frontend embeddings [B, T_enc, D]."""
    x = frames.astype(cfg.jnp_dtype) + params["enc_pos"][None, :frames.shape[1]]
    T = x.shape[1]
    positions = jnp.arange(T)[None, :]

    def step(carry, p):
        h = L.apply_norm(carry, p["attn_norm"], cfg)
        q, k, v = L.attention_qkv(h, p["attn"], cfg, positions)
        n_rep = cfg.n_heads // cfg.n_kv_heads
        o = L.sdpa(q, L.repeat_kv(k, n_rep), L.repeat_kv(v, n_rep), None,
                   cfg.head_dim_ ** -0.5)   # bidirectional: no mask
        x = carry + jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
        h = L.apply_norm(x, p["mlp_norm"], cfg)
        return x + L.mlp_block(h, p["mlp"], cfg), None

    x, _ = jax.lax.scan(step, x, params["enc_layers"])
    return L.apply_norm(x, params["enc_norm"], cfg)


def _cross_kv(memory: jnp.ndarray, p: dict, cfg: ModelConfig):
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"])
    return k, v


def _cross_attend(x: jnp.ndarray, xk: jnp.ndarray, xv: jnp.ndarray,
                  p: dict, cfg: ModelConfig) -> jnp.ndarray:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    n_rep = cfg.n_heads // cfg.n_kv_heads
    o = L.sdpa(q, L.repeat_kv(xk, n_rep), L.repeat_kv(xv, n_rep), None,
               cfg.head_dim_ ** -0.5)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def _dec_block(x, p, cfg, positions, memory_kv, self_mask, impl="xla"):
    xk, xv = memory_kv
    h = L.apply_norm(x, p["attn_norm"], cfg)
    q, k, v = L.attention_qkv(h, p["attn"], cfg, positions)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    o = L.full_attention(q, L.repeat_kv(k, n_rep), L.repeat_kv(v, n_rep),
                         causal=True, window=None,
                         scale=cfg.head_dim_ ** -0.5, impl=impl)
    x = x + jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
    h = L.apply_norm(x, p["xattn_norm"], cfg)
    x = x + _cross_attend(h, xk, xv, p["xattn"], cfg)
    h = L.apply_norm(x, p["mlp_norm"], cfg)
    return x + L.mlp_block(h, p["mlp"], cfg), (k, v)


def forward(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
            frames: jnp.ndarray, attention_impl: str = "xla",
            remat: bool = False, unembed: bool = True) -> jnp.ndarray:
    """Teacher-forced training forward.  Returns decoder logits [B,S,V]."""
    memory = encode(params, cfg, frames)
    x = L.embed(tokens, params["embed"]).astype(cfg.jnp_dtype)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    mask = L.causal_mask(S, S, 0)

    def blk(carry, p):
        kv = _cross_kv(memory, p["xattn"], cfg)
        out, _ = _dec_block(carry, p, cfg, positions, kv, mask,
                            impl=attention_impl)
        return out

    if remat:
        blk = jax.checkpoint(blk)

    def step(carry, p):
        return blk(carry, p), None

    x, _ = jax.lax.scan(step, x, params["dec_layers"])
    x = L.apply_norm(x, params["final_norm"], cfg)
    return L.unembed(x, params["embed"], cfg) if unembed else x


# ------------------------------------------------------------------ serving
def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim_
    H = cfg.n_heads
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_seq, Hkv, Dh), cfg.jnp_dtype),
        "v": jnp.zeros((cfg.n_layers, batch, max_seq, Hkv, Dh), cfg.jnp_dtype),
        # cross-attn memory K/V, computed at prefill
        "xk": jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq, Hkv, Dh),
                        cfg.jnp_dtype),
        "xv": jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq, Hkv, Dh),
                        cfg.jnp_dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def prefill(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
            frames: jnp.ndarray, attention_impl: str = "xla",
            pad_cache_to=None) -> Tuple[jnp.ndarray, dict]:
    memory = encode(params, cfg, frames)
    x = L.embed(tokens, params["embed"]).astype(cfg.jnp_dtype)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S)[None, :]
    mask = L.causal_mask(S, S, 0)

    def step(carry, p):
        kv = _cross_kv(memory, p["xattn"], cfg)
        out, (k, v) = _dec_block(carry, p, cfg, positions, kv, mask,
                                 impl=attention_impl)
        return out, (k, v, kv[0], kv[1])

    x, (ks, vs, xks, xvs) = jax.lax.scan(step, x, params["dec_layers"])
    x = L.apply_norm(x[:, -1:], params["final_norm"], cfg)
    logits = L.unembed(x[:, 0], params["embed"], cfg)
    ks, vs = L.pad_cache_seq(ks, vs, S, None, pad_cache_to)
    return logits, {"k": ks, "v": vs, "xk": xks, "xv": xvs,
                    "pos": jnp.full((B,), S, jnp.int32)}


def decode_step(params: dict, cfg: ModelConfig, token: jnp.ndarray,
                cache: dict) -> Tuple[jnp.ndarray, dict]:
    B = token.shape[0]
    pos = jnp.broadcast_to(cache["pos"], (B,))
    x = L.embed(token[:, None], params["embed"]).astype(cfg.jnp_dtype)
    positions = pos[:, None]

    def step(carry, xs):
        p, ck, cv, xk, xv = xs
        x = carry
        h = L.apply_norm(x, p["attn_norm"], cfg)
        q, k, v = L.attention_qkv(h, p["attn"], cfg, positions)
        ck, cv = L.kv_cache_update(ck, cv, k, v, pos, None)
        o = L.decode_attention(q, ck, cv, pos, cfg)
        x = x + jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
        h = L.apply_norm(x, p["xattn_norm"], cfg)
        x = x + _cross_attend(h, xk, xv, p["xattn"], cfg)
        h = L.apply_norm(x, p["mlp_norm"], cfg)
        x = x + L.mlp_block(h, p["mlp"], cfg)
        return x, (ck, cv)

    x, (ks, vs) = jax.lax.scan(
        step, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = L.apply_norm(x, params["final_norm"], cfg)
    logits = L.unembed(x[:, 0], params["embed"], cfg)
    return logits, {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"],
                    "pos": pos + 1}


def encode_cross(params: dict, cfg: ModelConfig,
                 frames: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Run the encoder and project per-decoder-layer cross K/V.

    Returns (xk, xv) [L, B, T_enc, Hkv, Dh] — bitwise identical to the
    ``xk``/``xv`` leaves :func:`prefill` produces (same ``encode`` + same
    per-layer ``_cross_kv`` einsums), so the chunked admission path can
    populate the slim cache without running a monolithic prefill."""
    memory = encode(params, cfg, frames)

    def step(carry, p):
        k, v = _cross_kv(memory, p["xattn"], cfg)
        return carry, (k, v)

    _, (xks, xvs) = jax.lax.scan(step, 0, params["dec_layers"])
    return xks, xvs


def decode_chunk(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
                 valid_len: jnp.ndarray, cache: dict) -> Tuple[jnp.ndarray, dict]:
    """T decoder tokens ([B,T]) against the KV cache in one forward.

    Chunked-prefill for the encoder-decoder: causal self-attention within
    the chunk + full attention over the cached prefix, cross-attending the
    precomputed ``xk``/``xv`` memory every layer.  Mirrors
    ``transformer.decode_chunk`` (non-windowed branch)."""
    B, T = tokens.shape
    pos = jnp.broadcast_to(cache["pos"], (B,))
    x = L.embed(tokens, params["embed"]).astype(cfg.jnp_dtype)
    positions = pos[:, None] + jnp.arange(T)[None, :]          # [B,T]
    valid = jnp.arange(T)[None, :] < valid_len[:, None]        # [B,T]

    def step(carry, xs):
        p, ck, cv, xk, xv = xs
        x = carry
        h = L.apply_norm(x, p["attn_norm"], cfg)
        q, k, v = L.attention_qkv(h, p["attn"], cfg, positions)
        ck, cv = L.kv_cache_update_chunk(ck, cv, k, v, pos, valid, None)
        o = L.chunk_decode_attention(q, ck, cv, positions, cfg)
        x = x + jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
        h = L.apply_norm(x, p["xattn_norm"], cfg)
        x = x + _cross_attend(h, xk, xv, p["xattn"], cfg)
        h = L.apply_norm(x, p["mlp_norm"], cfg)
        x = x + L.mlp_block(h, p["mlp"], cfg)
        return x, (ck, cv)

    x, (ks, vs) = jax.lax.scan(
        step, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = L.apply_norm(x, params["final_norm"], cfg)
    logits = L.unembed(x, params["embed"], cfg)                # [B,T,V]
    return logits, {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"],
                    "pos": pos + valid_len}


def decode_chunk_paged(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
                       valid_len: jnp.ndarray, cache: dict,
                       k_pages: jnp.ndarray, v_pages: jnp.ndarray,
                       page_table: jnp.ndarray, *, max_seq: int,
                       kernel: bool = False):
    """Paged-native :func:`decode_chunk`: decoder self-attention KV lives in
    the pool pages; the slim cache carries only {"xk", "xv", "pos"}.  Same
    scatter-routing and bitwise-parity strategy as
    ``transformer.decode_chunk_paged`` (non-windowed).  Returns
    (logits [B,T,V], slim cache, k_pages, v_pages)."""
    B, T = tokens.shape
    pos = jnp.broadcast_to(cache["pos"], (B,))
    x = L.embed(tokens, params["embed"]).astype(cfg.jnp_dtype)
    positions = pos[:, None] + jnp.arange(T)[None, :]          # [B,T]
    valid = jnp.arange(T)[None, :] < valid_len[:, None]        # [B,T]
    C = max_seq

    _nl, n_pages, P, Hkv, Dh = k_pages.shape
    maxp = page_table.shape[1]
    pslot = jnp.minimum(positions // P, maxp - 1)              # [B,T]
    page_of = jnp.take_along_axis(page_table, pslot, axis=1)   # [B,T]
    off = positions % P
    oob = (~valid) | (page_of < 0) | (positions >= C)
    widx = jnp.where(oob, n_pages, page_of)                    # drop sentinel
    pt_c = jnp.maximum(page_table, 0)

    def gather(pages):
        return pages[pt_c].reshape(B, maxp * P, Hkv, Dh)[:, :C]

    def step(carry, xs):
        p, kp, vp, xk, xv = xs
        x = carry
        h = L.apply_norm(x, p["attn_norm"], cfg)
        q, k, v = L.attention_qkv(h, p["attn"], cfg, positions)
        kp = kp.at[widx, off].set(k.astype(kp.dtype), mode="drop")
        vp = vp.at[widx, off].set(v.astype(vp.dtype), mode="drop")
        if kernel:
            o = L.paged_chunk_attention(q, kp, vp, page_table, pos, cfg)
        else:
            o = L.chunk_decode_attention(q, gather(kp), gather(vp),
                                         positions, cfg)
        x = x + jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
        h = L.apply_norm(x, p["xattn_norm"], cfg)
        x = x + _cross_attend(h, xk, xv, p["xattn"], cfg)
        h = L.apply_norm(x, p["mlp_norm"], cfg)
        x = x + L.mlp_block(h, p["mlp"], cfg)
        return x, (kp, vp)

    x, (ks, vs) = jax.lax.scan(
        step, x, (params["dec_layers"], k_pages, v_pages,
                  cache["xk"], cache["xv"]))
    x = L.apply_norm(x, params["final_norm"], cfg)
    logits = L.unembed(x, params["embed"], cfg)                # [B,T,V]
    return (logits, {"xk": cache["xk"], "xv": cache["xv"],
                     "pos": pos + valid_len}, ks, vs)
