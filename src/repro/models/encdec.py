"""Whisper-style encoder-decoder backbone — arXiv:2212.04356.

Per the assignment carve-out, the modality frontend (mel-spectrogram +
2-conv feature extractor) is a STUB: ``input_specs`` supplies precomputed
frame embeddings [B, T_enc, D].  This module implements everything after it:
bidirectional encoder, causal decoder with cross-attention, learned absolute
positions (Whisper uses sinusoidal enc / learned dec; both are stand-ins
here), LayerNorm + GELU.

Decode caches: decoder self-attention KV (grows with generated length) plus
the cross-attention K/V computed once from the encoder output.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L


def _init_xattn(rng, cfg: ModelConfig) -> dict:
    # cross-attention has full heads on both sides (Whisper is MHA)
    return L.init_attention(rng, cfg)


def _init_enc_layer(rng, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(rng)
    return {
        "attn_norm": L.init_norm(cfg),
        "attn": L.init_attention(k1, cfg),
        "mlp_norm": L.init_norm(cfg),
        "mlp": L.init_mlp(k2, cfg),
    }


def _init_dec_layer(rng, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "attn_norm": L.init_norm(cfg),
        "attn": L.init_attention(k1, cfg),
        "xattn_norm": L.init_norm(cfg),
        "xattn": _init_xattn(k2, cfg),
        "mlp_norm": L.init_norm(cfg),
        "mlp": L.init_mlp(k3, cfg),
    }


def init_params(rng, cfg: ModelConfig) -> dict:
    ke, kenc, kdec, kp = jax.random.split(rng, 4)
    enc_rngs = jax.random.split(kenc, cfg.n_encoder_layers)
    dec_rngs = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": L.init_embedding(ke, cfg),
        "enc_pos": (jax.random.normal(kp, (cfg.encoder_seq, cfg.d_model))
                    * 0.02).astype(cfg.jnp_dtype),
        "enc_layers": jax.vmap(lambda r: _init_enc_layer(r, cfg))(enc_rngs),
        "enc_norm": L.init_norm(cfg),
        "dec_layers": jax.vmap(lambda r: _init_dec_layer(r, cfg))(dec_rngs),
        "final_norm": L.init_norm(cfg),
    }


def encode(params: dict, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: precomputed conv-frontend embeddings [B, T_enc, D]."""
    x = frames.astype(cfg.jnp_dtype) + params["enc_pos"][None, :frames.shape[1]]
    T = x.shape[1]
    positions = jnp.arange(T)[None, :]

    def step(carry, p):
        h = L.apply_norm(carry, p["attn_norm"], cfg)
        q, k, v = L.attention_qkv(h, p["attn"], cfg, positions)
        n_rep = cfg.n_heads // cfg.n_kv_heads
        o = L.sdpa(q, L.repeat_kv(k, n_rep), L.repeat_kv(v, n_rep), None,
                   cfg.head_dim_ ** -0.5)   # bidirectional: no mask
        x = carry + jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
        h = L.apply_norm(x, p["mlp_norm"], cfg)
        return x + L.mlp_block(h, p["mlp"], cfg), None

    x, _ = jax.lax.scan(step, x, params["enc_layers"])
    return L.apply_norm(x, params["enc_norm"], cfg)


def _cross_kv(memory: jnp.ndarray, p: dict, cfg: ModelConfig):
    k = jnp.einsum("bsd,dhk->bshk", memory, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, p["wv"])
    return k, v


def _cross_attend(x: jnp.ndarray, xk: jnp.ndarray, xv: jnp.ndarray,
                  p: dict, cfg: ModelConfig) -> jnp.ndarray:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    n_rep = cfg.n_heads // cfg.n_kv_heads
    o = L.sdpa(q, L.repeat_kv(xk, n_rep), L.repeat_kv(xv, n_rep), None,
               cfg.head_dim_ ** -0.5)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def _dec_block(x, p, cfg, positions, memory_kv, self_mask, impl="xla"):
    xk, xv = memory_kv
    h = L.apply_norm(x, p["attn_norm"], cfg)
    q, k, v = L.attention_qkv(h, p["attn"], cfg, positions)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    o = L.full_attention(q, L.repeat_kv(k, n_rep), L.repeat_kv(v, n_rep),
                         causal=True, window=None,
                         scale=cfg.head_dim_ ** -0.5, impl=impl)
    x = x + jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
    h = L.apply_norm(x, p["xattn_norm"], cfg)
    x = x + _cross_attend(h, xk, xv, p["xattn"], cfg)
    h = L.apply_norm(x, p["mlp_norm"], cfg)
    return x + L.mlp_block(h, p["mlp"], cfg), (k, v)


def forward(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
            frames: jnp.ndarray, attention_impl: str = "xla",
            remat: bool = False, unembed: bool = True) -> jnp.ndarray:
    """Teacher-forced training forward.  Returns decoder logits [B,S,V]."""
    memory = encode(params, cfg, frames)
    x = L.embed(tokens, params["embed"]).astype(cfg.jnp_dtype)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    mask = L.causal_mask(S, S, 0)

    def blk(carry, p):
        kv = _cross_kv(memory, p["xattn"], cfg)
        out, _ = _dec_block(carry, p, cfg, positions, kv, mask,
                            impl=attention_impl)
        return out

    if remat:
        blk = jax.checkpoint(blk)

    def step(carry, p):
        return blk(carry, p), None

    x, _ = jax.lax.scan(step, x, params["dec_layers"])
    x = L.apply_norm(x, params["final_norm"], cfg)
    return L.unembed(x, params["embed"], cfg) if unembed else x


# ------------------------------------------------------------------ serving
def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    Hkv, Dh = cfg.n_kv_heads, cfg.head_dim_
    H = cfg.n_heads
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_seq, Hkv, Dh), cfg.jnp_dtype),
        "v": jnp.zeros((cfg.n_layers, batch, max_seq, Hkv, Dh), cfg.jnp_dtype),
        # cross-attn memory K/V, computed at prefill
        "xk": jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq, Hkv, Dh),
                        cfg.jnp_dtype),
        "xv": jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq, Hkv, Dh),
                        cfg.jnp_dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def prefill(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
            frames: jnp.ndarray, attention_impl: str = "xla",
            pad_cache_to=None) -> Tuple[jnp.ndarray, dict]:
    memory = encode(params, cfg, frames)
    x = L.embed(tokens, params["embed"]).astype(cfg.jnp_dtype)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S)[None, :]
    mask = L.causal_mask(S, S, 0)

    def step(carry, p):
        kv = _cross_kv(memory, p["xattn"], cfg)
        out, (k, v) = _dec_block(carry, p, cfg, positions, kv, mask,
                                 impl=attention_impl)
        return out, (k, v, kv[0], kv[1])

    x, (ks, vs, xks, xvs) = jax.lax.scan(step, x, params["dec_layers"])
    x = L.apply_norm(x[:, -1:], params["final_norm"], cfg)
    logits = L.unembed(x[:, 0], params["embed"], cfg)
    ks, vs = L.pad_cache_seq(ks, vs, S, None, pad_cache_to)
    return logits, {"k": ks, "v": vs, "xk": xks, "xv": xvs,
                    "pos": jnp.full((B,), S, jnp.int32)}


def decode_step(params: dict, cfg: ModelConfig, token: jnp.ndarray,
                cache: dict) -> Tuple[jnp.ndarray, dict]:
    B = token.shape[0]
    pos = jnp.broadcast_to(cache["pos"], (B,))
    x = L.embed(token[:, None], params["embed"]).astype(cfg.jnp_dtype)
    positions = pos[:, None]

    def step(carry, xs):
        p, ck, cv, xk, xv = xs
        x = carry
        h = L.apply_norm(x, p["attn_norm"], cfg)
        q, k, v = L.attention_qkv(h, p["attn"], cfg, positions)
        ck, cv = L.kv_cache_update(ck, cv, k, v, pos, None)
        o = L.decode_attention(q, ck, cv, pos, cfg)
        x = x + jnp.einsum("bshk,hkd->bsd", o, p["attn"]["wo"])
        h = L.apply_norm(x, p["xattn_norm"], cfg)
        x = x + _cross_attend(h, xk, xv, p["xattn"], cfg)
        h = L.apply_norm(x, p["mlp_norm"], cfg)
        x = x + L.mlp_block(h, p["mlp"], cfg)
        return x, (ck, cv)

    x, (ks, vs) = jax.lax.scan(
        step, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = L.apply_norm(x, params["final_norm"], cfg)
    logits = L.unembed(x[:, 0], params["embed"], cfg)
    return logits, {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"],
                    "pos": pos + 1}
