"""JAX model zoo: the architectures NALAR serves.

Families: dense (GQA transformer), moe (expert-parallel FFN), ssm (Mamba2
SSD), hybrid (RG-LRU + local attention), vlm (stub vision frontend + dense
backbone), audio (Whisper-style enc-dec with stub conv frontend).
"""

from .model import Model, build_model, cross_entropy

__all__ = ["Model", "build_model", "cross_entropy"]
