"""Dense decoder-only transformer (qwen3 / stablelm-2 / starcoder2 families).

Layer-stacked parameters + ``jax.lax.scan`` keep the HLO size independent of
depth (94-layer configs compile in seconds).  Also the backbone for the VLM
config (phi-3-vision consumes precomputed patch embeddings).
"""

from __future__ import annotations

import functools

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from . import moe as M


def init_layer(rng, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(rng)
    return {
        "attn_norm": L.init_norm(cfg),
        "attn": L.init_attention(k1, cfg),
        "mlp_norm": L.init_norm(cfg),
        "mlp": (M.init_moe_layer(k2, cfg) if cfg.n_experts > 0
                else L.init_mlp(k2, cfg)),
    }


def _ffn(x: jnp.ndarray, layer_p: dict, cfg: ModelConfig,
         moe_impl: str = "einsum"):
    """FFN sub-block: dense MLP or MoE.  Returns (y, aux_loss)."""
    if cfg.n_experts > 0:
        y, aux, _counts = M.moe_block(x, layer_p["mlp"], cfg, impl=moe_impl)
        return y, aux
    return L.mlp_block(x, layer_p["mlp"], cfg), jnp.zeros((), jnp.float32)


def init_params(rng, cfg: ModelConfig) -> dict:
    ke, kl = jax.random.split(rng)
    layer_rngs = jax.random.split(kl, cfg.n_layers)
    return {
        "embed": L.init_embedding(ke, cfg),
        "layers": jax.vmap(lambda r: init_layer(r, cfg))(layer_rngs),
        "final_norm": L.init_norm(cfg),
    }


def _block(x: jnp.ndarray, p: dict, *, cfg: ModelConfig,
           positions: jnp.ndarray, attention_impl: str,
           moe_impl: str = "einsum"):
    h = L.apply_norm(x, p["attn_norm"], cfg)
    x = x + L.attention_block(h, p["attn"], cfg, positions,
                              window=cfg.sliding_window,
                              attention_impl=attention_impl)
    h = L.apply_norm(x, p["mlp_norm"], cfg)
    y, aux = _ffn(h, p, cfg, moe_impl)
    return x + y, aux


def forward(params: dict, cfg: ModelConfig, tokens: Optional[jnp.ndarray],
            inputs_embeds: Optional[jnp.ndarray] = None,
            attention_impl: str = "xla", moe_impl: str = "einsum",
            return_aux: bool = False, remat: bool = False,
            unembed: bool = True):
    """Training/prefill forward.  Returns logits [B, S, V] (+ MoE aux loss);
    ``unembed=False`` returns the final hidden states instead (the chunked
    cross-entropy path never materializes full logits).

    ``remat=True`` checkpoints each layer (recompute-in-backward): live
    activations drop from O(L x per-layer internals) to O(L x boundaries) +
    one layer's internals — required for the production train shapes to fit
    HBM (EXPERIMENTS.md §Perf iteration 5)."""
    x = inputs_embeds if inputs_embeds is not None else L.embed(tokens, params["embed"])
    x = x.astype(cfg.jnp_dtype)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]

    blk = functools.partial(_block, cfg=cfg, positions=positions,
                            attention_impl=attention_impl, moe_impl=moe_impl)
    if remat:
        blk = jax.checkpoint(blk)

    def step(carry, layer_p):
        x, aux = carry
        x, a = blk(x, layer_p)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    x = L.apply_norm(x, params["final_norm"], cfg)
    out = x if not unembed else L.unembed(x, params["embed"], cfg)
    if return_aux:
        return out, aux / max(cfg.n_layers, 1)
    return out


# --------------------------------------------------------------- serving
def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    C = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    shape = (cfg.n_layers, batch, C, cfg.n_kv_heads, cfg.head_dim_)
    return {
        "k": jnp.zeros(shape, cfg.jnp_dtype),
        "v": jnp.zeros(shape, cfg.jnp_dtype),
        "pos": jnp.zeros((batch,), jnp.int32),   # per-seq next position
    }


def prefill(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
            inputs_embeds: Optional[jnp.ndarray] = None,
            attention_impl: str = "xla", moe_impl: str = "einsum",
            pad_cache_to: Optional[int] = None) -> Tuple[jnp.ndarray, dict]:
    """Process the full prompt; returns (last-token logits [B,V], cache).

    ``pad_cache_to`` adds decode headroom to the returned KV cache (the
    prefill cache is otherwise exactly prompt-sized and the first decoded
    token would overwrite the last prompt slot)."""
    x = inputs_embeds if inputs_embeds is not None else L.embed(tokens, params["embed"])
    x = x.astype(cfg.jnp_dtype)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S)[None, :]
    W = cfg.sliding_window
    C = min(S, W) if W else S

    def step(carry, layer_p):
        x = carry
        h = L.apply_norm(x, layer_p["attn_norm"], cfg)
        q, k, v = L.attention_qkv(h, layer_p["attn"], cfg, positions)
        n_rep = cfg.n_heads // cfg.n_kv_heads
        scale = cfg.head_dim_ ** -0.5
        o = L.full_attention(q, L.repeat_kv(k, n_rep), L.repeat_kv(v, n_rep),
                             causal=True, window=W, scale=scale,
                             impl=attention_impl)
        x = x + jnp.einsum("bshk,hkd->bsd", o, layer_p["attn"]["wo"])
        h = L.apply_norm(x, layer_p["mlp_norm"], cfg)
        y, _aux = _ffn(h, layer_p, cfg, moe_impl)
        x = x + y
        # keep the last C positions, ring-aligned so that slot = pos % C
        kc, vc = k[:, -C:], v[:, -C:]
        if W:
            shift = S % C
            kc = jnp.roll(kc, shift, axis=1)
            vc = jnp.roll(vc, shift, axis=1)
        return x, (kc, vc)

    x, (ks, vs) = jax.lax.scan(step, x, params["layers"])
    x = L.apply_norm(x[:, -1:], params["final_norm"], cfg)
    logits = L.unembed(x[:, 0], params["embed"], cfg)
    ks, vs = L.pad_cache_seq(ks, vs, C, W, pad_cache_to)
    cache = {"k": ks, "v": vs, "pos": jnp.full((B,), S, jnp.int32)}
    return logits, cache


def decode_step(params: dict, cfg: ModelConfig, token: jnp.ndarray,
                cache: dict, attention_impl: str = "xla",
                moe_impl: str = "einsum") -> Tuple[jnp.ndarray, dict]:
    """One token ([B] int32) against the KV cache.  Returns (logits, cache)."""
    B = token.shape[0]
    pos = jnp.broadcast_to(cache["pos"], (B,))
    x = L.embed(token[:, None], params["embed"]).astype(cfg.jnp_dtype)
    positions = pos[:, None]
    W = cfg.sliding_window

    def step(carry, xs):
        x = carry
        layer_p, ck, cv = xs
        h = L.apply_norm(x, layer_p["attn_norm"], cfg)
        q, k, v = L.attention_qkv(h, layer_p["attn"], cfg, positions)
        ck, cv = L.kv_cache_update(ck, cv, k, v, pos, W)
        o = L.decode_attention(q, ck, cv, pos, cfg, window=W,
                               impl=attention_impl)
        x = x + jnp.einsum("bshk,hkd->bsd", o, layer_p["attn"]["wo"])
        h = L.apply_norm(x, layer_p["mlp_norm"], cfg)
        y, _aux = _ffn(h, layer_p, cfg, moe_impl)
        x = x + y
        return x, (ck, cv)

    x, (ks, vs) = jax.lax.scan(step, x, (params["layers"], cache["k"], cache["v"]))
    x = L.apply_norm(x, params["final_norm"], cfg)
    logits = L.unembed(x[:, 0], params["embed"], cfg)
    return logits, {"k": ks, "v": vs, "pos": pos + 1}


def decode_chunk(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
                 valid_len: jnp.ndarray, cache: dict,
                 attention_impl: str = "xla",
                 moe_impl: str = "einsum") -> Tuple[jnp.ndarray, dict]:
    """T tokens ([B,T] int32) against the KV cache in one forward.

    The chunked-prefill primitive: each sequence advances by
    ``valid_len[b] <= T`` positions — a prefilling slot consumes a prompt
    chunk while a decoding slot piggybacked in the same batch advances one
    token (valid_len 1) and an idle slot none (valid_len 0; its cache row
    and position are untouched).  Causal within the chunk, full attention
    over the cached prefix.  Returns (logits [B,T,V], cache); callers read
    row ``valid_len[b]-1`` for the next-token distribution.
    """
    B, T = tokens.shape
    pos = jnp.broadcast_to(cache["pos"], (B,))
    x = L.embed(tokens, params["embed"]).astype(cfg.jnp_dtype)
    positions = pos[:, None] + jnp.arange(T)[None, :]          # [B,T]
    valid = jnp.arange(T)[None, :] < valid_len[:, None]        # [B,T]
    W = cfg.sliding_window

    def step(carry, xs):
        x = carry
        layer_p, ck, cv = xs
        h = L.apply_norm(x, layer_p["attn_norm"], cfg)
        q, k, v = L.attention_qkv(h, layer_p["attn"], cfg, positions)
        if W:
            # ring caches: attend the pre-write cache + the chunk itself
            # (a chunk write can clobber ring slots earlier in-chunk
            # queries still need), then write
            o = L.chunk_decode_attention_windowed(
                q, ck, cv, k, v, pos, valid_len, positions, cfg, window=W)
            ck, cv = L.kv_cache_update_chunk(ck, cv, k, v, pos, valid, W)
        else:
            ck, cv = L.kv_cache_update_chunk(ck, cv, k, v, pos, valid, W)
            o = L.chunk_decode_attention(q, ck, cv, positions, cfg)
        x = x + jnp.einsum("bshk,hkd->bsd", o, layer_p["attn"]["wo"])
        h = L.apply_norm(x, layer_p["mlp_norm"], cfg)
        y, _aux = _ffn(h, layer_p, cfg, moe_impl)
        x = x + y
        return x, (ck, cv)

    x, (ks, vs) = jax.lax.scan(step, x, (params["layers"], cache["k"],
                                         cache["v"]))
    x = L.apply_norm(x, params["final_norm"], cfg)
    logits = L.unembed(x, params["embed"], cfg)                # [B,T,V]
    return logits, {"k": ks, "v": vs, "pos": pos + valid_len}


def decode_chunk_paged(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
                       valid_len: jnp.ndarray, cache: dict,
                       k_pages: jnp.ndarray, v_pages: jnp.ndarray,
                       page_table: jnp.ndarray, *, max_seq: int,
                       attention_impl: str = "xla", moe_impl: str = "einsum",
                       kernel: bool = False):
    """Paged-native :func:`decode_chunk`: the KV pool IS the decode cache.

    New K/V is scattered straight into the pool pages named by
    ``page_table`` [B, max_pages] (-1 padding) — no dense per-slot cache,
    no ``gather_contiguous`` on admission, no write-back on eviction.  The
    caller (``serving.engine``) must guarantee every page about to receive
    a write has refcount 1 (``PagedKVPool.begin_append`` privatizes shared
    pages first) and that distinct batch rows never map a written position
    to the same page, so the scatter is collision-free; rows with
    ``valid_len == 0`` and -1 table entries write nowhere (``mode='drop'``).

    Default path gathers the tables to a dense [B, C, Hkv, Dh] view (C =
    the dense slot-cache length) and reuses the exact
    ``chunk_decode_attention`` / ``_windowed`` math, so greedy outputs and
    cache bytes are bitwise identical to :func:`decode_chunk`.
    ``kernel=True`` instead runs the Pallas paged kernel over the tables
    (no dense materialization; near-identical, not bitwise).  Windowed
    configs are only supported when ``max_seq <= sliding_window`` (ring
    slot == position, so the linear page layout matches the ring layout);
    the engine falls back to the dense path otherwise.

    Returns (logits [B,T,V], slim cache {"pos"}, k_pages, v_pages).
    """
    B, T = tokens.shape
    pos = jnp.broadcast_to(cache["pos"], (B,))
    x = L.embed(tokens, params["embed"]).astype(cfg.jnp_dtype)
    positions = pos[:, None] + jnp.arange(T)[None, :]          # [B,T]
    valid = jnp.arange(T)[None, :] < valid_len[:, None]        # [B,T]
    W = cfg.sliding_window
    C = min(max_seq, W) if W else max_seq

    _nl, n_pages, P, Hkv, Dh = k_pages.shape
    maxp = page_table.shape[1]
    # position -> (page, offset) routing for the chunk's scatter writes
    pslot = jnp.minimum(positions // P, maxp - 1)              # [B,T]
    page_of = jnp.take_along_axis(page_table, pslot, axis=1)   # [B,T]
    off = positions % P
    oob = (~valid) | (page_of < 0) | (positions >= C)
    widx = jnp.where(oob, n_pages, page_of)                    # drop sentinel
    pt_c = jnp.maximum(page_table, 0)                          # [B,maxp]

    def gather(pages):
        # dense [B, C, Hkv, Dh] view — same length as the dense slot cache,
        # so the attention HLO (and its reduction order) is identical
        return pages[pt_c].reshape(B, maxp * P, Hkv, Dh)[:, :C]

    def step(carry, xs):
        x = carry
        layer_p, kp, vp = xs
        h = L.apply_norm(x, layer_p["attn_norm"], cfg)
        q, k, v = L.attention_qkv(h, layer_p["attn"], cfg, positions)
        if kernel:
            kp = kp.at[widx, off].set(k.astype(kp.dtype), mode="drop")
            vp = vp.at[widx, off].set(v.astype(vp.dtype), mode="drop")
            o = L.paged_chunk_attention(q, kp, vp, page_table, pos, cfg)
        elif W:
            # mirror decode_chunk's order exactly: attend the pre-write
            # view + the chunk itself, then write
            o = L.chunk_decode_attention_windowed(
                q, gather(kp), gather(vp), k, v, pos, valid_len, positions,
                cfg, window=W)
            kp = kp.at[widx, off].set(k.astype(kp.dtype), mode="drop")
            vp = vp.at[widx, off].set(v.astype(vp.dtype), mode="drop")
        else:
            kp = kp.at[widx, off].set(k.astype(kp.dtype), mode="drop")
            vp = vp.at[widx, off].set(v.astype(vp.dtype), mode="drop")
            o = L.chunk_decode_attention(q, gather(kp), gather(vp),
                                         positions, cfg)
        x = x + jnp.einsum("bshk,hkd->bsd", o, layer_p["attn"]["wo"])
        h = L.apply_norm(x, layer_p["mlp_norm"], cfg)
        y, _aux = _ffn(h, layer_p, cfg, moe_impl)
        x = x + y
        return x, (kp, vp)

    x, (ks, vs) = jax.lax.scan(step, x, (params["layers"], k_pages, v_pages))
    x = L.apply_norm(x, params["final_norm"], cfg)
    logits = L.unembed(x, params["embed"], cfg)                # [B,T,V]
    return logits, {"pos": pos + valid_len}, ks, vs
