"""Shared model building blocks (pure JAX, pytree params).

Conventions:
  * params are nested dicts of jnp arrays; layer-stacked arrays have a
    leading L dimension and run under ``jax.lax.scan``;
  * activations flow in ``cfg.jnp_dtype`` (bf16 by default); norms/softmax
    accumulate in f32;
  * attention math matches the published architectures: GQA with optional
    per-head qk RMSNorm (Qwen3), partial RoPE (StableLM-2), sliding windows
    (RecurrentGemma local layers, long-context dense carve-out).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig


# ------------------------------------------------------------------- norms
def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
               eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * weight.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def apply_norm(x: jnp.ndarray, p: dict, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.norm_type == "layer":
        return layer_norm(x, p["w"], p["b"], cfg.norm_eps)
    return rms_norm(x, p["w"], cfg.norm_eps)


def init_norm(cfg: ModelConfig, width: Optional[int] = None) -> dict:
    d = width or cfg.d_model
    p = {"w": jnp.ones((d,), cfg.jnp_dtype)}
    if cfg.norm_type == "layer":
        p["b"] = jnp.zeros((d,), cfg.jnp_dtype)
    return p


# -------------------------------------------------------------------- RoPE
def rope_frequencies(head_dim: int, rope_pct: float, theta: float):
    rot = int(head_dim * rope_pct) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """x: [..., S, H, Dh]; positions: [..., S] (broadcastable)."""
    inv, rot = rope_frequencies(cfg.head_dim_, cfg.rope_pct, cfg.rope_theta)
    if rot == 0:
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv  # [..., S, rot/2]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., :, None, :]    # [..., S, 1, rot/2]
    cos = cos[..., :, None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([rotated.astype(x.dtype), x_pass], axis=-1)


# --------------------------------------------------------------- attention
def repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """[B,S,Hkv,Dh] -> [B,S,Hkv*n_rep,Dh] (GQA broadcast)."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(
        b, s, h * n_rep, d)


def causal_mask(q_len: int, kv_len: int, q_offset,
                window: Optional[int] = None) -> jnp.ndarray:
    """Boolean [q_len, kv_len]; True = attendable.  q position i (global
    q_offset+i) may attend kv position j iff j <= i and (window is None or
    i - j < window)."""
    qpos = q_offset + jnp.arange(q_len)[:, None]
    kpos = jnp.arange(kv_len)[None, :]
    m = kpos <= qpos
    if window is not None:
        m = m & (kpos > qpos - window)
    return m


def sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
         mask: Optional[jnp.ndarray], scale: float) -> jnp.ndarray:
    """Softmax attention.  q:[B,Sq,H,Dh] k,v:[B,Skv,H,Dh] mask:[Sq,Skv]."""
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask[None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)


def chunked_sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                 causal: bool, window: Optional[int], scale: float,
                 q_chunk: int = 512) -> jnp.ndarray:
    """Memory-efficient attention: scan over query chunks so only a
    [B, H, q_chunk, Skv] score block is ever live (the XLA-level analogue
    of the Pallas flash kernel — used at production shapes where the full
    [B, H, S, S] matrix does not fit HBM; EXPERIMENTS.md §Perf iter 5)."""
    B, S, H, D = q.shape
    Skv = k.shape[1]
    c = min(q_chunk, S)
    pad = (-S) % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = (S + pad) // c
    qb = q.reshape(B, nq, c, H, D).transpose(1, 0, 2, 3, 4)  # [nq,B,c,H,D]
    kpos = jnp.arange(Skv)

    def block(carry, inp):
        i, qi = inp
        logits = jnp.einsum("bqhd,bkhd->bhqk", qi, k,
                            preferred_element_type=jnp.float32) * scale
        qpos = i * c + jnp.arange(c)
        m = jnp.ones((c, Skv), bool)
        if causal:
            m = m & (kpos[None, :] <= qpos[:, None])
        if window is not None:
            m = m & (kpos[None, :] > qpos[:, None] - window)
        logits = jnp.where(m[None, None], logits, -1e30)
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        return carry, jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)

    _, out = jax.lax.scan(block, None, (jnp.arange(nq), qb))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, S + pad, H, D)
    return out[:, :S]


def full_attention(q, k, v, *, causal: bool, window, scale: float,
                   impl: str = "xla"):
    """Dispatch full-sequence attention (k/v already GQA-expanded)."""
    if impl == "pallas":
        from ..kernels.flash_attention.ops import flash_attention
        B, S, H, D = q.shape
        return flash_attention(q, k, v, causal=causal, window=window,
                               scale=scale)
    if impl == "xla_chunked":
        return chunked_sdpa(q, k, v, causal=causal, window=window,
                            scale=scale)
    mask = causal_mask(q.shape[1], k.shape[1], 0, window) if causal or window \
        else None
    return sdpa(q, k, v, mask, scale)


def init_attention(rng, cfg: ModelConfig) -> dict:
    D, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    k = jax.random.split(rng, 4)
    s = lambda *shape: (2.0 / (shape[0] + shape[-1])) ** 0.5
    p = {
        "wq": (jax.random.normal(k[0], (D, H, Dh)) * s(D, Dh)).astype(cfg.jnp_dtype),
        "wk": (jax.random.normal(k[1], (D, Hkv, Dh)) * s(D, Dh)).astype(cfg.jnp_dtype),
        "wv": (jax.random.normal(k[2], (D, Hkv, Dh)) * s(D, Dh)).astype(cfg.jnp_dtype),
        "wo": (jax.random.normal(k[3], (H, Dh, D)) * s(Dh, D)).astype(cfg.jnp_dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((Dh,), cfg.jnp_dtype)
        p["k_norm"] = jnp.ones((Dh,), cfg.jnp_dtype)
    return p


def attention_qkv(x: jnp.ndarray, p: dict, cfg: ModelConfig,
                  positions: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Project + qk-norm + rope.  Returns q:[B,S,H,Dh], k/v:[B,S,Hkv,Dh]."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg)
    k = apply_rope(k, positions, cfg)
    return q, k, v


def attention_block(x: jnp.ndarray, p: dict, cfg: ModelConfig,
                    positions: jnp.ndarray,
                    window: Optional[int] = None,
                    attention_impl: str = "xla") -> jnp.ndarray:
    """Full (training / prefill) self-attention over x:[B,S,D]."""
    B, S, _ = x.shape
    q, k, v = attention_qkv(x, p, cfg, positions)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    scale = cfg.head_dim_ ** -0.5
    if attention_impl == "pallas":
        from ..kernels.flash_attention.ops import flash_attention
        out = flash_attention(q, repeat_kv(k, n_rep), repeat_kv(v, n_rep),
                              causal=True, window=window, scale=scale)
    elif attention_impl == "xla_chunked":
        out = chunked_sdpa(q, repeat_kv(k, n_rep), repeat_kv(v, n_rep),
                           causal=True, window=window, scale=scale)
    else:
        mask = causal_mask(S, S, 0, window)
        out = sdpa(q, repeat_kv(k, n_rep), repeat_kv(v, n_rep), mask, scale)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# --------------------------------------------------------------------- MLP
def init_mlp(rng, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    k = jax.random.split(rng, 3)
    s_in = (2.0 / (D + F)) ** 0.5
    p = {
        "w_up": (jax.random.normal(k[0], (D, F)) * s_in).astype(cfg.jnp_dtype),
        "w_down": (jax.random.normal(k[1], (F, D)) * s_in).astype(cfg.jnp_dtype),
    }
    if cfg.mlp_type == "swiglu":
        p["w_gate"] = (jax.random.normal(k[2], (D, F)) * s_in).astype(cfg.jnp_dtype)
    return p


def mlp_block(x: jnp.ndarray, p: dict, cfg: ModelConfig) -> jnp.ndarray:
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]


# --------------------------------------------------------------- embedding
def init_embedding(rng, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(rng)
    p = {"tok": (jax.random.normal(k1, (cfg.vocab_size, cfg.d_model))
                 * cfg.d_model ** -0.5).astype(cfg.jnp_dtype)}
    if not cfg.tie_embeddings:
        p["out"] = (jax.random.normal(k2, (cfg.d_model, cfg.vocab_size))
                    * cfg.d_model ** -0.5).astype(cfg.jnp_dtype)
    return p


def embed(tokens: jnp.ndarray, p: dict) -> jnp.ndarray:
    return p["tok"][tokens]


@jax.custom_vjp
def _tied_unembed(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("...d,vd->...v", x, w)


def _tied_unembed_fwd(x, w):
    from ..distributed.context import constrain
    return constrain(_tied_unembed(x, w), "logits"), (x, w)


def _tied_unembed_bwd(res, g):
    """Backward with the cotangent explicitly constrained to the logits
    sharding.  Without this, GSPMD materializes replicated d(logits) for the
    tied-weight gradient — the residual ~40 GB all-gather of EXPERIMENTS.md
    §Perf iteration 1.  dw is a local v-shard product + a small all-reduce
    over the batch axis; dx is a sharded-v contraction (partial-sum).
    """
    from ..distributed.context import constrain
    x, w = res
    g = constrain(g, "logits")
    dx = jnp.einsum("...v,vd->...d", g, w).astype(x.dtype)
    dw = jnp.einsum("...v,...d->vd", g, x).astype(w.dtype)
    return dx, dw


_tied_unembed.defvjp(_tied_unembed_fwd, _tied_unembed_bwd)


def unembed(x: jnp.ndarray, p: dict, cfg: ModelConfig) -> jnp.ndarray:
    from ..distributed.context import constrain
    if cfg.tie_embeddings:
        return _tied_unembed(x, p["tok"])
    return constrain(x @ p["out"], "logits")


# ------------------------------------------------------------ decode utils
def pad_cache_seq(ks: jnp.ndarray, vs: jnp.ndarray, C: int,
                  window: Optional[int], pad_cache_to: Optional[int]):
    """Grow a prefill cache's seq dim (axis 2 of [L,B,C,H,D]) for decode
    headroom.  Windowed caches never grow past the window (the ring already
    holds the last `window` entries; C == window when S > window)."""
    if pad_cache_to is None:
        return ks, vs
    target = min(pad_cache_to, window) if window else pad_cache_to
    if target <= C:
        return ks, vs
    pads = [(0, 0), (0, 0), (0, target - C), (0, 0), (0, 0)]
    return jnp.pad(ks, pads), jnp.pad(vs, pads)

def kv_cache_update(cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                    k: jnp.ndarray, v: jnp.ndarray,
                    pos: jnp.ndarray, window: Optional[int]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Write one step's k/v ([B,1,Hkv,Dh]) at per-sequence ``pos`` [B]
    (ring-rolled if windowed).  cache_[kv]: [B, C, Hkv, Dh]."""
    B, C = cache_k.shape[0], cache_k.shape[1]
    pos = jnp.broadcast_to(pos, (B,))
    slot = pos % C if window is not None else jnp.minimum(pos, C - 1)
    b = jnp.arange(B)
    ck = cache_k.at[b, slot].set(k[:, 0].astype(cache_k.dtype))
    cv = cache_v.at[b, slot].set(v[:, 0].astype(cache_v.dtype))
    return ck, cv


def kv_cache_update_chunk(cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                          k: jnp.ndarray, v: jnp.ndarray,
                          pos: jnp.ndarray, valid: jnp.ndarray,
                          window: Optional[int]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Write up to T tokens' k/v ([B,T,Hkv,Dh]) at per-sequence positions
    ``pos .. pos+T-1`` (ring-rolled if windowed).  ``valid`` [B,T] masks the
    tail: an invalid position re-writes the cache's existing value, so a
    sequence advancing fewer than T tokens (a decode slot piggybacked on a
    prefill chunk) leaves the rest of its row untouched."""
    B, C = cache_k.shape[0], cache_k.shape[1]
    T = k.shape[1]
    pos = jnp.broadcast_to(pos, (B,))
    positions = pos[:, None] + jnp.arange(T)[None, :]          # [B,T]
    slot = positions % C if window is not None else jnp.minimum(positions,
                                                                C - 1)
    b = jnp.arange(B)[:, None]
    m = valid[..., None, None]
    ck = cache_k.at[b, slot].set(
        jnp.where(m, k.astype(cache_k.dtype), cache_k[b, slot]))
    cv = cache_v.at[b, slot].set(
        jnp.where(m, v.astype(cache_v.dtype), cache_v[b, slot]))
    return ck, cv


def chunk_decode_attention(q: jnp.ndarray, cache_k: jnp.ndarray,
                           cache_v: jnp.ndarray, positions: jnp.ndarray,
                           cfg: ModelConfig) -> jnp.ndarray:
    """T-token attention: q:[B,T,H,Dh] over a *non-ring* cache
    [B,C,Hkv,Dh] whose chunk k/v has already been written.

    ``positions`` [B,T] is the logical position of each query token; query
    t attends cache entries at positions <= positions[:, t] (slot index ==
    logical position without a sliding window), which gives causal
    attention within the chunk and full attention over the cached prefix —
    the chunked-prefill generalization of :func:`decode_attention` (T=1
    reduces to it).  Windowed (ring) caches must use
    :func:`chunk_decode_attention_windowed` instead: a chunk write can
    overwrite ring slots that earlier in-chunk queries still need.
    """
    B, C = cache_k.shape[0], cache_k.shape[1]
    n_rep = cfg.n_heads // cfg.n_kv_heads
    scale = cfg.head_dim_ ** -0.5
    k = repeat_kv(cache_k, n_rep)
    v = repeat_kv(cache_v, n_rep)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    slots = jnp.arange(C)
    p = positions[..., None]                                   # [B,T,1]
    valid = slots[None, None, :] <= p
    logits = jnp.where(valid[:, None], logits, -1e30)          # [B,H,T,C]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)


def chunk_decode_attention_windowed(q: jnp.ndarray, cache_k: jnp.ndarray,
                                    cache_v: jnp.ndarray, k_new: jnp.ndarray,
                                    v_new: jnp.ndarray, pos: jnp.ndarray,
                                    valid_len: jnp.ndarray,
                                    positions: jnp.ndarray, cfg: ModelConfig,
                                    window: int) -> jnp.ndarray:
    """Chunked attention for ring (sliding-window) caches, computed
    against the **pre-write** cache plus the chunk's own k/v.

    Writing a whole chunk into a ring of size C before attending is wrong
    for the earlier in-chunk queries: a later chunk token can land on the
    ring slot of a position still inside an earlier query's window.  So
    each query t (logical position ``positions[:, t]``) attends

    * the pre-write cache, whose slot ``s`` holds the largest logical
      position < pos congruent to ``s`` (mod C), masked to the query's
      window, plus
    * the chunk itself, causally (``t' <= t``) and window-masked, limited
      to each sequence's ``valid`` length.

    The ring write (:func:`kv_cache_update_chunk`) happens *after* this.
    """
    B, C = cache_k.shape[0], cache_k.shape[1]
    T = k_new.shape[1]
    n_rep = cfg.n_heads // cfg.n_kv_heads
    scale = cfg.head_dim_ ** -0.5
    pos = jnp.broadcast_to(pos, (B,))
    p = positions[..., None]                                   # [B,T,1]
    win = min(window, C)
    # pre-write holder of ring slot s: largest position < pos with
    # position % C == s (negative -> the slot was never written)
    slots = jnp.arange(C)[None, :]
    h_old = pos[:, None] - 1 - ((pos[:, None] - 1 - slots) % C)  # [B,C]
    valid_old = (h_old[:, None, :] >= 0) & (h_old[:, None, :] > p - win)
    k_c = repeat_kv(cache_k, n_rep)
    v_c = repeat_kv(cache_v, n_rep)
    log_c = jnp.einsum("bqhd,bkhd->bhqk", q, k_c,
                       preferred_element_type=jnp.float32) * scale
    log_c = jnp.where(valid_old[:, None], log_c, -1e30)
    # in-chunk: causal, window-masked, clipped to the sequence's valid len
    t_new = jnp.arange(T)
    p_new = pos[:, None] + t_new[None, :]                      # [B,T]
    valid_new = ((p_new[:, None, :] <= p) & (p_new[:, None, :] > p - win)
                 & (t_new[None, None, :] < valid_len[:, None, None]))
    k_n = repeat_kv(k_new, n_rep)
    v_n = repeat_kv(v_new, n_rep)
    log_n = jnp.einsum("bqhd,bkhd->bhqk", q, k_n,
                       preferred_element_type=jnp.float32) * scale
    log_n = jnp.where(valid_new[:, None], log_n, -1e30)
    logits = jnp.concatenate([log_c, log_n], axis=-1)          # [B,H,T,C+T]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    vals = jnp.concatenate([v_c, v_n], axis=1)                 # [B,C+T,...]
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(vals.dtype), vals)


def paged_chunk_attention(q: jnp.ndarray, k_pages: jnp.ndarray,
                          v_pages: jnp.ndarray, page_table: jnp.ndarray,
                          pos: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Pallas paged attention for a T-token chunk straight over the KV pool
    (q [B,T,H,Dh]; pages [n_pages,P,Hkv,Dh]; the chunk's K/V must already
    be scattered into the pages).

    The serving fast path behind ``decode_chunk_paged(kernel=True)``: no
    dense gather is materialized — pages stage HBM->VMEM by table lookup.
    Numerics match :func:`chunk_decode_attention` to float tolerance but
    not bitwise (different softmax accumulation order), so the engine
    gates it behind its ``paged_kernel`` knob (auto-on on TPU only)."""
    from ..kernels.paged_attention.ops import paged_decode_chunk_attention
    return paged_decode_chunk_attention(
        q, k_pages, v_pages, page_table, pos,
        scale=cfg.head_dim_ ** -0.5,
        n_rep=cfg.n_heads // cfg.n_kv_heads)


def decode_attention(q: jnp.ndarray, cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                     pos: jnp.ndarray, cfg: ModelConfig,
                     window: Optional[int] = None,
                     impl: str = "xla") -> jnp.ndarray:
    """One-token attention: q:[B,1,H,Dh] over cache [B,C,Hkv,Dh].

    ``pos`` [B] is the (0-based) position of each sequence's new token;
    cache entries at logical positions <= pos are valid.  With a window the
    cache is a ring buffer and entries older than ``window`` are masked.
    """
    B, C = cache_k.shape[0], cache_k.shape[1]
    pos = jnp.broadcast_to(pos, (B,))
    if impl == "pallas":
        from ..kernels.paged_attention.ops import decode_attention_kernel
        return decode_attention_kernel(q, cache_k, cache_v, pos,
                                       window=window,
                                       scale=cfg.head_dim_ ** -0.5,
                                       n_rep=cfg.n_heads // cfg.n_kv_heads)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    scale = cfg.head_dim_ ** -0.5
    k = repeat_kv(cache_k, n_rep)
    v = repeat_kv(cache_v, n_rep)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    slots = jnp.arange(C)
    if window is not None:
        # ring buffer: slot s holds logical position p with p % C == s and
        # p in (pos-window, pos]; newest write sits at pos % C.
        age = (pos[:, None] % C - slots[None, :]) % C        # [B,C], 0=newest
        valid = age < jnp.minimum(window, pos[:, None] + 1)
    else:
        valid = slots[None, :] <= pos[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
