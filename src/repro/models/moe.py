"""Mixture-of-Experts FFN (qwen3-moe / granite-moe families).

Top-k routing with capacity-bucketed one-hot dispatch (Mesh/Flaxformer
lineage): tokens are processed in groups of ``group_size`` so the dispatch
tensor [G, E, C] stays VMEM-scale; expert weights shard over the `model`
mesh axis (expert parallelism) and the dispatch/combine einsums lower to the
all-to-all the roofline analysis tracks.

Three dispatch implementations:
  * "einsum"  — baseline one-hot matmul dispatch (this file's default);
  * "gather"  — beyond-paper optimization used by the perf hillclimb
    (EXPERIMENTS.md §Perf): index-gather dispatch that removes the one-hot
    matmul FLOPs.
  * "dropless" — per-token inference dispatch with no capacity buffers.
    The capacity impls are priority-ordered across the whole token group
    (every first choice lands before any second choice), so whether a
    token's choice is dropped depends on *other* tokens in the batch —
    correct Switch-style training semantics, but it makes decode outputs
    a function of batch composition.  Serving needs batch invariance
    (chunked == sequential, speculative verify == plain decode, bitwise),
    so the serving entry points route through "dropless" instead.

The router's per-expert load statistics are exported via an auxiliary output
so the serving layer can feed them to NALAR's global controller as telemetry
(DESIGN.md §4: router load-balance feeds the control plane).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig


def init_moe_layer(rng, cfg: ModelConfig) -> dict:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.d_expert
    k = jax.random.split(rng, 4)
    s = (2.0 / (D + F)) ** 0.5
    return {
        "router": (jax.random.normal(k[0], (D, E)) * D ** -0.5).astype(jnp.float32),
        "w_gate": (jax.random.normal(k[1], (E, D, F)) * s).astype(cfg.jnp_dtype),
        "w_up": (jax.random.normal(k[2], (E, D, F)) * s).astype(cfg.jnp_dtype),
        "w_down": (jax.random.normal(k[3], (E, F, D)) * s).astype(cfg.jnp_dtype),
    }


def _capacity(group: int, cfg: ModelConfig) -> int:
    c = int(group * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(4, -(-c // 4) * 4)   # round up to a multiple of 4


def _route(xg: jnp.ndarray, router: jnp.ndarray, cfg: ModelConfig):
    """xg: [G, D] -> (gates [G,k], idx [G,k] int32, probs [G,E])."""
    logits = xg.astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)  # qwen3 renorm
    return gates, idx, probs


def _dispatch_masks(idx: jnp.ndarray, gates: jnp.ndarray, G: int, C: int,
                    cfg: ModelConfig):
    """Positions in per-expert buffers, k choices in priority order.

    Returns dispatch [G,E,C] (0/1) and combine [G,E,C] (gated), plus the
    per-expert assignment counts [E] (router telemetry).
    """
    E = cfg.n_experts
    dt = cfg.jnp_dtype
    counts = jnp.zeros((E,), jnp.int32)
    dispatch = jnp.zeros((G, E, C), dt)
    combine = jnp.zeros((G, E, C), jnp.float32)
    for j in range(cfg.top_k):                     # static small loop
        mask_j = jax.nn.one_hot(idx[:, j], E, dtype=jnp.int32)       # [G,E]
        pos_j = jnp.cumsum(mask_j, axis=0) - 1 + counts[None, :]     # [G,E]
        within = (pos_j < C) & (mask_j > 0)
        oh = jax.nn.one_hot(jnp.where(within, pos_j, 0), C, dtype=dt)
        oh = oh * within[:, :, None].astype(dt)                      # [G,E,C]
        dispatch = dispatch + oh
        combine = combine + oh.astype(jnp.float32) * gates[:, j, None, None]
        counts = counts + jnp.sum(mask_j * within.astype(jnp.int32), axis=0)
    return dispatch, combine, counts


def _expert_ffn(xe: jnp.ndarray, p: dict, cfg: ModelConfig) -> jnp.ndarray:
    """xe: [E, C, D] -> [E, C, D]."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def _group_einsum(xg: jnp.ndarray, p: dict, cfg: ModelConfig):
    G = xg.shape[0]
    C = _capacity(G, cfg)
    gates, idx, probs = _route(xg, p["router"], cfg)
    dispatch, combine, counts = _dispatch_masks(idx, gates, G, C, cfg)
    xe = jnp.einsum("gec,gd->ecd", dispatch, xg.astype(cfg.jnp_dtype))
    ye = _expert_ffn(xe.astype(cfg.jnp_dtype), p, cfg)
    y = jnp.einsum("gec,ecd->gd", combine.astype(ye.dtype), ye)
    return y.astype(xg.dtype), probs, counts


def _group_gather(xg: jnp.ndarray, p: dict, cfg: ModelConfig):
    """Gather-based dispatch: same routing, no one-hot matmuls.

    Builds per-expert row indices by sorting token-copies by expert id, then
    uses take/segment-add instead of [G,E,C] einsums.
    """
    G, D = xg.shape
    E, k = cfg.n_experts, cfg.top_k
    C = _capacity(G, cfg)
    gates, idx, probs = _route(xg, p["router"], cfg)
    # flatten (token, choice) pairs; sort stably by expert id
    flat_e = idx.reshape(-1)                                   # [G*k]
    flat_t = jnp.repeat(jnp.arange(G), k)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    # position within expert = rank - first_rank_of_expert
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts                       # [E]
    ranks = jnp.arange(G * k)
    pos = ranks - starts[se]
    within = pos < C
    # destination slot in the [E*C] buffer
    slot = jnp.where(within, se * C + pos, E * C)              # E*C = dropped
    buf = jnp.zeros((E * C + 1, D), cfg.jnp_dtype)
    buf = buf.at[slot].set(xg[st].astype(cfg.jnp_dtype))
    xe = buf[:-1].reshape(E, C, D)
    ye = _expert_ffn(xe, p, cfg)
    # combine: token t accumulates gate * ye[slot]
    ye_flat = jnp.concatenate([ye.reshape(E * C, D),
                               jnp.zeros((1, D), ye.dtype)])
    contrib = ye_flat[slot] * (sg * within).astype(ye.dtype)[:, None]
    y = jnp.zeros((G, D), ye.dtype).at[st].add(contrib)
    return y.astype(xg.dtype), probs, counts.astype(jnp.int32)


def _dropless(xt: jnp.ndarray, p: dict, cfg: ModelConfig):
    """Per-token dropless MoE: every token keeps all ``top_k`` choices.

    No capacity buffers and no cross-token state, so a token's output is
    bitwise invariant to what it is batched with — the property chunked
    decode and the speculative verifier rely on.  Computes all ``E``
    experts densely and masks the combine to the top-k gates (E/k x the
    routed FLOPs; production engines get the same semantics from grouped
    GEMMs, this repo's scale doesn't warrant one).
    """
    E = cfg.n_experts
    gates, idx, probs = _route(xt, p["router"], cfg)          # [T,k], [T,E]
    xe = xt.astype(cfg.jnp_dtype)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xe, p["w_gate"]))
    h = h * jnp.einsum("td,edf->tef", xe, p["w_up"])
    ye = jnp.einsum("tef,efd->ted", h, p["w_down"])           # [T,E,D]
    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)        # [T,k,E]
    w = jnp.sum(onehot * gates[..., None], axis=1)            # [T,E]
    y = jnp.einsum("te,ted->td", w, ye.astype(jnp.float32))
    counts = jnp.sum(onehot, axis=(0, 1)).astype(jnp.int32)
    return y.astype(xt.dtype), probs, counts


def load_balance_loss(probs: jnp.ndarray, counts: jnp.ndarray,
                      cfg: ModelConfig) -> jnp.ndarray:
    """Switch-style aux loss: E * <f_e> . <p_e>."""
    E = cfg.n_experts
    frac = counts.astype(jnp.float32) / jnp.maximum(jnp.sum(counts), 1)
    mean_p = jnp.mean(probs, axis=0)
    return E * jnp.sum(frac * mean_p)


def moe_block(x: jnp.ndarray, p: dict, cfg: ModelConfig,
              group_size: int = 2048, impl: str = "einsum"):
    """x: [B,S,D] -> (y, aux_loss, expert_counts [E])."""
    B, S, D = x.shape
    T = B * S
    xt = x.reshape(T, D)
    if impl == "dropless":
        y, probs, counts = _dropless(xt, p, cfg)
        aux = load_balance_loss(probs, counts, cfg)
        return y.reshape(B, S, D), aux, counts
    G = min(group_size, T)
    if T % G != 0:   # pad to a whole number of groups
        pad = G - T % G
        xt = jnp.concatenate([xt, jnp.zeros((pad, D), xt.dtype)])
    n_groups = xt.shape[0] // G
    xg = xt.reshape(n_groups, G, D)
    fn = _group_gather if impl == "gather" else _group_einsum

    if n_groups == 1:
        y, probs, counts = fn(xg[0], p, cfg)
        y = y[None]
        aux = load_balance_loss(probs, counts, cfg)
    else:
        # vmap (NOT lax.map): a loop's dynamic_slice over the data-sharded
        # group dim makes GSPMD all-gather the whole token tensor per group
        # iteration (§Perf iter 2b); vmap keeps groups shard-local.
        y, probs, counts = jax.vmap(
            functools.partial(fn, p=p, cfg=cfg))(xg)
        aux = load_balance_loss(probs.reshape(-1, cfg.n_experts),
                                jnp.sum(counts, axis=0), cfg)
        counts = jnp.sum(counts, axis=0)
    y = y.reshape(-1, D)[:T].reshape(B, S, D)
    return y, aux, counts
