"""Unified model API: build_model(cfg) -> Model.

One façade over the five families so the serving engine, trainer, launcher,
and dry-run treat every assigned architecture identically:

    model.init(rng)                      -> params
    model.forward(params, batch)         -> (logits, aux)
    model.loss_fn(params, batch)         -> scalar loss
    model.prefill(params, batch)         -> (last_logits, cache)
    model.decode_step(params, tok, cache)-> (logits, cache)
    model.init_cache(batch, max_seq)     -> cache pytree
    model.input_specs(shape)             -> {name: ShapeDtypeStruct}

``input_specs`` returns allocation-free stand-ins for every model input,
including the stubbed modality frontends (audio frames / image patches).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import InputShape, ModelConfig
from . import encdec, rglru, ssm, transformer, vlm

AUX_COEF = 0.01   # MoE load-balance loss weight


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None,
                  impl: str = "onehot") -> jnp.ndarray:
    """Cross entropy over (possibly vocab-sharded) logits.

    ``impl="onehot"`` extracts the gold logit with a one-hot contraction
    instead of ``take_along_axis``: the contraction stays *local* on each
    vocab shard (only a tiny [B,S] partial-sum all-reduce crosses the
    interconnect), whereas the gather's transpose makes GSPMD materialize
    the full [B,S,V] logits on every model shard — measured at 3 x ~40 GB
    of per-device collective traffic on qwen3-0.6b train_4k
    (EXPERIMENTS.md §Perf iteration 1).  "gather" keeps the naive path for
    comparison.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    if impl == "gather":
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    else:
        V = logits.shape[-1]
        onehot = (labels[..., None] == jnp.arange(V)[None, None, :]
                  if labels.ndim == 2 else
                  labels[..., None] == jnp.arange(V))
        gold = jnp.sum(logits * onehot.astype(logits.dtype), axis=-1)
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return jnp.mean(nll)


def chunked_cross_entropy(hidden: jnp.ndarray, labels: jnp.ndarray,
                          params: dict, cfg: ModelConfig,
                          mask: Optional[jnp.ndarray] = None,
                          chunk: int = 512) -> jnp.ndarray:
    """Cross entropy without materializing full [B, S, V] logits.

    Scans over sequence chunks; each chunk unembeds + reduces under
    jax.checkpoint, so only one [B, chunk, V/shards] logits block is live at
    a time (fwd and bwd).  This is what lets the production train shapes
    fit HBM (EXPERIMENTS.md §Perf iteration 5): the f32 logits+dlogits pair
    alone is ~74 GiB/device on qwen3-0.6b train_4k otherwise.
    """
    from . import layers as L
    B, S, D = hidden.shape
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask_full = jnp.pad(
            mask if mask is not None else jnp.ones((B, S), bool),
            ((0, 0), (0, pad)))
    else:
        mask_full = mask if mask is not None else jnp.ones((B, S), bool)
    ns = (S + pad) // c
    h_c = hidden.reshape(B, ns, c, D).transpose(1, 0, 2, 3)
    y_c = labels.reshape(B, ns, c).transpose(1, 0, 2)
    m_c = mask_full.reshape(B, ns, c).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_nll(h, y, m):
        logits = L.unembed(h, params["embed"], cfg).astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        onehot = y[..., None] == jnp.arange(cfg.vocab_size)[None, None, :]
        gold = jnp.sum(logits * onehot.astype(logits.dtype), axis=-1)
        return jnp.sum((logz - gold) * m), jnp.sum(m)

    def step(carry, inp):
        tot, cnt = carry
        h, y, m = inp
        s, n = chunk_nll(h, y, m)
        return (tot + s, cnt + n), None

    (total, count), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (h_c, y_c, m_c))
    return total / jnp.maximum(count, 1.0)


@dataclass
class Model:
    cfg: ModelConfig
    init: Callable[[Any], dict]
    forward: Callable[..., Any]
    loss_fn: Callable[[dict, Dict[str, jnp.ndarray]], jnp.ndarray]
    prefill: Callable[..., Tuple[jnp.ndarray, dict]]
    decode_step: Callable[[dict, jnp.ndarray, dict], Tuple[jnp.ndarray, dict]]
    init_cache: Callable[[int, int], dict]
    input_specs: Callable[[InputShape], Dict[str, Any]]
    # decode_chunk(params, tokens [B,T], valid_len [B], cache) -> (logits
    # [B,T,V], cache): T tokens in one forward, each sequence advancing by
    # valid_len[b] <= T positions — the serving engine's chunked-prefill
    # fast path.  Every family wires one: attention families fuse the
    # chunk natively, recurrent families scan masked single steps in-jit.
    decode_chunk: Optional[Callable[..., Tuple[jnp.ndarray, dict]]] = None
    # decode_chunk_paged(params, tokens, valid_len, slim_cache, k_pages,
    # v_pages, page_table, *, max_seq, kernel) -> (logits, slim_cache,
    # k_pages, v_pages): the paged-native variant — K/V is read from and
    # scattered into the engine's KV pool pages by table, no dense per-slot
    # cache exists.  None for families whose decode state is O(1)
    # (ssm/hybrid use StateCachePool, not pages).
    decode_chunk_paged: Optional[Callable[..., Any]] = None
    # encode_cross(params, frames) -> (xk, xv): encoder-decoder only — one
    # encoder pass producing the per-layer cross-attention memory, so
    # chunked admission can populate a slot without a monolithic prefill.
    encode_cross: Optional[Callable[..., Any]] = None

    def param_shapes(self) -> dict:
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    def cache_shapes(self, batch: int, max_seq: int) -> dict:
        # batch/max_seq are shape parameters, not traced values
        return jax.eval_shape(lambda: self.init_cache(batch, max_seq))


def _token_specs(shape: InputShape, cfg: ModelConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32)}
    if shape.kind == "prefill":
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    return {"token": jax.ShapeDtypeStruct((B,), i32)}


def build_model(cfg: ModelConfig, attention_impl: str = "xla",
                moe_impl: str = "einsum", remat: bool = False,
                moe_serve_impl: str = "dropless") -> Model:
    fam = cfg.family

    # ----------------------------------------------------------- dense/moe
    if fam in ("dense", "moe"):
        def fwd(params, batch):
            return transformer.forward(params, cfg, batch["tokens"],
                                       attention_impl=attention_impl,
                                       moe_impl=moe_impl, return_aux=True,
                                       remat=remat)

        def loss_fn(params, batch):
            if remat:   # production memory path: never materialize logits
                hidden, aux = transformer.forward(
                    params, cfg, batch["tokens"],
                    attention_impl=attention_impl, moe_impl=moe_impl,
                    return_aux=True, remat=True, unembed=False)
                return chunked_cross_entropy(hidden, batch["labels"], params,
                                             cfg) + AUX_COEF * aux
            logits, aux = fwd(params, batch)
            return cross_entropy(logits, batch["labels"]) + AUX_COEF * aux

        return Model(
            cfg=cfg,
            init=functools.partial(transformer.init_params, cfg=cfg),
            forward=fwd,
            loss_fn=loss_fn,
            # serving entry points use the dropless MoE dispatch: capacity
            # dropping is priority-ordered across the whole batch, so with
            # it a token's logits depend on batch composition — breaking
            # the pinned chunked == sequential and speculative == plain
            # bitwise invariants.  Training (forward/loss_fn) keeps the
            # paper's capacity semantics.
            prefill=lambda params, batch, **kw: transformer.prefill(
                params, cfg, batch["tokens"], attention_impl=attention_impl,
                moe_impl=moe_serve_impl, **kw),
            decode_step=lambda params, tok, cache: transformer.decode_step(
                params, cfg, tok, cache, attention_impl=attention_impl,
                moe_impl=moe_serve_impl),
            decode_chunk=lambda params, toks, n, cache: transformer.decode_chunk(
                params, cfg, toks, n, cache, attention_impl=attention_impl,
                moe_impl=moe_serve_impl),
            decode_chunk_paged=lambda params, toks, n, cache, kp, vp, pt, **kw:
                transformer.decode_chunk_paged(
                    params, cfg, toks, n, cache, kp, vp, pt,
                    attention_impl=attention_impl, moe_impl=moe_serve_impl,
                    **kw),
            init_cache=functools.partial(transformer.init_cache, cfg),
            input_specs=lambda shape: _token_specs(shape, cfg),
        )

    # ----------------------------------------------------------------- ssm
    if fam == "ssm":
        def fwd(params, batch):
            return (ssm.forward(params, cfg, batch["tokens"], remat=remat),
                    jnp.zeros((), jnp.float32))

        def loss_fn(params, batch):
            if remat:
                hidden = ssm.forward(params, cfg, batch["tokens"],
                                     remat=True, unembed=False)
                return chunked_cross_entropy(hidden, batch["labels"], params,
                                             cfg)
            logits, _ = fwd(params, batch)
            return cross_entropy(logits, batch["labels"])

        return Model(
            cfg=cfg,
            init=functools.partial(ssm.init_params, cfg=cfg),
            forward=fwd,
            loss_fn=loss_fn,
            prefill=lambda params, batch, **kw: ssm.prefill(params, cfg,
                                                            batch["tokens"]),
            decode_step=lambda params, tok, cache: ssm.decode_step(
                params, cfg, tok, cache),
            decode_chunk=lambda params, toks, n, cache: ssm.decode_chunk(
                params, cfg, toks, n, cache),
            init_cache=functools.partial(ssm.init_cache, cfg),
            input_specs=lambda shape: _token_specs(shape, cfg),
        )

    # -------------------------------------------------------------- hybrid
    if fam == "hybrid":
        def fwd(params, batch):
            return (rglru.forward(params, cfg, batch["tokens"],
                                  attention_impl=attention_impl,
                                  remat=remat),
                    jnp.zeros((), jnp.float32))

        def loss_fn(params, batch):
            if remat:
                hidden = rglru.forward(params, cfg, batch["tokens"],
                                       attention_impl=attention_impl,
                                       remat=True, unembed=False)
                return chunked_cross_entropy(hidden, batch["labels"], params,
                                             cfg)
            logits, _ = fwd(params, batch)
            return cross_entropy(logits, batch["labels"])

        return Model(
            cfg=cfg,
            init=functools.partial(rglru.init_params, cfg=cfg),
            forward=fwd,
            loss_fn=loss_fn,
            prefill=lambda params, batch, **kw: rglru.prefill(params, cfg,
                                                              batch["tokens"], **kw),
            decode_step=lambda params, tok, cache: rglru.decode_step(
                params, cfg, tok, cache),
            decode_chunk=lambda params, toks, n, cache: rglru.decode_chunk(
                params, cfg, toks, n, cache),
            init_cache=functools.partial(rglru.init_cache, cfg),
            input_specs=lambda shape: _token_specs(shape, cfg),
        )

    # ----------------------------------------------------------------- vlm
    if fam == "vlm":
        def specs(shape: InputShape) -> Dict[str, Any]:
            out = _token_specs(shape, cfg)
            if shape.kind != "decode":
                # stubbed vision tower output (ViT patches after projector)
                out["image_embeds"] = jax.ShapeDtypeStruct(
                    (shape.global_batch, cfg.n_image_tokens, cfg.d_model),
                    cfg.jnp_dtype)
            return out

        def fwd(params, batch):
            return vlm.forward(params, cfg, batch["tokens"],
                               batch.get("image_embeds"),
                               attention_impl=attention_impl,
                               return_aux=True, remat=remat)

        def loss_fn(params, batch):
            B, S_txt = batch["tokens"].shape
            mask = vlm.text_loss_mask(cfg, B, S_txt)
            pad = jnp.zeros((B, cfg.n_image_tokens), batch["labels"].dtype)
            labels = jnp.concatenate([pad, batch["labels"]], axis=1)
            if remat:
                hidden, aux = vlm.forward(params, cfg, batch["tokens"],
                                          batch.get("image_embeds"),
                                          attention_impl=attention_impl,
                                          return_aux=True, remat=True,
                                          unembed=False)
                return chunked_cross_entropy(hidden, labels, params, cfg,
                                             mask=mask) + AUX_COEF * aux
            logits, aux = fwd(params, batch)
            return cross_entropy(logits, labels, mask) + AUX_COEF * aux

        return Model(
            cfg=cfg,
            init=functools.partial(vlm.init_params, cfg=cfg),
            forward=fwd,
            loss_fn=loss_fn,
            prefill=lambda params, batch, **kw: vlm.prefill(
                params, cfg, batch["tokens"], batch.get("image_embeds"),
                attention_impl=attention_impl, **kw),
            decode_step=lambda params, tok, cache: vlm.decode_step(
                params, cfg, tok, cache),
            decode_chunk=lambda params, toks, n, cache: vlm.decode_chunk(
                params, cfg, toks, n, cache, attention_impl=attention_impl),
            decode_chunk_paged=lambda params, toks, n, cache, kp, vp, pt, **kw:
                vlm.decode_chunk_paged(
                    params, cfg, toks, n, cache, kp, vp, pt,
                    attention_impl=attention_impl, **kw),
            init_cache=functools.partial(vlm.init_cache, cfg),
            input_specs=specs,
        )

    # --------------------------------------------------------------- audio
    if fam == "audio":
        def specs(shape: InputShape) -> Dict[str, Any]:
            out = _token_specs(shape, cfg)
            if shape.kind != "decode":
                # stubbed conv-frontend output (mel frames -> embeddings)
                out["frames"] = jax.ShapeDtypeStruct(
                    (shape.global_batch, cfg.encoder_seq, cfg.d_model),
                    cfg.jnp_dtype)
            return out

        def fwd(params, batch):
            return (encdec.forward(params, cfg, batch["tokens"],
                                   batch["frames"], remat=remat),
                    jnp.zeros((), jnp.float32))

        def loss_fn(params, batch):
            if remat:
                hidden = encdec.forward(params, cfg, batch["tokens"],
                                        batch["frames"],
                                        attention_impl=attention_impl,
                                        remat=True, unembed=False)
                return chunked_cross_entropy(hidden, batch["labels"], params,
                                             cfg)
            logits, _ = fwd(params, batch)
            return cross_entropy(logits, batch["labels"])

        return Model(
            cfg=cfg,
            init=functools.partial(encdec.init_params, cfg=cfg),
            forward=fwd,
            loss_fn=loss_fn,
            prefill=lambda params, batch, **kw: encdec.prefill(
                params, cfg, batch["tokens"], batch["frames"], **kw),
            decode_step=lambda params, tok, cache: encdec.decode_step(
                params, cfg, tok, cache),
            decode_chunk=lambda params, toks, n, cache: encdec.decode_chunk(
                params, cfg, toks, n, cache),
            decode_chunk_paged=lambda params, toks, n, cache, kp, vp, pt, **kw:
                encdec.decode_chunk_paged(params, cfg, toks, n, cache,
                                          kp, vp, pt, **kw),
            encode_cross=lambda params, frames: encdec.encode_cross(
                params, cfg, frames),
            init_cache=functools.partial(encdec.init_cache, cfg),
            input_specs=specs,
        )

    raise ValueError(f"unknown family {fam!r}")
