"""Mamba2 (state-space duality / SSD) — arXiv:2405.21060.

Block: in_proj -> (z | x | B | C | dt), causal depthwise conv over (x,B,C),
SSD mixing, gated RMSNorm, out_proj.  The SSD computation uses the chunked
dual form: quadratic attention-like mixing within chunks + a linear state
recurrence across chunks, which is both the paper's algorithm and the
TPU-friendly layout (chunk = MXU tile work, recurrence = small scan).

Decode keeps O(1) state per layer: conv ring buffer + SSM state [H, P, N]
— the reason long_500k runs natively on this family.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L


def init_block(rng, cfg: ModelConfig) -> dict:
    D = cfg.d_model
    din = cfg.d_inner
    N = cfg.ssm_state
    H = cfg.n_ssm_heads
    K = cfg.ssm_conv
    conv_dim = din + 2 * N
    k = jax.random.split(rng, 4)
    s = lambda i, o: (2.0 / (i + o)) ** 0.5
    return {
        "norm": L.init_norm(cfg),
        # order: [z (din) | x (din) | B (N) | C (N) | dt (H)]
        "in_proj": (jax.random.normal(k[0], (D, 2 * din + 2 * N + H))
                    * s(D, din)).astype(cfg.jnp_dtype),
        "conv_w": (jax.random.normal(k[1], (K, conv_dim)) * 0.2).astype(cfg.jnp_dtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.jnp_dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gate_norm": jnp.ones((din,), cfg.jnp_dtype),
        "out_proj": (jax.random.normal(k[2], (din, D))
                     * s(din, D)).astype(cfg.jnp_dtype),
    }


def init_params(rng, cfg: ModelConfig) -> dict:
    ke, kl = jax.random.split(rng)
    layer_rngs = jax.random.split(kl, cfg.n_layers)
    return {
        "embed": L.init_embedding(ke, cfg),
        "layers": jax.vmap(lambda r: init_block(r, cfg))(layer_rngs),
        "final_norm": L.init_norm(cfg),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv.  x: [B,S,C], w: [K,C]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def _segsum(log_a: jnp.ndarray) -> jnp.ndarray:
    """log_a: [..., Q] per-step log decays -> [..., Q, Q] lower-tri cumulative
    log products: out[i,j] = sum_{j < m <= i} log_a[m] (=-inf for j > i)."""
    Q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # sum_(j,i]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
                init_state: jnp.ndarray = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD dual form.

    x:  [B,S,H,P]   inputs per head
    dt: [B,S,H]     softplus'd step sizes (>0)
    A:  [H]         negative decay rates
    Bm: [B,S,N]     input projections (single group, broadcast over H)
    Cm: [B,S,N]     output projections
    Returns y: [B,S,H,P], final_state: [B,H,P,N].
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    S_orig = S
    if S % Q:
        # ragged tail: pad with identity steps (dt=0 -> decay=1, no input)
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q

    xc = x.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)
    # A is a *positive* rate; per-step decay = exp(-dt*A), log decay <= 0.
    log_a = -dtc * A[None, None, None, :]

    # within-chunk (attention-like) term
    Lmat = jnp.exp(_segsum(jnp.transpose(log_a, (0, 1, 3, 2))))  # [B,nc,H,Q,Q]
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)               # [B,nc,Q,Q]
    M = scores[:, :, None] * Lmat                                # [B,nc,H,Q,Q]
    y_intra = jnp.einsum("bchij,bcjh,bcjhp->bcihp", M.astype(x.dtype),
                         dtc.astype(x.dtype), xc)

    # per-chunk summary state: S_c = sum_j decay(j->end) * dt_j x_j B_j^T
    a_cum = jnp.cumsum(log_a, axis=2)                            # [B,nc,Q,H]
    a_total = a_cum[:, :, -1:, :]                                # [B,nc,1,H]
    decay_to_end = jnp.exp(a_total - a_cum)                      # [B,nc,Q,H]
    state_c = jnp.einsum("bcjh,bcjh,bcjhp,bcjn->bchpn",
                         decay_to_end.astype(jnp.float32),
                         dtc, xc.astype(jnp.float32), Bc.astype(jnp.float32))

    # recurrence across chunks
    a_tot = jnp.exp(a_total[:, :, 0, :])                          # [B,nc,H]
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def step(carry, inp):
        a_c, s_c = inp                                            # [B,H], [B,H,P,N]
        new = carry * a_c[:, :, None, None] + s_c
        return new, carry                                         # emit state *entering* the chunk

    final, states_in = jax.lax.scan(
        step, init_state,
        (jnp.moveaxis(a_tot, 1, 0), jnp.moveaxis(state_c, 1, 0)))
    states_in = jnp.moveaxis(states_in, 0, 1)                     # [B,nc,H,P,N]

    # inter-chunk contribution: y_i += C_i . (decay(start->i) * S_in)
    decay_from_start = jnp.exp(a_cum)                             # [B,nc,Q,H]
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp",
                         Cc.astype(jnp.float32), states_in,
                         decay_from_start)
    y = (y_intra.astype(jnp.float32) + y_inter).reshape(Bsz, S, H, P)
    return y[:, :S_orig].astype(x.dtype), final


def _block_inner(x: jnp.ndarray, p: dict, cfg: ModelConfig,
                 conv_state=None, ssm_state=None, single_step: bool = False):
    """Shared by train/prefill (full-seq) and decode (single token).

    Returns (y, new_conv_state, new_ssm_state).
    """
    Bsz, S, D = x.shape
    din, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    P = cfg.ssm_head_dim
    proj = x @ p["in_proj"]
    z, xbc_dt = jnp.split(proj, [din], axis=-1)
    xbcd, dt_raw = jnp.split(xbc_dt, [din + 2 * N], axis=-1)

    K = cfg.ssm_conv
    if single_step:
        # conv ring: conv_state [B, K-1, din+2N] holds previous inputs
        window = jnp.concatenate([conv_state, xbcd], axis=1)       # [B,K,conv]
        conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
        conv_out = jax.nn.silu(conv_out)[:, None, :]
        new_conv_state = window[:, 1:]
    else:
        conv_out = _causal_conv(xbcd, p["conv_w"], p["conv_b"])
        new_conv_state = jnp.pad(
            xbcd, ((0, 0), (max(0, K - 1 - S), 0), (0, 0)))[:, -(K - 1):]

    xs, Bm, Cm = jnp.split(conv_out, [din, din + N], axis=-1)
    xs = xs.reshape(Bsz, -1, H, P)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = jnp.exp(p["A_log"])                                        # positive rates

    if single_step:
        # recurrent update: state' = exp(-dt A) state + dt * x B^T
        st = ssm_state                                             # [B,H,P,N]
        decay = jnp.exp(-dt[:, 0, :] * A[None, :])                 # [B,H]
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0, :],
                         xs[:, 0].astype(jnp.float32),
                         Bm[:, 0].astype(jnp.float32))
        new_state = st * decay[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32),
                       new_state)[:, None]                          # [B,1,H,P]
        y = y.astype(x.dtype)
    else:
        y, new_state = ssd_chunked(xs, dt, A, Bm, Cm, cfg.ssm_chunk,
                                   init_state=ssm_state)
    y = y + xs * p["D_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(Bsz, -1, din)
    y = L.rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"], new_conv_state, new_state


# ----------------------------------------------------------------- training
def forward(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
            attention_impl: str = "xla", remat: bool = False,
            unembed: bool = True) -> jnp.ndarray:
    x = L.embed(tokens, params["embed"]).astype(cfg.jnp_dtype)

    def blk(carry, layer_p):
        h = L.apply_norm(carry, layer_p["norm"], cfg)
        y, _, _ = _block_inner(h, layer_p, cfg)
        return carry + y

    if remat:
        blk = jax.checkpoint(blk)

    def step(carry, layer_p):
        return blk(carry, layer_p), None

    x, _ = jax.lax.scan(step, x, params["layers"])
    x = L.apply_norm(x, params["final_norm"], cfg)
    return L.unembed(x, params["embed"], cfg) if unembed else x


# ------------------------------------------------------------------ serving
def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    din, N = cfg.d_inner, cfg.ssm_state
    H, P, K = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_conv
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, K - 1, din + 2 * N),
                          cfg.jnp_dtype),
        "ssm": jnp.zeros((cfg.n_layers, batch, H, P, N), jnp.float32),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def prefill(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
            attention_impl: str = "xla") -> Tuple[jnp.ndarray, dict]:
    x = L.embed(tokens, params["embed"]).astype(cfg.jnp_dtype)
    S = x.shape[1]

    def step(carry, layer_p):
        h = L.apply_norm(carry, layer_p["norm"], cfg)
        y, conv_st, ssm_st = _block_inner(h, layer_p, cfg)
        return carry + y, (conv_st, ssm_st)

    x, (conv_sts, ssm_sts) = jax.lax.scan(step, x, params["layers"])
    xl = L.apply_norm(x[:, -1:], params["final_norm"], cfg)
    logits = L.unembed(xl[:, 0], params["embed"], cfg)
    return logits, {"conv": conv_sts, "ssm": ssm_sts,
                    "pos": jnp.full((tokens.shape[0],), S, jnp.int32)}


def decode_step(params: dict, cfg: ModelConfig, token: jnp.ndarray,
                cache: dict) -> Tuple[jnp.ndarray, dict]:
    x = L.embed(token[:, None], params["embed"]).astype(cfg.jnp_dtype)

    def step(carry, xs):
        layer_p, conv_st, ssm_st = xs
        h = L.apply_norm(carry, layer_p["norm"], cfg)
        y, conv_st, ssm_st = _block_inner(h, layer_p, cfg, conv_st, ssm_st,
                                          single_step=True)
        return carry + y, (conv_st, ssm_st)

    x, (conv_sts, ssm_sts) = jax.lax.scan(
        step, x, (params["layers"], cache["conv"], cache["ssm"]))
    x = L.apply_norm(x, params["final_norm"], cfg)
    logits = L.unembed(x[:, 0], params["embed"], cfg)
    return logits, {"conv": conv_sts, "ssm": ssm_sts, "pos": cache["pos"] + 1}


def decode_chunk(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
                 valid_len: jnp.ndarray, cache: dict):
    """T tokens ([B,T]) in one compiled forward: an in-jit scan of masked
    single steps.

    The recurrence is inherently sequential, so unlike the attention
    families there is no quadratic fusion to exploit — the win is purely
    dispatch: one jitted call (and one host round-trip) per engine step
    instead of ``prefill_chunk`` of them.  Token ``t`` advances sequence
    ``b`` iff ``t < valid_len[b]``; a masked-out step leaves that row's
    state (and position) untouched, exactly like the engine's masked
    fallback.  Returns (logits [B,T,V], cache)."""
    T = tokens.shape[1]

    def outer(cache, xs):
        tok, t = xs
        logits, new = decode_step(params, cfg, tok, cache)
        mask = t < valid_len                                   # [B]
        out = {}
        for key in new:
            ax = 0 if key == "pos" else 1       # batch axis per leaf
            shp = [1] * new[key].ndim
            shp[ax] = new[key].shape[ax]
            out[key] = jnp.where(mask.reshape(shp), new[key], cache[key])
        return out, logits

    cache, logits = jax.lax.scan(
        outer, cache, (jnp.moveaxis(tokens, 0, 1), jnp.arange(T)))
    return jnp.moveaxis(logits, 0, 1), cache
