"""Financial Analyst workflow — reproduces paper §6 **Fig. 9a** (financial-
analyst serving benchmark; also the Fig. 6 high-priority-session case
study).  Run it with:

    PYTHONPATH=src python -m benchmarks.fig9_financial       # figure numbers
    PYTHONPATH=src python examples/financial_analyst.py      # single workflow

An analyst agent fans out to stock-analysis / bond-market / market-research
/ news-search agents, then summarizes on a *shared, session-stateful* LLM
engine.  Users issue follow-up queries in the same session (human-in-the-
loop), so every framework must route follow-ups to the instance holding the
session's K,V cache — except NALAR, whose K,V control lets it migrate the
session away from head-of-line blocking (the Fig. 9a mechanism).

Latency model: FinQA-style numeric-reasoning queries — prefill-heavy with
heavy-tailed generation lengths (a few requests carry very large contexts),
which is what creates the blocking the HoL policy mitigates.
"""

from __future__ import annotations

import random
from typing import Dict

import math

from ..core import (AgentSpec, Directives, FixedLatency, LLMLatency,
                    LognormalLatency, NalarRuntime, emulated)
from ..core.executor import LatencyModel
from ..core.runtime import current_runtime
from .baselines import SystemConfig


class KVCacheLLMLatency(LatencyModel):
    """LLM cost model with session K,V-cache reuse (§4.3.2).

    Prefill pays only for tokens beyond the session's cached prefix *on the
    executing instance*; the cache registry (NALAR's LMCache-hook layer)
    tracks residency, so a migrated session keeps its discount while a
    session bounced to a cold instance rebuilds from scratch — exactly the
    stickiness/migration tension the paper's Fig. 9a exercises.
    """

    def __init__(self, registry, prefill_tps: float, decode_tps: float,
                 base: float, jitter_sigma: float = 0.1) -> None:
        self.registry = registry
        self.prefill_tps = prefill_tps
        self.decode_tps = decode_tps
        self.base = base
        self.jitter_sigma = jitter_sigma

    def service_time(self, hints, rng) -> float:
        total = 0.0
        for h in hints:
            sid, inst = h.get("session_id", ""), h.get("instance", "")
            cached = self.registry.cached_tokens(sid, inst) if sid else 0
            tin = max(0, h.get("in_tokens", 512) - cached)
            tout = h.get("out_tokens", 128)
            t = self.base + tin / self.prefill_tps + tout / self.decode_tps
            if self.jitter_sigma:
                t *= math.exp(rng.gauss(0.0, self.jitter_sigma))
            total += t
            if sid:
                self.registry.touch(sid, inst,
                                    h.get("in_tokens", 512) + tout,
                                    h.get("now", 0.0))
        return total


def build_runtime(sys_cfg: SystemConfig, *, n_llm: int = 8,
                  seed: int = 0) -> NalarRuntime:
    rt = NalarRuntime(
        simulate=True,
        nodes={f"n{i}": {"GPU": 4, "CPU": 32} for i in range(2)},
        policy=sys_cfg.policy,
        control_interval=sys_cfg.control_interval,
        seed=seed)
    rt.router.mode = sys_cfg.router_mode

    # shared LLM engine: session-sticky for baselines, migratable for NALAR;
    # both get the K,V-cache prefill discount at the instance holding the
    # session's cache
    rt.register_agent(AgentSpec(
        name="llm",
        methods={"generate": emulated(
            KVCacheLLMLatency(rt.kv_registry, prefill_tps=12000,
                              decode_tps=120, base=0.08, jitter_sigma=0.15),
            lambda prompt, **kw: f"summary({str(prompt)[:24]})")},
        directives=Directives(
            stateful=sys_cfg.sticky_sessions,
            uses_managed_state=not sys_cfg.sticky_sessions,
            max_instances=n_llm, resources={"GPU": 1}),
    ), instances=n_llm)

    for tool, med in (("stock", 0.35), ("bond", 0.3), ("research", 0.5),
                      ("news", 0.6)):
        rt.register_agent(AgentSpec(
            name=tool,
            methods={"query": emulated(LognormalLatency(med, 0.4),
                                       lambda q, _t=tool: f"{_t}-data")},
            directives=Directives(max_instances=4, resources={"CPU": 2}),
        ), instances=2)
    return rt


def analyst_driver(query: str, in_tokens: int, out_tokens: int) -> str:
    rt = current_runtime()
    sub = [rt.stub(t).query(query, _hint={"graph_depth": 1,
                                          "est_service": 0.4})
           for t in ("stock", "bond", "research", "news")]
    data = [f.value() for f in sub]
    # est_service: the token counts make LLM service time predictable —
    # exactly the signal SRTF-style policies consume (§6.2)
    f = rt.stub("llm").generate(
        (query, data), _hint={"in_tokens": in_tokens,
                              "out_tokens": out_tokens,
                              "graph_depth": 2,
                              "est_service": 0.08 + in_tokens / 12000
                              + out_tokens / 120})
    return f.value()


def run_financial(sys_cfg: SystemConfig, *, rps: float = 1.0,
                  n_sessions: int = 25, followups: int = 5,
                  seed: int = 0) -> Dict[str, float]:
    """Poisson sessions, each issuing `followups+1` requests with think
    time.  ~10% of requests are heavy (huge context) — the HoL source."""
    rt = build_runtime(sys_cfg, seed=seed)
    rng = random.Random(seed)
    rt.start()

    def request_driver(sid: str, k: int) -> None:
        rng_local = random.Random(f"{sid}:{k}")
        heavy = rng_local.random() < 0.08
        in_tok = 24000 if heavy else rng_local.randint(600, 2400)
        out_tok = 1600 if heavy else rng_local.randint(80, 300)
        analyst_driver(f"q-{sid}-{k}", in_tok, out_tok)

    def submit_chain(session: str, k: int, delay: float) -> None:
        """Each follow-up is its own request (per-request latency metrics),
        issued after user think time once the previous one returns."""
        def done(_out, _err, s=session, kk=k):
            if kk < followups:
                think = random.Random(f"{s}:{kk}:t").uniform(0.5, 3.0)
                rt.kernel.schedule(think, lambda: rt.submit_request(
                    request_driver, s, kk + 1, session=s))

        rt.submit_request(request_driver, session, k, session=session,
                          delay=delay, on_done=done)

    t = 0.0
    for _ in range(n_sessions):
        t += rng.expovariate(rps)
        session = rt.sessions.new_session(priority=0.0).session_id
        submit_chain(session, 0, t)
    rt.run()
    out = rt.telemetry.summary()
    out["system"] = sys_cfg.name
    out["rps"] = rps
    return out
