"""Baseline agent-framework emulations (paper §6 comparison classes).

Each baseline is NALAR with capabilities *removed*, matching the paper's
characterization of the competing systems (§2.3):

  crewai   — specification-focused: no resource management, no global
             control, whole-workflow replication, FCFS, sticky sessions.
  autogen  — event-driven messaging: least-queue at submission, no
             periodic control, no migration, sticky sessions.
  ayo      — static graph + Ray-style immutable placement: parallel
             execution allowed, but a future's placement never changes and
             capacity is fixed.
  nalar    — full system: the three §6.1 default policies (load-balance
             routing, HoL migration, resource reassignment) + migratable
             session state (K,V control).

All four run the *same* workload code on the same simulated cluster; only
the control capabilities differ, which is the comparison the paper makes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from ..core import (HoLMitigationPolicy, LoadBalancePolicy, Policy,
                    PolicyChain, ResourceReassignmentPolicy)


class NullPolicy(Policy):
    name = "null"

    def step(self, view, act) -> None:
        return


@dataclass
class SystemConfig:
    name: str
    policy: Policy
    # sessions may migrate with their state (NALAR's K,V control, §4.3.2);
    # baselines route a session to its original instance forever
    sticky_sessions: bool
    # the runtime may kill/provision instances across agent types
    dynamic_resources: bool
    # default-routing capability (see core.runtime.Router.mode)
    router_mode: str = "least_eta"
    control_interval: float = 0.25


def system_config(name: str) -> SystemConfig:
    if name == "nalar":
        # native least-ETA routing IS the paper's default policy 1
        # (load-balance via routing); the chain adds HoL migration and
        # resource reassignment (§6.1's three defaults).
        return SystemConfig(
            name="nalar",
            policy=PolicyChain(HoLMitigationPolicy(wait_threshold=1.0),
                               ResourceReassignmentPolicy(hot=3.0, cold=0.5,
                                                          cooldown=4.0)),
            sticky_sessions=False,
            dynamic_resources=True,
            router_mode="least_eta")
    if name == "autogen":
        # event-driven messaging: queue-length routing at send time, no
        # periodic control, no migration
        return SystemConfig(name="autogen", policy=NullPolicy(),
                            sticky_sessions=True, dynamic_resources=False,
                            router_mode="least_qlen")
    if name == "crewai":
        # thin specification layer: whole-workflow replication ~ round-robin
        return SystemConfig(name="crewai", policy=NullPolicy(),
                            sticky_sessions=True, dynamic_resources=False,
                            router_mode="round_robin")
    if name == "ayo":
        # static graph + Ray-style event-driven scheduling: least-queue at
        # future creation, placement immutable afterwards
        return SystemConfig(name="ayo", policy=NullPolicy(),
                            sticky_sessions=True, dynamic_resources=False,
                            router_mode="least_qlen")
    raise KeyError(name)


BASELINES = ["ayo", "crewai", "autogen"]
