"""Router-based workflow — reproduces paper §6 **Fig. 9b** (router serving
benchmark).  Run it with:

    PYTHONPATH=src python -m benchmarks.fig9_router          # figure numbers
    PYTHONPATH=src python examples/router_workflow.py        # single workflow
    PYTHONPATH=src python examples/real_engine_workflow.py   # real engines
    PYTHONPATH=src python examples/engine_pool_workflow.py   # replica pool

A lightweight router classifies each query and forwards it to either a chat
workflow or a coding agent.  Per the Azure LLM traces the paper uses, the
branch mix shifts over time (imbalance can exceed 90%), so a static split
of engines starves one branch while the other idles.  NALAR's resource-
reassignment policy moves GPU capacity between branches; baselines can't,
and their overloaded branch's latency blows up (the paper reports OOM
failures at 70-80 RPS — here the failure mode is unbounded queueing, and we
report a timeout rate).

Three execution modes: :func:`build_runtime` (emulated branch LLMs, virtual
time — the paper's §6.3 methodology), :func:`build_engine_runtime` (branch
LLMs on single real ``InferenceEngine`` instances, wall-clock time), and
:func:`build_pool_runtime` (one LLM agent type backed by an ``EnginePool``
of N real replicas, where global-controller routing/migration actions
resolve to concrete replicas — see ``benchmarks/pool_routing.py``).
"""

from __future__ import annotations

import random
from typing import Dict

from ..core import (AgentSpec, Directives, FixedLatency, LLMLatency,
                    NalarRuntime, emulated)
from ..core.runtime import current_runtime
from .baselines import SystemConfig


def build_runtime(sys_cfg: SystemConfig, *, n_gpus: int = 8,
                  seed: int = 0) -> NalarRuntime:
    rt = NalarRuntime(
        simulate=True,
        nodes={f"n{i}": {"GPU": 4, "CPU": 32} for i in range(n_gpus // 4)},
        policy=sys_cfg.policy,
        control_interval=sys_cfg.control_interval,
        seed=seed)
    rt.router.mode = sys_cfg.router_mode
    rt.register_agent(AgentSpec(
        name="router",
        methods={"classify": emulated(
            FixedLatency(0.01), lambda q: "code" if "code" in q else "chat")},
        directives=Directives(max_instances=2, resources={"CPU": 1}),
    ), instances=2)
    rt.register_agent(AgentSpec(
        name="chat_llm",
        methods={"generate": emulated(
            LLMLatency(prefill_tps=40000, decode_tps=1800, base=0.015,
                       jitter_sigma=0.1),
            lambda q, **kw: f"chat({q[:16]})")},
        directives=Directives(batchable=True, max_batch=8,
                              max_instances=n_gpus - 1,
                              min_instances=1, resources={"GPU": 1}),
    ), instances=n_gpus // 2)
    rt.register_agent(AgentSpec(
        name="code_llm",
        methods={"generate": emulated(
            LLMLatency(prefill_tps=30000, decode_tps=1500, base=0.02,
                       jitter_sigma=0.1),
            lambda q, **kw: f"code({q[:16]})")},
        directives=Directives(batchable=True, max_batch=8,
                              max_instances=n_gpus - 1,
                              min_instances=1, resources={"GPU": 1}),
    ), instances=n_gpus - n_gpus // 2)
    return rt


def build_engine_runtime(*, arch: str = "qwen3_0_6b", max_batch: int = 4,
                         max_seq: int = 128, max_new_tokens: int = 8,
                         seed: int = 0) -> NalarRuntime:
    """Real-execution variant of :func:`build_runtime`.

    Same workflow topology — a cheap router tool classifies, then a branch
    LLM generates — but the two branch agents execute on actual
    ``repro.serving.InferenceEngine`` instances (reduced model, CPU JAX)
    through the ``EngineMethod`` backend instead of ``LLMLatency`` emulation.
    Requests run in wall-clock time (``simulate=False``); repeated calls in
    one session reuse prefix KV on the engine that holds the session cache.
    """
    import jax

    from ..configs import get_smoke_config
    from ..models import build_model
    from ..serving import InferenceEngine, SamplingParams
    from ..serving.bridge import register_engine_agent

    rt = NalarRuntime(simulate=False,
                      nodes={"n0": {"GPU": 2, "CPU": 8}}, seed=seed)
    rt.register_agent(AgentSpec(
        name="router",
        methods={"classify": emulated(
            FixedLatency(0.001), lambda q: "code" if "code" in q else "chat")},
        directives=Directives(max_instances=2, resources={"CPU": 1}),
    ), instances=1)
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    for name in ("chat_llm", "code_llm"):
        engine = InferenceEngine(model, params, max_batch=max_batch,
                                 max_seq=max_seq)
        register_engine_agent(
            rt, name, engine,
            sampling=SamplingParams(max_new_tokens=max_new_tokens),
            resources={"GPU": 1})
    return rt


def build_pool_runtime(*, replicas: int = 3, arch: str = "qwen3_0_6b",
                       max_batch: int = 4, max_seq: int = 128,
                       max_new_tokens: int = 6, router_mode: str = "least_eta",
                       kv_affinity: bool = True, policy=None,
                       control_interval: float = 0.25,
                       heterogeneous: bool = False,
                       prefill_chunk: int = 8, max_queue: int = 0,
                       max_retries: int = 0, retry_backoff: float = 0.05,
                       prefix_sharing: bool = True,
                       tiers=None, tier_archs=None,
                       draft_layers: int = 0, spec_k: int = 3,
                       decode=None, seed: int = 0) -> NalarRuntime:
    """One ``llm`` agent type backed by an ``EnginePool`` of real replicas.

    This is the pooled topology of the migration/routing benchmarks: N
    ``InferenceEngine`` replicas (sharing reduced-model weights, each with
    its own KV pool and pump thread) are the N instances of one agent type,
    so Router modes (``round_robin`` / ``least_eta``), ``route`` pins from a
    global policy, and ``migrate`` actions all resolve to concrete engines.
    ``kv_affinity=False`` disables the Router's native cache-locality rule —
    the baseline configuration that sprays a session's turns across replicas
    and pays a full-context prefill per turn.  ``heterogeneous=True`` halves
    the last replica's batch width (a deliberately weaker engine) to show
    policies handling non-uniform capacity.

    Data-plane knobs (the sustained-RPS benchmark sweeps these):
    ``prefill_chunk`` — prompt tokens consumed per slot per engine step
    (0 = legacy monolithic bucket prefill); ``max_queue`` — per-replica
    admission bound (0 = unbounded queueing, the baseline collapse mode);
    ``max_retries``/``retry_backoff`` — retry-ladder budget so admission
    rejections back off and reroute instead of failing the request;
    ``prefix_sharing`` — cross-session KV prefix index with copy-on-write
    pages (``False`` = the baseline that re-prefills identical system
    prompts per session).

    Model-tier knobs (the spec-decode benchmark's routing row): ``tiers``
    is a per-replica tier label list (``len == replicas``; ``None`` = an
    untiered pool) and ``tier_archs`` maps a tier label to the smoke arch
    its replicas load (labels absent from the map fall back to ``arch``).
    Pair with a ``TierRoutePolicy`` and ``model_tier`` work hints (see
    :func:`tiered_driver`) for just-in-time routing of cheap steps to
    small-tier replicas.  ``draft_layers > 0`` arms every replica whose
    model has more layers than that with a layer-truncated self-draft
    (speculative decoding, ``spec_k`` proposals per round).
    """
    import jax

    from ..configs import get_smoke_config
    from ..models import build_model
    from ..serving import InferenceEngine, SamplingParams
    from ..serving.pool import register_engine_pool

    rt = NalarRuntime(simulate=False,
                      nodes={"n0": {"GPU": replicas, "CPU": 8}},
                      policy=policy, control_interval=control_interval,
                      seed=seed)
    rt.router.mode = router_mode
    rt.router.kv_affinity = kv_affinity
    built = {}

    def _built(a):
        if a not in built:
            c = get_smoke_config(a)
            m = build_model(c)
            built[a] = (m, m.init(jax.random.PRNGKey(seed)))
        return built[a]

    engines = []
    for i in range(replicas):
        mb = max_batch
        if heterogeneous and i == replicas - 1:
            mb = max(1, max_batch // 2)
        tier = tiers[i] if tiers else ""
        model, params = _built((tier_archs or {}).get(tier, arch))
        kw = {}
        if 0 < draft_layers < model.cfg.n_layers:
            from ..serving.speculative import truncated_draft
            dm, dp = truncated_draft(model, params, draft_layers)
            kw = dict(draft_model=dm, draft_params=dp, spec_k=spec_k)
        engines.append(InferenceEngine(model, params, max_batch=mb,
                                       max_seq=max_seq,
                                       prefill_chunk=prefill_chunk,
                                       max_queue=max_queue,
                                       prefix_sharing=prefix_sharing,
                                       tier=tier, **kw))
    register_engine_pool(
        rt, "llm", engines,
        sampling=SamplingParams(max_new_tokens=max_new_tokens),
        decode=decode, resources={"GPU": 1})
    if max_retries:
        rt.apply_directives("llm", {"max_retries": max_retries,
                                    "retry_backoff": retry_backoff})
    return rt


def classify_tokens(out, k: int = 10) -> str:
    """Branch from the first ``k`` output tokens only.

    Accepts either a partial token list (the streamed path hands the
    classifier ``Future.partial()`` — a plain prefix of token ids) or the
    full engine result (the completion path hands the resolved value, which
    carries ``.tokens``).  Depending only on the first ``k`` tokens is what
    makes the two paths decide identically: greedy decode regenerates the
    same prefix, so a router that looked past position ``k`` would be the
    only source of divergence.
    """
    toks = list(getattr(out, "tokens", out))[:k]
    return "code" if sum(int(t) for t in toks) % 2 else "chat"


def add_stream_classifier(rt: NalarRuntime, *, latency: float = 0.02,
                          k: int = 10) -> None:
    """Register the pipelining classifier on a pool runtime.

    An emulated CPU agent (works on real-time kernels, same as
    :func:`build_engine_runtime`'s router) whose one method classifies from
    the first ``k`` tokens — the downstream consumer of the streaming data
    plane's ``stream_min_tokens`` hint.
    """
    rt.register_agent(AgentSpec(
        name="classifier",
        methods={"classify": emulated(
            FixedLatency(latency), lambda out: classify_tokens(out, k))},
        directives=Directives(max_instances=2, resources={"CPU": 1}),
    ), instances=1)


def streamed_routed_driver(query: str, out_tokens: int = 24,
                           stream_min: int = 10,
                           refine_tokens: int = 6) -> Dict[str, object]:
    """Route on partial output: the classifier starts after ``stream_min``
    streamed tokens, so the branch call overlaps the tail of the upstream
    generation instead of queueing behind it.

    The branch call detaches from the driver session (``session_id: ""``):
    the per-session ordering that keeps multi-turn transcripts consistent
    would otherwise park it behind the still-streaming draft — the very
    call it is pipelining past.
    """
    rt = current_runtime()
    draft = rt.stub("llm").generate(query, _hint={"out_tokens": out_tokens})
    branch = rt.stub("classifier").classify(
        draft, _hint={"stream_min_tokens": stream_min}).value()
    refine = rt.stub("llm").generate(
        f"{branch} follow-up: {query}",
        _hint={"out_tokens": refine_tokens, "session_id": ""})
    d = draft.value()
    r = refine.value()
    return {"branch": branch, "draft": [int(t) for t in d.tokens],
            "refine": [int(t) for t in r.tokens]}


def completion_routed_driver(query: str, out_tokens: int = 24,
                             refine_tokens: int = 6) -> Dict[str, object]:
    """Baseline twin of :func:`streamed_routed_driver`: identical workflow,
    no streaming hints — the classifier waits for the draft to resolve
    fully, and the branch call starts only after.  Greedy decode makes the
    two drivers' outputs byte-identical; only the overlap differs."""
    rt = current_runtime()
    draft = rt.stub("llm").generate(query, _hint={"out_tokens": out_tokens})
    branch = rt.stub("classifier").classify(draft).value()
    refine = rt.stub("llm").generate(
        f"{branch} follow-up: {query}",
        _hint={"out_tokens": refine_tokens, "session_id": ""})
    d = draft.value()
    r = refine.value()
    return {"branch": branch, "draft": [int(t) for t in d.tokens],
            "refine": [int(t) for t in r.tokens]}


def routed_driver(query: str, in_tokens: int, out_tokens: int) -> str:
    rt = current_runtime()
    branch = rt.stub("router").classify(query).value()
    agent = "code_llm" if branch == "code" else "chat_llm"
    return rt.stub(agent).generate(
        query, _hint={"in_tokens": in_tokens, "out_tokens": out_tokens}).value()


def tiered_driver(query: str, tier: str, out_tokens: int) -> str:
    """Pool driver that stamps the just-in-time ``model_tier`` hint: the
    caller (an agent program that knows a classify/extract step is cheap)
    names the tier it wants, and the Router's tier table — installed by
    ``TierRoutePolicy`` — steers the call there, shed watermark permitting."""
    rt = current_runtime()
    return rt.stub("llm").generate(
        query, _hint={"model_tier": tier, "out_tokens": out_tokens}).value()


def run_router(sys_cfg: SystemConfig, *, rps: float = 80.0,
               duration: float = 24.0, seed: int = 0,
               timeout_s: float = 60.0) -> Dict[str, float]:
    """Two phases: chat-heavy then code-heavy (the trace's imbalance)."""
    rt = build_runtime(sys_cfg, seed=seed)
    rng = random.Random(seed)
    rt.start()
    t = 0.0
    i = 0
    while t < duration:
        t += rng.expovariate(rps)
        phase2 = t > duration / 2
        is_code = rng.random() < (0.9 if phase2 else 0.1)
        q = f"{'code' if is_code else 'chat'} query {i}"
        in_tok = rng.randint(400, 1600)
        out_tok = rng.randint(150, 450) if is_code else rng.randint(40, 160)
        rt.submit_request(routed_driver, q, in_tok, out_tok, delay=t,
                          deadline_s=timeout_s)
        i += 1
    rt.run(max_time=duration + timeout_s)
    out = rt.telemetry.summary()
    # real per-request deadline outcomes from telemetry (each request was
    # submitted with deadline_s=timeout_s), not "unfinished == timed out":
    # a request that failed DeadlineExceeded or completed past its budget
    # is a timeout even though it finished, and an unfinished request at
    # the horizon is counted separately as such.
    dl = rt.telemetry.deadline_outcomes()
    out["timeouts"] = dl["deadline_missed"] + dl["unfinished"]
    out["deadline_missed"] = dl["deadline_missed"]
    out["unfinished"] = dl["unfinished"]
    out["timeout_rate"] = out["timeouts"] / max(dl["requests"], 1)
    out["system"] = sys_cfg.name
    out["rps"] = rps
    return out
