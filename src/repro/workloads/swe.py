"""Software-engineering workflow — reproduces paper §6 **Fig. 9c** (and the
Fig. 1 motivating example).  Run it with:

    PYTHONPATH=src python -m benchmarks.fig9_swe             # figure numbers
    PYTHONPATH=src python examples/software_engineering.py   # single workflow

MetaGPT-style recursive workflow on SWE-bench-like tasks: a program manager
decomposes the request; developer agents implement subtasks consulting a
documentation store and web search; testing agents run the suites; failing
subtasks REQUEUE at the developer stage (the recursion), which is what
creates the paper's 2.1x load imbalance and the head-of-line pressure that
NALAR's dynamic reallocation + (§6.2) LPT-retry prioritization resolve —
up to 2.9x end-to-end speedup.

Each agent is paired with its own LLM (per the paper), so developer
capacity and tester capacity are separate GPU pools.
"""

from __future__ import annotations

import random
from typing import Dict

from ..core import (AgentSpec, Directives, FixedLatency, LLMLatency,
                    LognormalLatency, NalarRuntime, emulated)
from ..core.runtime import current_runtime
from ..core.session import get_current_deadline
from .baselines import SystemConfig


def build_runtime(sys_cfg: SystemConfig, *, seed: int = 0,
                  fail_prob: float = 0.35) -> NalarRuntime:
    rt = NalarRuntime(
        simulate=True,
        nodes={f"n{i}": {"GPU": 4, "CPU": 32} for i in range(3)},
        policy=sys_cfg.policy,
        control_interval=sys_cfg.control_interval,
        seed=seed)
    rt.router.mode = sys_cfg.router_mode
    fail_rng = random.Random(seed + 1)

    rt.register_agent(AgentSpec(
        name="pm",
        methods={"plan": emulated(
            LLMLatency(prefill_tps=10000, decode_tps=60, base=0.1,
                       jitter_sigma=0.1),
            lambda req, n, **kw: [f"{req}::sub{i}" for i in range(n)])},
        directives=Directives(max_instances=2, resources={"GPU": 1}),
    ), instances=1)

    rt.register_agent(AgentSpec(
        name="docs",
        methods={"get": emulated(LognormalLatency(0.15, 0.3),
                                 lambda t: f"docs[{t[-6:]}]")},
        directives=Directives(max_instances=4, resources={"CPU": 2}),
    ), instances=2)

    rt.register_agent(AgentSpec(
        name="dev_llm",
        methods={"generate": emulated(
            LLMLatency(prefill_tps=9000, decode_tps=45, base=0.1,
                       jitter_sigma=0.2),
            lambda t, **kw: f"code({t[-8:]})")},
        directives=Directives(batchable=True, max_batch=4, max_instances=8,
                              min_instances=1, resources={"GPU": 1}),
    ), instances=4)

    rt.register_agent(AgentSpec(
        name="tester",
        methods={"run_tests": emulated(
            LognormalLatency(0.8, 0.5),
            lambda code, **kw: "Fail" if fail_rng.random() < fail_prob
            else "Pass")},
        directives=Directives(max_instances=8, min_instances=1,
                              resources={"GPU": 1}),
    ), instances=4)
    return rt


def swe_driver(request: str, n_subtasks: int, max_retries: int = 4) -> int:
    """Returns total attempts (>=n_subtasks)."""
    rt = current_runtime()
    subtasks = rt.stub("pm").plan(request, n_subtasks,
                                  _hint={"out_tokens": 120}).value()
    attempts = 0

    def implement(task: str, retry: int):
        docs = rt.stub("docs").get(task)
        code = rt.stub("dev_llm").generate(
            docs, _hint={"in_tokens": 2500 + 600 * retry, "out_tokens": 350,
                         "retry": retry, "graph_depth": 1,
                         "est_service": 8.0})
        return rt.stub("tester").run_tests(
            code, _hint={"retry": retry, "graph_depth": 2,
                         "est_service": 1.0})

    futures = {i: implement(t, 0) for i, t in enumerate(subtasks)}
    retries = {i: 0 for i in futures}
    done = set()
    while len(done) < len(subtasks):
        progressed = False
        for i, f in list(futures.items()):
            if i in done or not f.available:
                continue
            attempts += 1
            progressed = True
            if f.value() == "Pass" or retries[i] >= max_retries:
                done.add(i)
            else:
                retries[i] += 1
                futures[i] = implement(subtasks[i], retries[i])
        if not progressed:
            # block on one unfinished stage — within the request's remaining
            # deadline budget if it was submitted with one (the 600 s cap is
            # only the no-deadline fallback, not a hard-coded wait)
            deadline = get_current_deadline()
            budget = 600.0
            if deadline >= 0:
                budget = max(0.0, min(budget, deadline - rt.kernel.now()))
            for i, f in futures.items():
                if i not in done:
                    f.value(timeout=budget)
                    break
    return attempts


def run_swe(sys_cfg: SystemConfig, *, n_requests: int = 12,
            rps: float = 0.5, n_subtasks: int = 4, seed: int = 0) -> Dict[str, float]:
    rt = build_runtime(sys_cfg, seed=seed)
    rng = random.Random(seed)
    rt.start()
    t = 0.0
    for i in range(n_requests):
        t += rng.expovariate(rps)
        rt.submit_request(swe_driver, f"task-{i}", n_subtasks, delay=t)
    end = rt.run()
    out = rt.telemetry.summary()
    out["makespan"] = end
    out["system"] = sys_cfg.name
    return out
