from .baselines import BASELINES, SystemConfig, system_config
from .financial import run_financial
from .router import run_router
from .swe import run_swe

__all__ = ["BASELINES", "SystemConfig", "run_financial", "run_router",
           "run_swe", "system_config"]
