"""OpenAI-compatible streaming HTTP front end over the NALAR engine pool.

    PYTHONPATH=src python -m repro.launch.serve --port 8080 --replicas 2
    curl -N localhost:8080/v1/chat/completions -d '{
        "model": "nalar-llm", "stream": true,
        "messages": [{"role": "user", "content": "hello there"}]}'

The launcher builds the pooled runtime (``build_pool_runtime``: N real
``InferenceEngine`` replicas behind one ``llm`` agent type) and serves
``/v1/chat/completions`` on stdlib ``http.server`` threads — no new
dependency.  ``"stream": true`` answers with Server-Sent Events riding the
token-streaming data plane: the engine step loop emits per-slot chunks,
the bridge appends them to the request's future, and the handler forwards
each increment the moment ``Future.wait_streamed`` wakes.  The delta loop
tracks how many tokens it has already sent, so a mid-stream retry (which
truncates the chunk log back to the attempt boundary and re-streams) never
duplicates or reorders client-visible text — the concatenated deltas are
byte-identical to the non-streaming response for the same prompt.

Wire format follows SNIPPETS §3's event-envelope conventions: every SSE
frame carries an ``id:`` line plus an in-payload monotonically increasing
``seq`` (client-side idempotency / resume marker), a typed ``object``
field, and is schema-validated at publish time — malformed events fail the
producer, not the consumer.  JSON over binary: events are tiny and
debuggability wins.

Tokens are hash ids (``hash_tokenize``), not real BPE, so "text" on the
wire is the canonical decimal spelling of each token id — deterministic,
reversible, honest about the reproduction's text model.

``--selftest`` starts the server on an ephemeral port, drives it with a
real network client (urllib over TCP), and asserts incremental delivery
plus streamed == non-streamed byte equality; CI runs it as the
streaming-smoke job.
"""

from __future__ import annotations

import argparse
import json
import socket
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, List, Optional

from ..workloads.router import build_pool_runtime

#: required envelope keys, checked at publish time (SNIPPETS §3: validate
#: where events are produced so schema drift cannot reach consumers)
_ENVELOPE_KEYS = ("id", "object", "created", "model", "seq", "choices")


def detokenize(tokens: List[int]) -> str:
    """Token ids -> wire text (space-joined decimal ids; see module doc)."""
    return " ".join(str(int(t)) for t in tokens)


class OpenAIFrontend:
    """The serving surface: owns the pooled runtime and turns HTTP chat
    completions into NALAR driver requests against the ``llm`` stub."""

    def __init__(self, runtime, agent: str = "llm",
                 model_name: str = "nalar-llm",
                 default_max_tokens: int = 32,
                 request_timeout: float = 120.0) -> None:
        self.rt = runtime
        self.agent = agent
        self.model_name = model_name
        self.default_max_tokens = default_max_tokens
        self.request_timeout = request_timeout

    # ------------------------------------------------------------ submission
    def launch(self, prompt: str, *, max_tokens: int,
               temperature: float = 0.0,
               session: Optional[str] = None):
        """Submit one generation as a NALAR request; returns the Future.

        The driver thread blocks on the future (keeping request telemetry
        honest: the request record closes when generation does) while the
        HTTP handler thread consumes the same future's stream."""
        box: Dict[str, Any] = {}
        ready = threading.Event()

        def driver() -> None:
            fut = self.rt.stub(self.agent).generate(
                prompt, _hint={"out_tokens": max_tokens,
                               "temperature": temperature})
            box["fut"] = fut
            ready.set()
            try:
                fut.value(timeout=self.request_timeout)
            except BaseException:   # noqa: BLE001 — handler surfaces errors
                pass

        self.rt.submit_request(driver, session=session)
        if not ready.wait(timeout=30.0):
            raise RuntimeError("driver thread failed to start")
        return box["fut"]

    def serve(self, host: str = "127.0.0.1", port: int = 8080):
        server = _Server((host, port), _Handler)
        server.frontend = self
        return server


class _Server(ThreadingHTTPServer):
    daemon_threads = True
    frontend: OpenAIFrontend


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"

    # silence the default per-request stderr log line
    def log_message(self, fmt: str, *args: Any) -> None:  # noqa: A003
        pass

    # -------------------------------------------------------------- plumbing
    def _json(self, code: int, payload: Dict[str, Any]) -> None:
        body = json.dumps(payload).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _publish_event(self, seq: int, payload: Dict[str, Any]) -> None:
        """One SSE frame, envelope-validated at publish time."""
        missing = [k for k in _ENVELOPE_KEYS if k not in payload]
        if missing:
            raise ValueError(f"malformed stream event, missing {missing}")
        self.wfile.write(
            f"id: {seq}\ndata: {json.dumps(payload)}\n\n".encode())
        self.wfile.flush()

    def _envelope(self, fut, seq: int, **fields: Any) -> Dict[str, Any]:
        fe = self.server.frontend
        return {"id": f"chatcmpl-{fut.fid}", "created": int(time.time()),
                "model": fe.model_name, "seq": seq, **fields}

    # -------------------------------------------------------------- endpoints
    def do_GET(self) -> None:  # noqa: N802 — BaseHTTPRequestHandler contract
        fe = self.server.frontend
        if self.path == "/healthz":
            self._json(200, {"status": "ok"})
        elif self.path == "/v1/models":
            self._json(200, {"object": "list", "data": [
                {"id": fe.model_name, "object": "model",
                 "owned_by": "nalar"}]})
        else:
            self._json(404, {"error": {"message": f"no route {self.path}"}})

    def do_POST(self) -> None:  # noqa: N802 — BaseHTTPRequestHandler contract
        if self.path != "/v1/chat/completions":
            self._json(404, {"error": {"message": f"no route {self.path}"}})
            return
        try:
            n = int(self.headers.get("Content-Length", 0))
            body = json.loads(self.rfile.read(n) or b"{}")
            messages = body.get("messages") or []
            prompt = "\n".join(
                f"{m.get('role', 'user')}: {m.get('content', '')}"
                for m in messages)
            if not prompt:
                raise ValueError("messages must be a non-empty list")
            max_tokens = int(body.get("max_tokens",
                                      self.server.frontend.default_max_tokens))
            temperature = float(body.get("temperature", 0.0))
        except (ValueError, TypeError, json.JSONDecodeError) as e:
            self._json(400, {"error": {"message": str(e)}})
            return

        fe = self.server.frontend
        fut = fe.launch(prompt, max_tokens=max_tokens,
                        temperature=temperature,
                        session=body.get("user") or None)
        if body.get("stream"):
            self._stream_completion(fut)
        else:
            self._blocking_completion(fut)

    def _blocking_completion(self, fut) -> None:
        fe = self.server.frontend
        try:
            result = fut.value(timeout=fe.request_timeout)
        except BaseException as e:  # noqa: BLE001 — wire fault reporting
            self._json(500, {"error": {"message": str(e),
                                       "type": type(e).__name__}})
            return
        tokens = list(result.tokens)
        self._json(200, {
            "id": f"chatcmpl-{fut.fid}", "object": "chat.completion",
            "created": int(time.time()), "model": fe.model_name,
            "choices": [{"index": 0, "finish_reason": "stop",
                         "message": {"role": "assistant",
                                     "content": detokenize(tokens)}}],
            "usage": {"prompt_tokens": result.prompt_tokens,
                      "completion_tokens": len(tokens),
                      "total_tokens": result.prompt_tokens + len(tokens)}})

    def _stream_completion(self, fut) -> None:
        fe = self.server.frontend
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.send_header("Connection", "close")
        self.end_headers()

        seq = 0
        self._publish_event(seq, self._envelope(
            fut, seq, object="chat.completion.chunk",
            choices=[{"index": 0, "finish_reason": None,
                      "delta": {"role": "assistant", "content": ""}}]))
        # Delta loop over the future's chunk log.  ``sent`` counts tokens
        # already on the wire: a retry that rewinds the log re-streams from
        # the attempt boundary, and waiting for ``sent + 1`` naturally
        # skips what the client already has (greedy decode regenerates the
        # identical prefix), so the client never sees duplicates.
        sent = 0
        err: Optional[BaseException] = None
        try:
            while True:
                fut.wait_streamed(sent + 1, timeout=fe.request_timeout)
                cur = fut.partial()
                if len(cur) > sent:
                    text = detokenize(cur[sent:])
                    if sent:
                        text = " " + text
                    sent = len(cur)
                    seq += 1
                    self._publish_event(seq, self._envelope(
                        fut, seq, object="chat.completion.chunk",
                        choices=[{"index": 0, "finish_reason": None,
                                  "delta": {"content": text}}]))
                if fut.available:
                    fut.value()     # raises if the generation failed
                    break
        except BaseException as e:  # noqa: BLE001 — wire fault reporting
            err = e
        seq += 1
        if err is None:
            final = {"index": 0, "delta": {}, "finish_reason": "stop"}
            self._publish_event(seq, self._envelope(
                fut, seq, object="chat.completion.chunk", choices=[final]))
        else:
            self._publish_event(seq, self._envelope(
                fut, seq, object="error", choices=[],
                error={"message": str(err), "type": type(err).__name__}))
        self.wfile.write(b"data: [DONE]\n\n")
        self.wfile.flush()


# ------------------------------------------------------------------ selftest
def _client_request(port: int, payload: Dict[str, Any]):
    """Real network client (urllib over TCP).  Non-streaming -> parsed JSON;
    streaming -> list of SSE event payloads in arrival order."""
    import urllib.request
    req = urllib.request.Request(
        f"http://127.0.0.1:{port}/v1/chat/completions",
        data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=180) as resp:
        if not payload.get("stream"):
            return json.loads(resp.read())
        events = []
        for raw in resp:
            line = raw.decode().strip()
            if line.startswith("data: "):
                data = line[len("data: "):]
                if data == "[DONE]":
                    break
                events.append(json.loads(data))
        return events


def selftest(replicas: int = 2, max_new: int = 24) -> None:
    """Start the endpoint, drive it over real TCP, assert the streaming
    contract: incremental delivery, monotonic event seq, and streamed
    deltas concatenating byte-identically to the non-streaming answer."""
    rt = build_pool_runtime(replicas=replicas, max_batch=4,
                            max_new_tokens=max_new)
    rt.start()
    fe = OpenAIFrontend(rt, default_max_tokens=max_new)
    server = fe.serve(port=0)
    port = server.server_address[1]
    t = threading.Thread(target=server.serve_forever, daemon=True)
    t.start()
    print(f"[serve.selftest] endpoint up on 127.0.0.1:{port}")
    try:
        msgs = [{"role": "user", "content": "stream me a careful answer"}]
        full = _client_request(port, {"model": "nalar-llm", "messages": msgs,
                                      "max_tokens": max_new})
        text = full["choices"][0]["message"]["content"]
        assert full["usage"]["completion_tokens"] > 1, full

        events = _client_request(port, {"model": "nalar-llm",
                                        "messages": msgs, "stream": True,
                                        "max_tokens": max_new})
        deltas = [e["choices"][0]["delta"].get("content", "")
                  for e in events if e["object"] == "chat.completion.chunk"
                  and e["choices"][0]["delta"].get("content")]
        assert len(deltas) > 1, (
            f"no incremental delivery: {len(deltas)} content events")
        seqs = [e["seq"] for e in events]
        assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs), seqs
        assert events[-1]["choices"][0]["finish_reason"] == "stop", events[-1]
        streamed_text = "".join(deltas)
        assert streamed_text == text, (
            f"streamed != non-streamed:\n  {streamed_text!r}\n  {text!r}")
        print(f"[serve.selftest] PASS: {len(deltas)} incremental events, "
              f"{full['usage']['completion_tokens']} tokens, streamed text "
              f"byte-identical to the non-streaming path")
    finally:
        server.shutdown()
        rt.shutdown()


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8080)
    p.add_argument("--replicas", type=int, default=2)
    p.add_argument("--arch", default="qwen3_0_6b")
    p.add_argument("--max-batch", type=int, default=4)
    p.add_argument("--max-seq", type=int, default=128)
    p.add_argument("--max-new", type=int, default=32,
                   help="default max_tokens when the request omits it")
    p.add_argument("--selftest", action="store_true",
                   help="ephemeral-port endpoint + real-client assertions "
                        "(the CI streaming-smoke job)")
    args = p.parse_args()

    if args.selftest:
        selftest(replicas=args.replicas, max_new=min(args.max_new, 24))
        return

    rt = build_pool_runtime(replicas=args.replicas, arch=args.arch,
                            max_batch=args.max_batch, max_seq=args.max_seq,
                            max_new_tokens=args.max_new)
    rt.start()
    fe = OpenAIFrontend(rt, default_max_tokens=args.max_new)
    server = fe.serve(host=args.host, port=args.port)
    print(f"[launch.serve] /v1/chat/completions on "
          f"http://{args.host}:{server.server_address[1]} "
          f"({args.replicas}x {args.arch} replicas; stream=true for SSE)")
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.shutdown()
        rt.shutdown()


if __name__ == "__main__":
    main()
