"""Serving launcher: NALAR-registered inference engines over a synthetic
request stream.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --engines 2 --requests 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config, get_smoke_config
from ..core import KVRegistry
from ..models import build_model
from ..serving import InferenceEngine, Request, SamplingParams


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--engines", type=int, default=2)
    p.add_argument("--requests", type=int, default=16)
    p.add_argument("--sessions", type=int, default=4)
    p.add_argument("--max-new", type=int, default=12)
    p.add_argument("--max-batch", type=int, default=4)
    p.add_argument("--max-seq", type=int, default=128)
    args = p.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    registry = KVRegistry()
    engines = [InferenceEngine(model, params, max_batch=args.max_batch,
                               max_seq=args.max_seq, kv_registry=registry,
                               instance_id=f"llm:{i}")
               for i in range(args.engines)]
    print(f"[launch.serve] arch={cfg.arch_id} engines={args.engines}")

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(6, 32))).tolist()
        extras = {}
        if cfg.family == "vlm":
            extras["image_embeds"] = rng.standard_normal(
                (cfg.n_image_tokens, cfg.d_model)).astype(np.float32)[None]
        if cfg.family == "audio":
            extras["frames"] = rng.standard_normal(
                (cfg.encoder_seq, cfg.d_model)).astype(np.float32)[None]
        r = Request.make(prompt, session_id=f"sess{i % args.sessions}",
                         sampling=SamplingParams(max_new_tokens=args.max_new),
                         **extras)
        engines[i % args.engines].submit(r)
        reqs.append(r)

    t0 = time.perf_counter()
    while not all(r.finished for r in reqs):
        for e in engines:
            e.step()
    wall = time.perf_counter() - t0
    toks = sum(len(r.generated) for r in reqs)
    print(f"[launch.serve] {len(reqs)} requests, {toks} tokens in "
          f"{wall:.1f}s ({toks / wall:.1f} tok/s)")
    for e in engines:
        print(f"[launch.serve] {e.instance_id}: {e.telemetry()}")


if __name__ == "__main__":
    main()
