"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches JAX device state — the dry-run sets XLA_FLAGS before any jax import
to fabricate 512 host devices; tests and benches must keep seeing 1 device.
"""

from __future__ import annotations


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1, data: int = 1):
    """Small mesh over however many devices exist (CPU tests)."""
    import jax
    n = len(jax.devices())
    assert model * data <= n, f"need {model * data} devices, have {n}"
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link (~per-direction)
HBM_BYTES = 16 * 2 ** 30        # 16 GiB per chip
