"""Training launcher.

On the production TPU mesh this shards params/optimizer per
distributed.ShardingRules and runs the jitted train step; on this CPU
container it runs the same code path over a 1x1 local mesh with reduced
configs (--smoke), proving the launcher end to end.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 50 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import time

import jax

from ..configs import get_config, get_smoke_config
from ..distributed.sharding import ShardingRules
from ..models import build_model
from ..training import (AdamW, DataConfig, Syntheticcorpus, checkpoint,
                        cosine_schedule, extra_inputs, make_train_step)
from .mesh import make_local_mesh, make_production_mesh


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true",
                   help="reduced same-family config (CPU-scale)")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--production-mesh", action="store_true",
                   help="16x16 mesh (requires 256 devices)")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--ckpt", default="")
    p.add_argument("--log-every", type=int, default=10)
    args = p.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    if args.production_mesh or args.multi_pod:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        n = len(jax.devices())
        mesh = make_local_mesh(model=1, data=n)
    rules = ShardingRules(cfg, mesh, mode="train")
    print(f"[launch.train] arch={cfg.arch_id} mesh={dict(mesh.shape)} "
          f"devices={mesh.devices.size}")

    opt = AdamW(learning_rate=cosine_schedule(args.lr, 10, args.steps))
    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        pspecs = rules.param_specs(jax.eval_shape(lambda: params))
        pshard = jax.tree_util.tree_map(
            rules.named, pspecs,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        params = jax.tree_util.tree_map(jax.device_put, params, pshard)
        step_fn = jax.jit(make_train_step(model, opt))
        corpus = Syntheticcorpus(DataConfig(
            vocab_size=cfg.vocab_size, seq_len=args.seq,
            global_batch=args.batch))
        extras = extra_inputs(cfg, args.batch)
        t0 = time.perf_counter()
        first = last = None
        for step in range(args.steps):
            batch = dict(corpus.batch(step))
            batch.update(extras)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            first = first if first is not None else loss
            last = loss
            if step % args.log_every == 0:
                print(f"[launch.train] step {step:4d} loss={loss:.4f}")
        wall = time.perf_counter() - t0
    print(f"[launch.train] {args.steps} steps in {wall:.1f}s; "
          f"loss {first:.3f} -> {last:.3f}")
    if args.ckpt:
        n = checkpoint.save(args.ckpt, params)
        print(f"[launch.train] checkpoint {args.ckpt} ({n / 1e6:.1f} MB)")


if __name__ == "__main__":
    main()
