"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

This proves the distribution config is coherent without hardware: 512
placeholder host devices stand in for 2 TPU v5e pods.  The compiled
artifact supplies memory_analysis (fits?), cost_analysis (FLOPs/bytes), and
the post-SPMD HLO from which collective bytes are extracted — the three
inputs of EXPERIMENTS.md §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""

# The VERY FIRST lines, before ANY other import: jax locks the device count
# on first init.  (Do NOT set this in conftest.py — tests must see 1 device.)
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
from typing import Any, Dict, Optional, Tuple  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..configs import ARCH_IDS, canonical, get_config, get_shape, INPUT_SHAPES  # noqa: E402
from ..configs.base import InputShape, ModelConfig  # noqa: E402
from ..distributed.context import activation_shardings  # noqa: E402
from ..distributed.sharding import ShardingRules, to_sds  # noqa: E402
from ..models import build_model  # noqa: E402
from ..training.optimizer import AdamW, constant_schedule  # noqa: E402
from ..training.train import make_train_step  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402

# long-context carve-outs (DESIGN.md §4)
LONG_CTX_WINDOW = 8192
LONG_CTX_SKIP = {"whisper-medium": "enc-dec: 30s audio ~ 1500 frames; "
                                   "524K decode out of family scope"}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, total_devices: int) -> int:
    """Devices per replica group of a collective instruction."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)   # iota form
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)  # explicit form
    if m:
        return len(m.group(1).split(","))
    return total_devices


def _wire_bytes(op: str, out_bytes: int, g: int) -> float:
    """Per-device bytes over the interconnect (ring algorithms).

    all-gather: receives (g-1)/g of the gathered output;
    all-reduce: reduce-scatter + all-gather = 2 (g-1)/g of the tensor;
    reduce-scatter: input is g x output; sends/receives (g-1) output shards;
    all-to-all: exchanges (g-1)/g of the buffer;
    collective-permute: whole buffer.
    """
    if g <= 1:
        return 0.0
    f = (g - 1) / g
    if op == "all-gather":
        return out_bytes * f
    if op == "all-reduce":
        return 2 * out_bytes * f
    if op == "reduce-scatter":
        return out_bytes * (g - 1)
    if op == "all-to-all":
        return out_bytes * f
    return float(out_bytes)      # collective-permute


def collective_bytes(hlo_text: str, loop_trip_counts=(),
                     total_devices: int = 256) -> Dict[str, float]:
    """Per-device interconnect bytes from the post-SPMD HLO.

    XLA:CPU prints while-loop bodies once and its cost analysis does not
    scale by trip count, so ops whose metadata places them inside loop
    bodies (op_name contains "while/body") are multiplied by the known trip
    counts supplied by the caller: ``loop_trip_counts[d-1]`` for nesting
    depth d (our programs have static loop structure: layer scan, then the
    MoE group map / grad-accum scan).
    """
    out: Dict[str, float] = {op: 0.0 for op in COLLECTIVE_OPS}
    counts: Dict[str, int] = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT\s+)?%\S+\s+=\s+(\S+)\s+([a-z0-9\-]+)\(",
                     stripped)
        if not m:
            continue
        out_shape, op = m.group(1), m.group(2)
        base = next((c for c in COLLECTIVE_OPS
                     if op == c or op.startswith(c + "-")), None)
        if base is None or op.endswith("-done"):
            continue
        # output may be a tuple (dt[..], dt[..]); sum all components
        ob = sum(_shape_bytes(dt, dims)
                 for dt, dims in _SHAPE_RE.findall(out_shape)
                 if dt in _DTYPE_BYTES)
        g = _group_size(stripped, total_devices)
        mult = 1.0
        depth = stripped.count("while/body")
        for d in range(min(depth, len(loop_trip_counts))):
            mult *= max(1, loop_trip_counts[d])
        out[base] += _wire_bytes(base, ob, g) * mult
        counts[base] += 1
    out["total"] = sum(out[op] for op in COLLECTIVE_OPS)
    out["op_counts"] = counts
    return out


def effective_config(arch: str, shape: InputShape) -> Tuple[Optional[ModelConfig], str]:
    cfg = get_config(arch)
    note = ""
    if shape.name == "long_500k":
        if cfg.arch_id in LONG_CTX_SKIP:
            return None, LONG_CTX_SKIP[cfg.arch_id]
        if not cfg.supports_long_context:
            cfg = cfg.replace(sliding_window=LONG_CTX_WINDOW)
            note = f"sliding_window={LONG_CTX_WINDOW} carve-out"
    return cfg, note


def _max_cache_seq(cfg: ModelConfig, shape: InputShape) -> int:
    # decode: KV cache of seq_len; +8 slack so position seq_len is writable
    return shape.seq_len


def loop_trips(cfg: ModelConfig, shape: InputShape, q_chunk: int = 512):
    """Static trip counts of the while loops in each program, outermost
    first.  Current loop structure: layer scan (hybrid: group scan) at
    depth 1; inside it, the SSD chunk scan (ssm) or the chunked-attention
    query scan (train/prefill) at depth 2.  MoE groups run under vmap (no
    loop) since §Perf iteration 2b."""
    full_seq = shape.kind != "decode"
    nq = max(1, -(-shape.seq_len // q_chunk)) if full_seq else 1
    if cfg.family == "hybrid":
        ng = cfg.n_layers // cfg.hybrid_period
        return [max(ng, 1)] + ([nq] if full_seq else [])
    trips = [cfg.n_layers]
    if cfg.family == "ssm":
        if full_seq:
            trips.append(max(1, shape.seq_len // cfg.ssm_chunk))
    elif full_seq:
        trips.append(nq)
    return trips


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
                mesh=None, moe_impl: str = "einsum",
                remat: bool = False) -> Dict[str, Any]:
    """Lower+compile one combination; returns the §Dry-run record."""
    shape = get_shape(shape_name)
    cfg, note = effective_config(arch, shape)
    if cfg is None:
        return {"arch": arch, "shape": shape_name, "skipped": note}
    # production memory config: chunked attention at long sequences and
    # per-layer remat for training (EXPERIMENTS.md §Perf iteration 5)
    attn_impl = "xla_chunked" if shape.kind in ("train", "prefill") else "xla"
    model = build_model(cfg, attention_impl=attn_impl, moe_impl=moe_impl,
                        remat=(shape.kind == "train"))
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    mode = "train" if shape.kind == "train" else "serve"
    rules = ShardingRules(cfg, mesh, mode=mode)

    t0 = time.time()
    param_shapes = model.param_shapes()
    pspecs = rules.param_specs(param_shapes)
    pshard = jax.tree_util.tree_map(rules.named, pspecs,
                                    is_leaf=lambda x: isinstance(
                                        x, jax.sharding.PartitionSpec))
    params_sds = to_sds(param_shapes, pshard)

    bspecs = rules.batch_spec(shape)
    batch_sds = {k: jax.ShapeDtypeStruct(
        v.shape, v.dtype, sharding=rules.named(bspecs[k]))
        for k, v in model.input_specs(shape).items()}

    # constrain logits to stay vocab-sharded through the loss (see
    # EXPERIMENTS.md §Perf iteration 1; distributed/context.py)
    b_ax = bspecs.get("tokens", jax.sharding.PartitionSpec()).sharding \
        if hasattr(bspecs.get("tokens"), "sharding") else None
    batch_axes_name = rules.batch
    logits_sh = rules.named(jax.sharding.PartitionSpec(
        batch_axes_name, None,
        "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None))
    act_ctx = activation_shardings({"logits": logits_sh})

    with mesh, act_ctx:
        if shape.kind == "train":
            opt = AdamW(learning_rate=constant_schedule(1e-4))
            step = make_train_step(model, opt)
            opt_shapes = jax.eval_shape(opt.init, param_shapes)
            ospecs = rules.opt_specs(pspecs)
            oshard = jax.tree_util.tree_map(
                rules.named, ospecs,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
            opt_sds = to_sds(opt_shapes, oshard)
            lowered = jax.jit(step).lower(params_sds, opt_sds, batch_sds)
        elif shape.kind == "prefill":
            def prefill_fn(params, batch):
                return model.prefill(params, batch)
            lowered = jax.jit(prefill_fn).lower(params_sds, batch_sds)
        else:  # decode
            cache_shapes = model.cache_shapes(shape.global_batch,
                                              _max_cache_seq(cfg, shape))
            cspecs = rules.cache_specs(cache_shapes, shape)
            cshard = jax.tree_util.tree_map(
                rules.named, cspecs,
                is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
            cache_sds = to_sds(cache_shapes, cshard)

            def decode_fn(params, token, cache):
                return model.decode_step(params, token, cache)

            lowered = jax.jit(decode_fn).lower(
                params_sds, batch_sds["token"], cache_sds)
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    # jax's Compiled.cost_analysis() has returned a one-element list of
    # dicts on some versions and a bare dict on others; normalize.
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    trips = loop_trips(cfg, shape)
    n_dev = int(mesh.devices.size)
    coll = collective_bytes(compiled.as_text(), loop_trip_counts=trips,
                            total_devices=n_dev)

    def _get(obj, *names, default=0.0):
        for n in names:
            if hasattr(obj, n):
                return float(getattr(obj, n))
            if isinstance(obj, dict) and n in obj:
                return float(obj[n])
        return default

    record: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": list(mesh.devices.shape),
        "axes": list(mesh.axis_names),
        "note": note,
        "moe_impl": moe_impl if cfg.n_experts else "",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "n_devices": n_dev,
        "loop_trip_counts": trips,
        # raw XLA numbers: loop bodies are counted ONCE (XLA:CPU cost
        # analysis is trip-count-unaware); §Roofline uses the analytic
        # estimator cross-checked against these.
        "flops_per_device_raw": _get(cost, "flops"),
        "bytes_accessed_per_device_raw": _get(cost, "bytes accessed",
                                              "bytes_accessed"),
        "collective_bytes_per_device": coll,
        "memory": {
            "argument_bytes": _get(mem, "argument_size_in_bytes"),
            "output_bytes": _get(mem, "output_size_in_bytes"),
            "temp_bytes": _get(mem, "temp_size_in_bytes"),
            "generated_code_bytes": _get(mem, "generated_code_size_in_bytes"),
        },
        "param_count": cfg.param_count(),
        "param_count_active": cfg.param_count(active_only=True),
    }
    return record


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--moe-impl", default="einsum", choices=["einsum", "gather"])
    p.add_argument("--out", default="benchmarks/results")
    args = p.parse_args()

    combos = []
    if args.all:
        for arch in ARCH_IDS:
            for s in INPUT_SHAPES:
                combos.append((arch, s.name))
    else:
        combos.append((canonical(args.arch), args.shape))

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)
    for arch, shape_name in combos:
        for mp in meshes:
            tag = "multipod" if mp else "singlepod"
            try:
                rec = lower_combo(arch, shape_name, multi_pod=mp,
                                  moe_impl=args.moe_impl)
                status = "SKIP " + rec["skipped"] if "skipped" in rec else (
                    f"ok lower={rec['lower_s']}s compile={rec['compile_s']}s "
                    f"flops/dev(raw)={rec['flops_per_device_raw']:.3e} "
                    f"coll={rec['collective_bytes_per_device']['total']:.3e}B")
            except Exception as e:  # noqa: BLE001 — report, continue sweep
                rec = {"arch": arch, "shape": shape_name, "mesh_tag": tag,
                       "error": f"{type(e).__name__}: {e}"}
                status = "FAIL " + rec["error"][:120]
            suffix = "" if args.moe_impl == "einsum" else f"_{args.moe_impl}"
            fname = os.path.join(
                args.out, f"dryrun_{canonical(arch)}_{shape_name}_{tag}{suffix}.json")
            with open(fname, "w") as f:
                json.dump(rec, f, indent=2)
            print(f"[{arch} x {shape_name} x {tag}] {status}", flush=True)


if __name__ == "__main__":
    main()
