"""Global controller: periodic policy computation (paper §4.1, §4.2).

Single-threaded, push-based loop.  Each period it:
 1. aggregates metrics + future-metadata mirrors from every node store
    (modelled per-node fetch latency — this is what Fig. 10 measures),
 2. runs the operator's policy program over the ClusterView,
 3. writes the resulting decisions (routing tables, priorities, migrations,
    provisioning) back into node stores, where component controllers consume
    them asynchronously.

The global controller is never on the execution fast path; a slow loop only
delays policy refresh, not request progress.

View collection is *incremental*: the controller keeps one long-lived
``ClusterView`` and patches it each round from per-store delta scans
(``NodeStore.scan_changed``), so per-round collect cost scales with churn —
futures created/resolved and mirrors republished since the previous round —
not with the total population.  A periodic full rebuild
(``full_rebuild_interval`` rounds) is the drift-correction escape hatch;
``collect_view(full=True)`` forces one on demand.  This is what takes the
Fig. 10 claim (131K futures, sub-500 ms global loop, policy logic dominating)
from aspiration to measured.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Dict, Optional, Tuple

from .policy import ActionSink, ClusterView, Policy, RetryPolicy

#: key prefixes the cluster view is built from
VIEW_PREFIXES = ("metrics:", "future:")


class GlobalController:
    def __init__(self, runtime, policy: Policy, interval: float = 0.25,
                 node_fetch_latency: float = 0.0,
                 full_rebuild_interval: int = 64) -> None:
        self.runtime = runtime
        self.policy = policy
        self.interval = interval
        # virtual-time cost to poll one node's store (network RTT model);
        # real wall-clock compute cost is measured separately for Fig. 10.
        self.node_fetch_latency = node_fetch_latency
        # always-on rung 2 of the retry ladder: decides the fate of failures
        # component controllers escalated (reroute to a survivor / give up).
        # Swappable like the main policy, but kept separate from it so
        # escalations are never lost to an operator policy chain that
        # doesn't know about them.
        self.retry_policy: Policy = RetryPolicy()
        # every ``full_rebuild_interval`` rounds the persistent view is
        # rebuilt from scratch (drift correction); 0 disables the periodic
        # rebuild (delta-only after the bootstrap round)
        self.full_rebuild_interval = full_rebuild_interval
        self._running = False
        # rounds are logically single-threaded; under the RealTimeKernel an
        # escalation nudge fires on a timer thread and must not interleave
        # with a periodic tick now that the view is persistent shared state
        self._round_lock = threading.RLock()
        # rolling histories (bounded: the loop ticks forever in long-lived
        # deployments; Telemetry.control_rounds keeps the canonical record)
        self.loop_wall_times: "deque[float]" = deque(maxlen=4096)
        self.loop_breakdown: "deque[Dict[str, float]]" = deque(maxlen=4096)
        # incremental-collection state
        self._view: Optional[ClusterView] = None
        self._cursors: Dict[Tuple[str, str], int] = {}  # (node, prefix) -> seq
        self._rounds_since_rebuild = 0
        self.rebuild_rounds = 0      # full rebuilds performed (incl. bootstrap)
        self.delta_rounds = 0        # delta-patched rounds
        self._last_collected = 0     # entries read from stores last round

    # ------------------------------------------------------------- lifecycle
    def start(self) -> None:
        self._running = True
        self._schedule_next(0.0)

    def stop(self) -> None:
        self._running = False

    def _schedule_next(self, delay: float) -> None:
        if self._running:
            self.runtime.kernel.schedule(delay, self._tick, tag="global-tick",
                                         periodic=True)

    def _tick(self) -> None:
        if not self._running:
            return
        self.run_once()
        self._schedule_next(self.interval)

    # ------------------------------------------------------------- one round
    def collect_view(self, full: bool = False) -> ClusterView:
        with self._round_lock:
            due = (full or self._view is None
                   or (self.full_rebuild_interval
                       and self._rounds_since_rebuild
                       >= self.full_rebuild_interval))
            view = self._collect_full() if due else self._collect_delta()
            self._refresh_dynamic(view)
            return view

    def _apply_entries(self, view: ClusterView, prefix: str, node_id: str,
                       changed: Dict[str, dict], deleted, is_live) -> int:
        """Upsert/evict one prefix's entries into the view.  Shared by the
        full-rebuild and delta paths so the two can never drift on how a
        mirror is interpreted."""
        plen = len(prefix)
        n = 0
        if prefix == "metrics:":
            for key, m in changed.items():
                if not m:
                    continue
                view.upsert_instance(key[plen:], m, node_id, is_live)
                n += 1
            for key in deleted:
                view.evict_instance(key[plen:])
        else:   # "future:"
            for key, h in changed.items():
                view.upsert_future_mirror(key[plen:], h, node_id)
                n += 1
            for key in deleted:
                view.evict_future_mirror(key[plen:], node_id)
        return n

    def _collect_full(self) -> ClusterView:
        """Rebuild the view from scratch (bootstrap round / escape hatch)."""
        rt = self.runtime
        view = ClusterView(now=rt.kernel.now())
        # drain BEFORE snapshotting liveness: a session flipping after the
        # drain re-marks itself for the next delta round, whereas the
        # reverse order could swallow a flip the snapshot never saw
        rt.futures.drain_dirty_sessions()    # rebuilt from scratch: reset
        live = rt.futures.live_sessions()
        is_live = live.__contains__
        n = 0
        for store in rt.stores.all_stores():
            for prefix in VIEW_PREFIXES:
                # scanning resets the journal (drain semantics) and advances
                # the cursor; writes racing the key scan below re-report
                # next round (upserts are idempotent)
                _, _, cur = store.scan_changed(
                    prefix, self._cursors.get((store.node_id, prefix), 0))
                self._cursors[(store.node_id, prefix)] = cur
                keys = store.keys(prefix)
                n += self._apply_entries(view, prefix, store.node_id,
                                         store.hgetall_many(keys), (),
                                         is_live)
        self._view = view
        self._rounds_since_rebuild = 0
        self.rebuild_rounds += 1
        self._last_collected = n
        return view

    def _collect_delta(self) -> ClusterView:
        """Patch the persistent view with what moved since the last round."""
        rt = self.runtime
        view = self._view
        view.now = rt.kernel.now()
        table = rt.futures
        is_live = lambda sid: table.live_count(sid) > 0  # noqa: E731
        n = 0
        for store in rt.stores.all_stores():
            nid = store.node_id
            for prefix in VIEW_PREFIXES:
                changed, deleted, cur = store.scan_changed(
                    prefix, self._cursors.get((nid, prefix), 0))
                self._cursors[(nid, prefix)] = cur
                hashes = store.hgetall_many(changed) if changed else {}
                n += self._apply_entries(view, prefix, nid, hashes, deleted,
                                         is_live)
        # sessions whose liveness flipped re-filter exactly the waiting
        # lists that name them (stale-session pruning without a full pass)
        dirty = table.drain_dirty_sessions()
        if dirty:
            view.refresh_waiting(dirty, is_live)
        self._rounds_since_rebuild += 1
        self.delta_rounds += 1
        self._last_collected = n
        return view

    def _refresh_dynamic(self, view: ClusterView) -> None:
        """Non-mirrored view fields, recomputed every round.  All are small
        (O(sessions) / O(escalations)), never O(total futures)."""
        rt = self.runtime
        view.session_priority = {s.session_id: s.priority
                                 for s in rt.sessions.all()}
        view.node_resources = rt.free_resources()
        view.kv_residency = rt.kv_registry.residency_map()
        view.blacklisted = set(rt.blacklist)
        view.escalated = [
            dict(fid=rec.fut.fid,
                 agent_type=rec.fut.meta.agent_type,
                 session=rec.fut.meta.session_id,
                 executor=rec.src_instance,
                 attempt=rec.fut.meta.attempt,
                 escalations=rec.fut.meta.escalations,
                 reason=rec.reason,
                 error=repr(rec.error))
            for rec in rt.pending_escalations()]
        view.hedge_candidates = rt.hedge_candidates()

    def handle_escalations(self) -> None:
        """Off-cycle retry round, nudged by ``runtime.escalate``.

        Escalated failures must not wait for the next periodic tick (under
        the SimKernel there might never be one — periodic events don't keep
        the simulation alive), so controllers schedule this directly.  Only
        the retry policy runs; the operator's policy chain stays periodic.
        """
        if not self.runtime.pending_escalations():
            return
        with self._round_lock:
            view = self.collect_view()
            sink = ActionSink()
            self.retry_policy.step(view, sink)
            self.apply(sink)

    def run_once(self) -> Dict[str, float]:
        """One policy round.  Returns wall-clock breakdown (collect/policy/push)."""
        with self._round_lock:
            return self._run_once_locked()

    def _run_once_locked(self) -> Dict[str, float]:
        t0 = time.perf_counter()
        rebuilds_before = self.rebuild_rounds
        view = self.collect_view()
        t1 = time.perf_counter()
        sink = ActionSink()
        self.policy.step(view, sink)
        if view.escalated:
            self.retry_policy.step(view, sink)
        t2 = time.perf_counter()
        self.apply(sink)
        t3 = time.perf_counter()
        # model the per-node fetch RTT in virtual time
        if self.node_fetch_latency:
            pass  # accounted by the benchmark harness, not the fast path
        breakdown = {
            "collect": t1 - t0,
            "policy": t2 - t1,
            "push": t3 - t2,
            "total": t3 - t0,
            "n_instances": float(len(view.instances)),
            "n_futures": float(len(view.futures)),
            # entries actually fetched from stores this round (== churn on
            # delta rounds, == population on rebuild rounds)
            "n_collected": float(self._last_collected),
            "rebuild": float(self.rebuild_rounds > rebuilds_before),
        }
        self.loop_wall_times.append(breakdown["total"])
        self.loop_breakdown.append(breakdown)
        self.runtime.telemetry.on_control_round(
            view.now, breakdown["collect"], breakdown["policy"],
            breakdown["push"], int(self._last_collected),
            self.rebuild_rounds > rebuilds_before)
        return breakdown

    # ----------------------------------------------------------- enforcement
    def apply(self, sink: ActionSink) -> None:
        """Enact one round's actions.

        Store-mediated commands (migrations, schedule installs) are
        coalesced into one ``hset_many`` per destination command key —
        component controllers consume a batch per run of commands instead
        of a write per action.  Policy action *order* is preserved: a direct
        runtime action (kill, provision, retry, ...) first flushes every
        pending command write, so e.g. a migrate emitted before a kill
        still lands before the kill executes.
        """
        rt = self.runtime
        # (node, key) -> {field: payload}
        writes: Dict[Tuple[str, str], Dict[str, dict]] = {}

        def emit(node: str, key: str, fld: str, payload: dict) -> None:
            writes.setdefault((node, key), {})[fld] = payload

        def flush() -> None:
            for (node, key), mapping in writes.items():
                rt.stores.get(node).hset_many(key, mapping)
            writes.clear()

        _STORE_MEDIATED = ("migrate", "migrate_future", "install_schedule")
        for a in sink.actions:
            p = a.payload
            if writes and a.kind not in _STORE_MEDIATED:
                flush()     # ordering barrier before any direct action
            if a.kind == "route":
                rt.router.pin(p["session_id"], p["agent_type"], p["instance"])
            elif a.kind == "route_weighted":
                rt.router.set_weights(p["agent_type"], p["instances"],
                                      p["weights"])
            elif a.kind == "route_tier":
                rt.router.set_tiers(p["agent_type"], p["tiers"])
            elif a.kind == "set_priority":
                rt.sessions.set_priority(p["session_id"], p["value"],
                                         p.get("agent"))
                rt.reprioritize_session(p["session_id"])
            elif a.kind == "migrate":
                ctrl = rt.controller_of(p["src"])
                if ctrl is not None:
                    emit(ctrl.inst.node_id, f"cmd:{p['src']}",
                         f"mig:{p['session_id']}",
                         dict(kind="migrate_session",
                              session_id=p["session_id"], dst=p["dst"]))
            elif a.kind == "migrate_future":
                fut = rt.futures.get(p["fid"])
                if fut is None:
                    continue
                ctrl = rt.controller_of(fut.meta.executor)
                if ctrl is not None:
                    emit(ctrl.inst.node_id, f"cmd:{fut.meta.executor}",
                         f"migf:{p['fid']}",
                         dict(kind="migrate_future", fid=p["fid"],
                              dst=p["dst"]))
            elif a.kind == "kill":
                rt.kill_instance(p["instance"], drain_to=p.get("drain_to"))
            elif a.kind == "provision":
                rt.provision_instance(p["agent_type"], p["node"])
            elif a.kind == "retry_future":
                rt.apply_retry(p["fid"], p["instance"])
            elif a.kind == "hedge_future":
                rt.apply_hedge(p["fid"], p["instance"])
            elif a.kind == "fail_future":
                rt.fail_escalated(p["fid"], p.get("reason", ""))
            elif a.kind == "blacklist":
                rt.blacklist_instance(p["instance"])
            elif a.kind == "install_schedule":
                for iid in list(rt.instances_of_type(p["agent_type"])):
                    ctrl = rt.controller_of(iid)
                    if ctrl is not None:
                        emit(ctrl.inst.node_id, f"cmd:{iid}", "sched",
                             dict(kind="set_schedule", policy=p["policy"]))
        flush()
