"""Global controller: periodic policy computation (paper §4.1, §4.2).

Single-threaded, push-based loop.  Each period it:
 1. aggregates metrics + future-metadata mirrors from every node store
    (modelled per-node fetch latency — this is what Fig. 10 measures),
 2. runs the operator's policy program over the ClusterView,
 3. writes the resulting decisions (routing tables, priorities, migrations,
    provisioning) back into node stores, where component controllers consume
    them asynchronously.

The global controller is never on the execution fast path; a slow loop only
delays policy refresh, not request progress.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional

from .policy import ActionSink, ClusterView, InstanceView, Policy, RetryPolicy


class GlobalController:
    def __init__(self, runtime, policy: Policy, interval: float = 0.25,
                 node_fetch_latency: float = 0.0) -> None:
        self.runtime = runtime
        self.policy = policy
        self.interval = interval
        # virtual-time cost to poll one node's store (network RTT model);
        # real wall-clock compute cost is measured separately for Fig. 10.
        self.node_fetch_latency = node_fetch_latency
        # always-on rung 2 of the retry ladder: decides the fate of failures
        # component controllers escalated (reroute to a survivor / give up).
        # Swappable like the main policy, but kept separate from it so
        # escalations are never lost to an operator policy chain that
        # doesn't know about them.
        self.retry_policy: Policy = RetryPolicy()
        self._running = False
        self.loop_wall_times: List[float] = []   # real seconds per loop
        self.loop_breakdown: List[Dict[str, float]] = []

    # ------------------------------------------------------------- lifecycle
    def start(self) -> None:
        self._running = True
        self._schedule_next(0.0)

    def stop(self) -> None:
        self._running = False

    def _schedule_next(self, delay: float) -> None:
        if self._running:
            self.runtime.kernel.schedule(delay, self._tick, tag="global-tick",
                                         periodic=True)

    def _tick(self) -> None:
        if not self._running:
            return
        self.run_once()
        self._schedule_next(self.interval)

    # ------------------------------------------------------------- one round
    def collect_view(self) -> ClusterView:
        now = self.runtime.kernel.now()
        view = ClusterView(now=now)
        # Sessions that still have unresolved futures.  Metrics mirrors are
        # pushed asynchronously, so an instance's ``waiting_sessions`` list
        # can name sessions whose work has since completed; acting on those
        # (e.g. migrating a finished session, Fig. 6 style) wastes real
        # migration work.  Prune against the future table at aggregation.
        live_sessions = {f.meta.session_id
                         for f in self.runtime.futures.snapshot()
                         if f.meta.session_id and not f.available}
        for store in self.runtime.stores.all_stores():
            for key in store.keys("metrics:"):
                m = store.hgetall(key)
                if not m:
                    continue
                iid = key[len("metrics:"):]
                iv = InstanceView(
                    instance_id=iid,
                    agent_type=m.get("agent_type", ""),
                    node=m.get("node", store.node_id),
                    qsize=int(m.get("qsize", 0)),
                    busy=bool(m.get("busy", False)),
                    busy_until=float(m.get("busy_until", 0.0)),
                    ema_service=float(m.get("ema_service", 0.0)),
                    completed=int(m.get("completed", 0)),
                    failed=int(m.get("failed", 0)),
                    alive=bool(m.get("alive", True)),
                    waiting_sessions=[s for s in m.get("waiting_sessions", [])
                                      if s in live_sessions],
                    inflight=int(m.get("inflight", 0)),
                    retries=int(m.get("retries", 0)),
                    cancelled=int(m.get("cancelled", 0)),
                )
                view.instances[iid] = iv
                view.by_type.setdefault(iv.agent_type, []).append(iid)
            # future-metadata mirrors (used by future-aware policies and the
            # Fig. 10 scalability benchmark)
            for key in store.keys("future:"):
                view.futures[key[len("future:"):]] = store.hgetall(key)
        for s in self.runtime.sessions.all():
            view.session_priority[s.session_id] = s.priority
        view.node_resources = self.runtime.free_resources()
        view.kv_residency = self.runtime.kv_registry.residency_map()
        view.blacklisted = set(self.runtime.blacklist)
        view.escalated = [
            dict(fid=rec.fut.fid,
                 agent_type=rec.fut.meta.agent_type,
                 session=rec.fut.meta.session_id,
                 executor=rec.src_instance,
                 attempt=rec.fut.meta.attempt,
                 escalations=rec.fut.meta.escalations,
                 reason=rec.reason,
                 error=repr(rec.error))
            for rec in self.runtime.pending_escalations()]
        return view

    def handle_escalations(self) -> None:
        """Off-cycle retry round, nudged by ``runtime.escalate``.

        Escalated failures must not wait for the next periodic tick (under
        the SimKernel there might never be one — periodic events don't keep
        the simulation alive), so controllers schedule this directly.  Only
        the retry policy runs; the operator's policy chain stays periodic.
        """
        if not self.runtime.pending_escalations():
            return
        view = self.collect_view()
        sink = ActionSink()
        self.retry_policy.step(view, sink)
        self.apply(sink)

    def run_once(self) -> Dict[str, float]:
        """One policy round.  Returns wall-clock breakdown (collect/policy/push)."""
        t0 = time.perf_counter()
        view = self.collect_view()
        t1 = time.perf_counter()
        sink = ActionSink()
        self.policy.step(view, sink)
        if view.escalated:
            self.retry_policy.step(view, sink)
        t2 = time.perf_counter()
        self.apply(sink)
        t3 = time.perf_counter()
        # model the per-node fetch RTT in virtual time
        if self.node_fetch_latency:
            pass  # accounted by the benchmark harness, not the fast path
        breakdown = {
            "collect": t1 - t0,
            "policy": t2 - t1,
            "push": t3 - t2,
            "total": t3 - t0,
            "n_instances": float(len(view.instances)),
            "n_futures": float(len(view.futures)),
        }
        self.loop_wall_times.append(breakdown["total"])
        self.loop_breakdown.append(breakdown)
        return breakdown

    # ----------------------------------------------------------- enforcement
    def apply(self, sink: ActionSink) -> None:
        rt = self.runtime
        for a in sink.actions:
            p = a.payload
            if a.kind == "route":
                rt.router.pin(p["session_id"], p["agent_type"], p["instance"])
            elif a.kind == "route_weighted":
                rt.router.set_weights(p["agent_type"], p["instances"],
                                      p["weights"])
            elif a.kind == "set_priority":
                rt.sessions.set_priority(p["session_id"], p["value"],
                                         p.get("agent"))
                rt.reprioritize_session(p["session_id"])
            elif a.kind == "migrate":
                ctrl = rt.controller_of(p["src"])
                if ctrl is not None:
                    store = rt.stores.get(ctrl.inst.node_id)
                    store.hset(f"cmd:{p['src']}", f"mig:{p['session_id']}",
                               dict(kind="migrate_session",
                                    session_id=p["session_id"], dst=p["dst"]))
            elif a.kind == "migrate_future":
                fut = rt.futures.get(p["fid"])
                if fut is None:
                    continue
                ctrl = rt.controller_of(fut.meta.executor)
                if ctrl is not None:
                    store = rt.stores.get(ctrl.inst.node_id)
                    store.hset(f"cmd:{fut.meta.executor}", f"migf:{p['fid']}",
                               dict(kind="migrate_future", fid=p["fid"],
                                    dst=p["dst"]))
            elif a.kind == "kill":
                rt.kill_instance(p["instance"], drain_to=p.get("drain_to"))
            elif a.kind == "provision":
                rt.provision_instance(p["agent_type"], p["node"])
            elif a.kind == "retry_future":
                rt.apply_retry(p["fid"], p["instance"])
            elif a.kind == "fail_future":
                rt.fail_escalated(p["fid"], p.get("reason", ""))
            elif a.kind == "blacklist":
                rt.blacklist_instance(p["instance"])
            elif a.kind == "install_schedule":
                for iid in list(rt.instances_of_type(p["agent_type"])):
                    ctrl = rt.controller_of(iid)
                    if ctrl is not None:
                        store = rt.stores.get(ctrl.inst.node_id)
                        store.hset(f"cmd:{iid}", "sched",
                                   dict(kind="set_schedule",
                                        policy=p["policy"]))
