"""The NALAR runtime: deployment, routing, and the glue between layers.

``NalarRuntime`` owns the kernel, node stores, future table, session registry,
state layer, KV registry, telemetry, agent instances and their controllers,
and the global controller.  ``deployment`` (bottom) is the thin user-facing
entry mirroring the paper's ``deployment.main(...)``.
"""

from __future__ import annotations

import random
import threading
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

from .clock import Kernel, RealTimeKernel, SimKernel
from .controller_global import GlobalController
from .controller_local import ComponentController
from .directives import Directives
from .executor import AgentInstance, EmulatedMethod, EngineBackedMethod
from .future import DeadlineExceeded, Future, FutureState, FutureTable
from .kv_registry import KVRegistry
from .node_store import StoreCluster
from .policy import Policy, default_policies
from .session import (SessionRegistry, clear_context, get_context,
                      get_current_deadline, set_context, set_current_deadline)
from .state import SessionStateStore
from .stubs import AgentSpec, Stub
from .telemetry import Telemetry

_current_runtime: Optional["NalarRuntime"] = None
_rt_lock = threading.Lock()


def current_runtime() -> Optional["NalarRuntime"]:
    return _current_runtime


def _set_current(rt: Optional["NalarRuntime"]) -> None:
    global _current_runtime
    with _rt_lock:
        _current_runtime = rt


@dataclass
class EscalationRecord:
    """A failure a component controller could not absorb locally, parked
    until the global controller's RetryPolicy decides its fate."""

    fut: Future
    error: BaseException
    src_instance: str
    reason: str               # "budget_exhausted" | "instance_death"
    at: float


class Router:
    """Routing decisions for newly created futures.

    Precedence: session pin (stateful / route primitive) → managed-state
    locality → weighted table (load-balance policy) → least-ETA default.
    """

    def __init__(self, runtime: "NalarRuntime") -> None:
        self.rt = runtime
        self._pins: Dict[tuple, str] = {}        # (sid, agent_type) -> iid
        self._weights: Dict[str, tuple] = {}     # agent_type -> (iids, cum_w)
        self._tiers: Dict[str, Dict[str, List[str]]] = {}  # at -> tier -> iids
        self._rng = random.Random(0xA11CE)
        # default-routing capability: "least_eta" (NALAR's native policy-1
        # load balancing), "least_qlen" (queue-length only — blind to
        # in-flight service time, the HoL trap), "round_robin"
        self.mode = "least_eta"
        self._rr: Dict[str, int] = {}
        # native cache/state locality (steps 2a/2b below).  On by default —
        # disabling it models baseline systems that spray sessions across
        # replicas and pay a full-context rebuild per call (the pooled-
        # routing benchmark compares exactly this), or lets an explicit
        # KVAffinityPolicy own the decision through `route` pins instead.
        self.kv_affinity = True
        # admission-control shedding: when an engine-backed instance's wait
        # queue is above this saturation fraction and a less-saturated
        # sibling exists, route there instead — even past a session pin or
        # KV-affinity hit (paying a cold prefill beats queueing into
        # collapse).  None disables shedding.
        self.shed_watermark: Optional[float] = 0.75

    def _saturation_fn(self, agent_type: str):
        """Backend queue-saturation probe, if the agent is engine-backed."""
        if self.shed_watermark is None:
            return None
        backend = self.rt.engine_backends.get(agent_type)
        if backend is None or not hasattr(backend, "saturation_of"):
            return None
        return backend.saturation_of

    def pin(self, session_id: str, agent_type: str, instance: str) -> None:
        self._pins[(session_id, agent_type)] = instance

    def unpin(self, session_id: str, agent_type: str) -> None:
        self._pins.pop((session_id, agent_type), None)

    def set_weights(self, agent_type: str, instances: List[str],
                    weights: List[float]) -> None:
        cum, s = [], 0.0
        for w in weights:
            s += w
            cum.append(s)
        self._weights[agent_type] = (list(instances), cum)

    def set_tiers(self, agent_type: str,
                  tiers: Dict[str, List[str]]) -> None:
        """Install the ``route_tier`` table: tier label -> instance ids."""
        self._tiers[agent_type] = {t: list(ids) for t, ids in tiers.items()}

    def route(self, fut: Future) -> Optional[AgentInstance]:
        at = fut.meta.agent_type
        sid = fut.meta.session_id
        live = self.rt.live_instances(at)
        if not live:
            return None
        spec = self.rt.spec_of(at)
        sat_of = self._saturation_fn(at)

        def shed(inst: AgentInstance) -> bool:
            """True when ``inst`` is past the watermark and a fresher
            sibling exists: fall through to load-based routing."""
            if sat_of is None:
                return False
            if sat_of(inst.instance_id) < self.shed_watermark:
                return False
            return any(sat_of(i.instance_id) < self.shed_watermark
                       for i in live if i.instance_id != inst.instance_id)

        # 1. explicit/stateful pin
        pin = self._pins.get((sid, at))
        if pin is not None:
            inst = self.rt.instance(pin)
            if inst is not None and inst.alive:
                # stateful sessions are never shed: they may not migrate
                # (§5), and falling through would re-pin them elsewhere
                if spec.directives.stateful or not shed(inst):
                    return inst
                # saturated: shed this call (keep the pin — follow-ups
                # return home once the queue drains)
            else:
                self.unpin(sid, at)
        if spec.directives.stateful and sid:
            inst = min(live, key=lambda i: i.load_score(self.rt.kernel.now()))
            self.pin(sid, at, inst.instance_id)  # sticky forever (§5)
            return inst
        # 2a. K,V-cache locality: route the session to the instance holding
        # its cache (§4.3.2 — "scheduling is rendered sticky").  NALAR's HoL
        # policy relieves this by *migrating the cache*, after which the
        # registry points follow-ups at the new instance.
        if self.kv_affinity and spec.directives.uses_managed_state and sid:
            info = self.rt.kv_registry.lookup(sid)
            if info is not None:
                inst = self.rt.instance(info.instance_id)
                if (inst is not None and inst.alive
                        and inst.agent_type == at and not shed(inst)):
                    return inst
        # 2b. managed-state locality: prefer the node holding session state
        if self.kv_affinity and spec.directives.uses_managed_state and sid:
            names = self.rt.state_store.session_state_names(sid, at)
            if names:
                node = self.rt.state_store.placement_of(sid, at, names[0])
                local = [i for i in live if i.node_id == node
                         and not shed(i)]
                if local:
                    return min(local, key=lambda i: i.load_score(self.rt.kernel.now()))
        # 2c. model-tier hint (route_tier primitive): restrict the candidate
        # pool to the hinted tier's replicas.  SLO-aware fallback: when the
        # whole tier sits at/above the shed watermark while another tier
        # still has a fresh replica, the hint yields to the shed — a hint
        # is a preference, never a hard pin.
        tier_pool = None
        tiers = self._tiers.get(at)
        tier_hint = fut.meta.work_hint.get("model_tier") if tiers else None
        if tier_hint is not None:
            ids = set(tiers.get(str(tier_hint), ()))
            pool = [i for i in live if i.instance_id in ids]
            if pool and not (
                    sat_of is not None
                    and not any(sat_of(i.instance_id) < self.shed_watermark
                                for i in pool)
                    and any(sat_of(i.instance_id) < self.shed_watermark
                            for i in live)):
                tier_pool = pool
                live = pool
        # shed saturated replicas from default/weighted selection while a
        # below-watermark sibling exists (backpressure-aware routing)
        if sat_of is not None:
            fresh = [i for i in live
                     if sat_of(i.instance_id) < self.shed_watermark]
            if fresh:
                live = fresh
        # 3. weighted table installed by the global policy
        wt = self._weights.get(at)
        if wt is not None:
            iids, cum = wt
            allowed = {i.instance_id for i in live}
            valid = [(i, c) for i, c in zip(iids, cum)
                     if self.rt.instance(i) is not None
                     and self.rt.instance(i).alive
                     and (i in allowed or not (sat_of or tier_pool))]
            if valid:
                r = self._rng.random() * valid[-1][1]
                for iid, c in valid:
                    if r <= c:
                        inst = self.rt.instance(iid)
                        if inst is not None:
                            return inst
        # 4. default routing, per capability mode
        if self.mode == "round_robin":
            idx = self._rr.get(at, 0)
            self._rr[at] = idx + 1
            return live[idx % len(live)]
        if self.mode == "least_qlen":
            return min(live, key=lambda i: (i.qsize(), i.instance_id))
        return min(live, key=lambda i: i.load_score(self.rt.kernel.now()))


class NalarRuntime:
    def __init__(self, *, simulate: bool = True,
                 nodes: Optional[Dict[str, Dict[str, float]]] = None,
                 policy: Optional[Policy] = None,
                 control_interval: float = 0.25,
                 net_latency_same_node: float = 5e-5,
                 net_latency_cross_node: float = 5e-4,
                 state_bandwidth: float = 1e9,
                 future_gc_threshold: int = 4096,
                 seed: int = 0) -> None:
        self.kernel: Kernel = SimKernel() if simulate else RealTimeKernel()
        self.stores = StoreCluster()
        self.futures = FutureTable(gc_threshold=future_gc_threshold)
        self.sessions = SessionRegistry()
        self.telemetry = Telemetry()
        self.kv_registry = KVRegistry()
        self.state_store = SessionStateStore(self.stores)
        self.router = Router(self)
        self.rng = random.Random(seed)
        self._net_same = net_latency_same_node
        self._net_cross = net_latency_cross_node
        self._state_bw = state_bandwidth
        # cluster resources
        self.nodes: Dict[str, Dict[str, float]] = dict(
            nodes or {"n0": {"GPU": 8, "CPU": 64}})
        self._used: Dict[str, Dict[str, float]] = {
            n: {k: 0.0 for k in caps} for n, caps in self.nodes.items()}
        for n in self.nodes:
            self.stores.get(n)  # materialize node stores
        # agents
        self._specs: Dict[str, AgentSpec] = {}
        self._stubs: Dict[str, Stub] = {}
        self._instances: Dict[str, AgentInstance] = {}
        self._controllers: Dict[str, ComponentController] = {}
        self._instance_counter: Dict[str, int] = {}
        self._agent_ctx = threading.local()
        # real execution backends (serving bridges) attached to agent types;
        # populated by repro.serving.bridge.register_engine_agent
        self.engine_backends: Dict[str, Any] = {}
        # failure handling: escalated futures awaiting a RetryPolicy decision,
        # and instances the router must never pick again (dead replicas)
        self._esc_lock = threading.Lock()
        self.escalations: Dict[str, EscalationRecord] = {}
        self.blacklist: set = set()
        # hedged dispatch (latency-fault handling): fid -> (src, dst) for
        # futures currently racing a duplicate attempt.  First completion
        # wins (terminal-state guard in complete_async); the loser is
        # cancelled/detached by on_future_resolved.
        self._hedge_lock = threading.Lock()
        self._hedges: Dict[str, tuple] = {}
        self._hedge_claimed: set = set()
        self.hedges_issued = 0
        self._shutdown_hooks: List[Callable[[], None]] = []
        self.global_controller = GlobalController(
            self, policy or default_policies(), interval=control_interval)
        _set_current(self)

    # ---------------------------------------------------------- agent mgmt
    def register_agent(self, spec: AgentSpec,
                       nodes: Optional[List[str]] = None,
                       instances: Optional[int] = None) -> Stub:
        spec.validate()
        self._specs[spec.name] = spec
        stub = Stub(self, spec)
        self._stubs[spec.name] = stub
        n = instances if instances is not None else spec.directives.min_instances
        node_list = nodes or list(self.nodes)
        for i in range(n):
            self.provision_instance(spec.name, node_list[i % len(node_list)])
        return stub

    def apply_directives(self, agent_type: str, overrides: Dict[str, Any]) -> None:
        spec = self._specs[agent_type]
        spec.directives = spec.directives.merged(**overrides)
        # already-provisioned instances adopt the new directives too —
        # ``stub.init(...)`` runs at deployment time, after ``register_agent``
        # provisioned the min_instances floor
        for inst in self._instances.values():
            if inst.agent_type == agent_type:
                inst.directives = spec.directives

    def spec_of(self, agent_type: str) -> AgentSpec:
        return self._specs[agent_type]

    def stub(self, agent_type: str) -> Stub:
        return self._stubs[agent_type]

    def provision_instance(self, agent_type: str, node: str) -> Optional[str]:
        spec = self._specs[agent_type]
        live = self.live_instances(agent_type)
        if len(live) >= spec.directives.max_instances:
            return None
        if not self._reserve(node, spec.directives.resources):
            return None
        idx = self._instance_counter.get(agent_type, 0)
        self._instance_counter[agent_type] = idx + 1
        iid = f"{agent_type}:{node}/{idx}"
        inst = AgentInstance(agent_type, iid, node, spec.methods,
                             spec.directives)
        self._instances[iid] = inst
        self._controllers[iid] = ComponentController(self, inst)
        return iid

    def kill_instance(self, instance_id: str,
                      drain_to: Optional[str] = None,
                      hard: bool = False) -> None:
        """Stop an instance.

        Graceful (default): respects the ``min_instances`` floor and lets
        in-flight work finish (the policy-layer ``kill`` action).
        ``hard=True`` is the fault-injection API: the instance *dies* —
        no floor (real failures don't respect one), queued work re-routes,
        and in-flight futures fail with ``InstanceDied`` and travel the
        retry ladder.  Engine-backed instances additionally recover their
        resident sessions on surviving replicas by transcript replay
        (``on_replica_killed`` on the serving backend).
        """
        inst = self._instances.get(instance_id)
        if inst is None or not inst.alive:
            return
        spec = self._specs[inst.agent_type]
        if not hard:
            live = self.live_instances(inst.agent_type)
            if len(live) <= spec.directives.min_instances:
                return  # never go below the floor (Table 1 min_instances)
        ctrl = self._controllers[instance_id]
        ctrl.shutdown(drain_to=drain_to, hard=hard)
        if hard:
            backend = self.engine_backends.get(inst.agent_type)
            if backend is not None and hasattr(backend, "on_replica_killed"):
                backend.on_replica_killed(instance_id)
        self._release(inst.node_id, spec.directives.resources)

    def instance(self, instance_id: str) -> Optional[AgentInstance]:
        return self._instances.get(instance_id)

    def controller_of(self, instance_id: str) -> Optional[ComponentController]:
        return self._controllers.get(instance_id)

    def live_instances(self, agent_type: str) -> List[AgentInstance]:
        return [i for i in self._instances.values()
                if i.agent_type == agent_type and i.alive
                and i.instance_id not in self.blacklist]

    def instances_of_type(self, agent_type: str) -> List[str]:
        return [i.instance_id for i in self.live_instances(agent_type)]

    def node_of_instance(self, caller: str) -> str:
        inst = self._instances.get(caller)
        if inst is not None:
            return inst.node_id
        return next(iter(self.nodes))  # drivers live on the entry node

    # ------------------------------------------------------------ resources
    def _reserve(self, node: str, res: Dict[str, float]) -> bool:
        caps = self.nodes.get(node)
        if caps is None:
            return False
        used = self._used[node]
        for k, v in res.items():
            if used.get(k, 0.0) + v > caps.get(k, 0.0):
                return False
        for k, v in res.items():
            used[k] = used.get(k, 0.0) + v
        return True

    def _release(self, node: str, res: Dict[str, float]) -> None:
        used = self._used.get(node, {})
        for k, v in res.items():
            used[k] = max(0.0, used.get(k, 0.0) - v)

    def free_resources(self) -> Dict[str, Dict[str, float]]:
        return {n: {k: caps[k] - self._used[n].get(k, 0.0) for k in caps}
                for n, caps in self.nodes.items()}

    # --------------------------------------------------------------- network
    def net_latency(self, src_node: str, dst_node: str) -> float:
        return self._net_same if src_node == dst_node else self._net_cross

    def state_transfer_delay(self, src_node: str, dst_node: str,
                             nbytes: int) -> float:
        if src_node == dst_node:
            return self._net_same
        return self._net_cross + nbytes / self._state_bw

    # -------------------------------------------------------------- dispatch
    def add_future(self, fut: Future) -> None:
        """Register a newly created future; opportunistically retire resolved
        ones (and their node-store mirrors) once the table outgrows its
        threshold, keeping long-running deployments memory-flat."""
        self.futures.add(fut)
        if self.futures.needs_sweep():
            scrub: Dict[str, List[str]] = {}
            for f in self.futures.sweep():
                for node in f.meta.mirror_nodes:
                    scrub.setdefault(node, []).append(f"future:{f.fid}")
            for node, keys in scrub.items():
                self.stores.get(node).delete_many(keys)

    def dispatch(self, fut: Future) -> None:
        self.mirror_future(fut)
        inst = self.router.route(fut)
        if inst is None:
            fut.fail(RuntimeError(
                f"no live instance of agent {fut.meta.agent_type!r}"),
                self.kernel.now())
            # reachable mid-run since hard kills: parked dependents must
            # observe the failure or they stay parked forever
            self.push_ready(fut)
            return
        ctrl = self._controllers[inst.instance_id]
        src_node = self.node_of_instance(fut.meta.creator)
        delay = self.net_latency(src_node, inst.node_id)
        self.kernel.schedule(delay, lambda: ctrl.submit(fut), tag="dispatch")

    def register_consumer(self, fut: Future) -> None:
        """Driver/agent blocked on ``fut.value()`` — record consumership."""
        _, _, caller = get_context()
        if caller not in fut.meta.consumers:
            fut.meta.consumers.append(caller)
            self.mirror_future(fut)

    def register_dep_consumer(self, dep_fid: str,
                              ctrl: ComponentController) -> None:
        dep = self.futures.get(dep_fid)
        if dep is None:
            ctrl.on_dep_ready(dep_fid)
            return
        iid = ctrl.inst.instance_id
        if iid not in dep.meta.consumers:
            dep.meta.consumers.append(iid)
        if dep.available:
            # value already materialized: push immediately
            prod = self._instances.get(dep.meta.executor)
            src = prod.node_id if prod else ctrl.inst.node_id
            delay = self.net_latency(src, ctrl.inst.node_id)
            self.kernel.schedule(delay, lambda: ctrl.on_dep_ready(dep_fid))

    def mirror_future(self, fut: Future) -> None:
        """Write the metadata mirror into the executor/creator node store.

        The mirror is single-homed: re-homing (migration, escalated reroute)
        scrubs the copy from every previous node so exactly one store holds
        each future's metadata — the incremental ClusterView would otherwise
        have to arbitrate between divergent stale copies."""
        node = self.node_of_instance(fut.meta.executor or fut.meta.creator)
        for prev in fut.meta.mirror_nodes:
            if prev != node:
                self.stores.get(prev).delete(f"future:{fut.fid}")
        fut.meta.mirror_nodes = [node]
        self.stores.get(node).hset_many(f"future:{fut.fid}", {
            "state": fut.state.value,
            "agent_type": fut.meta.agent_type,
            "session": fut.meta.session_id,
            "executor": fut.meta.executor,
            "consumers": list(fut.meta.consumers),
            "dependencies": list(fut.meta.dependencies),
            "priority": fut.meta.priority,
            "created_at": fut.meta.created_at,
            "attempt": fut.meta.attempt,
        })

    def reprioritize_session(self, session_id: str) -> None:
        sess = self.sessions.get(session_id)
        if sess is None:
            return
        # by-session index: O(session's futures), not O(table)
        for fut in self.futures.futures_of_session(session_id):
            if not fut.available:
                fut.meta.priority = sess.priority_for(fut.meta.agent_type)

    # ------------------------------------------------------- fault handling
    def push_ready(self, fut: Future, src_node: Optional[str] = None) -> None:
        """Notify every consumer controller that ``fut`` resolved.

        Runtime-level counterpart of the producing controller's
        ``_push_consumers`` (which keeps a same-controller inline fast path);
        used by resolution paths that have no producing controller — a
        dispatch with no live instance, a RetryPolicy ``fail_future``, a
        cancellation of an unrouted future."""
        src = src_node or self.node_of_instance(fut.meta.executor
                                                or fut.meta.creator)
        for consumer in list(fut.meta.consumers):
            ctrl = self._controllers.get(consumer)
            if ctrl is not None:
                self.kernel.schedule(
                    self.net_latency(src, ctrl.inst.node_id),
                    lambda c=ctrl, f=fut.fid: c.on_dep_ready(f))

    def on_future_partial(self, fut: Future) -> None:
        """A streaming producer appended a chunk to ``fut``.

        Partial counterpart of :meth:`push_ready`: consumer controllers get
        a chance to unpark dependents whose ``stream_min_tokens`` hint is
        now satisfied, so inter-step pipelining starts before the producer
        resolves.  Fired per chunk — chunk counts are bounded by
        ``max_new_tokens``, and controllers ignore deps they aren't parked
        on, so the fan-out stays cheap."""
        if not fut.meta.consumers:
            return
        streamed = fut.streamed()
        src = self.node_of_instance(fut.meta.executor or fut.meta.creator)
        for consumer in list(fut.meta.consumers):
            ctrl = self._controllers.get(consumer)
            if ctrl is not None:
                self.kernel.schedule(
                    self.net_latency(src, ctrl.inst.node_id),
                    lambda c=ctrl, f=fut.fid, n=streamed:
                        c.on_dep_partial(f, n))

    def escalate(self, fut: Future, error: BaseException, src_instance: str,
                 reason: str) -> bool:
        """Rung 2 of the retry ladder: park the future (PENDING) for the
        global controller's RetryPolicy and nudge an off-cycle policy round.

        The nudge is a *non-periodic* kernel event, so under the SimKernel
        an escalation keeps virtual time alive until it is resolved — the
        periodic global tick alone would let the simulation quiesce with
        the future stranded.
        """
        if not fut.reset_for_retry(self.kernel.now()):
            return False        # already resolved (e.g. cancelled)
        fut.meta.escalations += 1
        with self._esc_lock:
            self.escalations[fut.fid] = EscalationRecord(
                fut=fut, error=error, src_instance=src_instance,
                reason=reason, at=self.kernel.now())
        self.mirror_future(fut)
        spec = self._specs.get(fut.meta.agent_type)
        delay = spec.directives.retry_backoff if spec is not None else 0.05
        self.kernel.schedule(delay, self.global_controller.handle_escalations,
                             tag=f"escalate:{fut.fid}")
        return True

    def pending_escalations(self) -> List[EscalationRecord]:
        with self._esc_lock:
            return list(self.escalations.values())

    def take_escalation(self, fid: str) -> Optional[EscalationRecord]:
        with self._esc_lock:
            return self.escalations.pop(fid, None)

    def apply_retry(self, fid: str, dst_instance: str) -> bool:
        """Enact a RetryPolicy ``retry_future`` decision: re-dispatch the
        escalated future on the chosen surviving replica."""
        rec = self.take_escalation(fid)
        if rec is None:
            return False
        fut = rec.fut
        if fut.state != FutureState.PENDING:
            return False        # cancelled while parked
        ctrl = self._controllers.get(dst_instance)
        if ctrl is None or not ctrl.inst.alive:
            self.dispatch(fut)  # destination vanished: let the router pick
            return True
        ctrl.inst.metrics.retries += 1
        sid = fut.meta.session_id
        spec = self._specs.get(fut.meta.agent_type)
        if sid and spec is not None and spec.directives.stateful:
            # the "sticky forever" pin points at the dead instance; re-home it
            self.router.pin(sid, fut.meta.agent_type, dst_instance)
        self.mirror_future(fut)
        ctrl.submit(fut)
        return True

    def fail_escalated(self, fid: str, reason: str = "") -> None:
        """Enact a RetryPolicy ``fail_future`` decision: the ladder is out of
        rungs — resolve the future with its original error."""
        rec = self.take_escalation(fid)
        if rec is None:
            return
        fut = rec.fut
        now = self.kernel.now()
        fut.fail(rec.error, now)
        self.telemetry.on_future_done(fut, None, now)
        # push readiness so parked dependents observe the failure
        self.push_ready(fut, src_node=self.node_of_instance(rec.src_instance))

    def blacklist_instance(self, instance_id: str) -> None:
        """Never route to ``instance_id`` again (dead/poisoned replica)."""
        self.blacklist.add(instance_id)

    # ------------------------------------------------------- hedged dispatch
    def hedge_candidates(self) -> List[Dict[str, Any]]:
        """In-flight *leaf* futures eligible for a hedged duplicate: running
        on a live instance, not already hedged.  The global controller feeds
        this into ``ClusterView.hedge_candidates`` each round; HedgePolicy
        compares ``elapsed`` against the pool's typical service time."""
        now = self.kernel.now()
        with self._hedge_lock:
            hedged = set(self._hedges)
        out: List[Dict[str, Any]] = []
        for iid, ctrl in list(self._controllers.items()):
            inst = ctrl.inst
            if not inst.alive:
                continue
            for f in list(inst.running):
                if (f.available or f.fid in hedged
                        or f.state != FutureState.RUNNING):
                    continue
                method = inst.methods.get(f.meta.method)
                if not isinstance(method, (EngineBackedMethod,
                                           EmulatedMethod)):
                    continue    # composite bodies cannot race (shared epoch)
                out.append(dict(fid=f.fid, instance=iid,
                                agent_type=inst.agent_type,
                                session=f.meta.session_id,
                                elapsed=now - f.meta.started_at))
        return out

    def apply_hedge(self, fid: str, dst_instance: str) -> bool:
        """Enact a HedgePolicy ``hedge_future`` decision: launch a duplicate
        of the straggling in-flight future on ``dst_instance``.

        The duplicate shares the original's run id — first completion wins
        through ``complete_async``'s terminal-state guard, and the loser's
        late result is dropped.  Only leaf methods (engine-backed or
        emulated) may race: a composite body would double-open the attempt's
        state epoch."""
        fut = self.futures.get(fid)
        if fut is None or fut.state != FutureState.RUNNING:
            return False
        src = fut.meta.executor
        if src == dst_instance:
            return False
        ctrl = self._controllers.get(dst_instance)
        if (ctrl is None or not ctrl.inst.alive
                or dst_instance in self.blacklist):
            return False
        method = ctrl.inst.methods.get(fut.meta.method)
        if not isinstance(method, (EngineBackedMethod, EmulatedMethod)):
            return False
        with self._hedge_lock:
            if fid in self._hedges:
                return False
            self._hedges[fid] = (src, dst_instance)
            self.hedges_issued += 1
        with ctrl._lock:
            ctrl.inst.running.append(fut)
        try:
            if isinstance(method, EngineBackedMethod):
                method.launch([fut], ctrl)
            else:
                ctrl._execute_emulated([fut], method)
        except BaseException:  # noqa: BLE001 — duplicate submit failed
            with self._hedge_lock:
                self._hedges.pop(fid, None)
            ctrl.detach_running(fut)
            return False
        ctrl._publish_metrics()
        return True

    def on_future_resolved(self, fut: Future) -> None:
        """Resolution hook: if ``fut`` was hedged, cancel/clean up the losing
        duplicate — detach it from both instances' running sets and abort the
        engine-side request so its slot and KV pages free up."""
        if not self._hedges:
            return
        with self._hedge_lock:
            rec = self._hedges.pop(fut.fid, None)
            self._hedge_claimed.discard(fut.fid)
        if rec is None:
            return
        src_iid, dst_iid = rec

        # deferred: we are inside a controller's completion path — touching
        # the sibling controller's bookkeeping here would re-enter its lock
        def cleanup() -> None:
            backend = self.engine_backends.get(fut.meta.agent_type)
            if backend is None:
                # emulated loser: its own completion event detaches it when
                # the service time elapses — the instance genuinely was busy
                # with the duplicate until then, so don't free it early
                return
            for iid in (src_iid, dst_iid):
                ctrl = self._controllers.get(iid)
                if ctrl is not None:
                    ctrl.detach_running(fut)
                if hasattr(backend, "cancel_inflight"):
                    backend.cancel_inflight(fut.fid, iid)

        self.kernel.schedule(0.0, cleanup, tag=f"hedge-cleanup:{fut.fid}")

    def claim_hedge_completion(self, fid: str) -> bool:
        """First-completion fence for hedged engine calls.  The winning
        bridge claims before extending the transcript / resolving the
        future; the simultaneous loser sees False and must stand down
        (drop its result entirely).  Unhedged futures always claim —
        the normal single-completion path is unaffected."""
        with self._hedge_lock:
            if fid not in self._hedges:
                return True
            if fid in self._hedge_claimed:
                return False
            self._hedge_claimed.add(fid)
            return True

    def cancel_future(self, fut: Future, reason: str = "cancelled") -> bool:
        """Cancel a future wherever it currently is — parked, queued, or in
        flight.  Queued work is dequeued; in-flight work keeps running but
        its completion is discarded (terminal-state + run-id guards).
        Returns False when the future is already resolved."""
        if fut.available:
            return False
        self.take_escalation(fut.fid)    # un-park if awaiting a retry ruling
        ctrl = self._controllers.get(fut.meta.executor)
        if ctrl is not None:
            return ctrl.cancel_local(fut, reason)
        if not fut.cancel(self.kernel.now(), reason):
            return False
        self.telemetry.on_future_done(fut, None, self.kernel.now())
        self.push_ready(fut)
        return True

    def cancel_session(self, session_id: str,
                       reason: str = "session cancelled") -> int:
        """Cancel every unresolved future of a session (user abandoned it).
        Returns the number of futures cancelled."""
        n = 0
        for fut in self.futures.futures_of_session(session_id):
            if not fut.available:
                n += bool(self.cancel_future(fut, reason))
        return n

    # ------------------------------------------------- managed-state support
    def migrate_session_state(self, session_id: str, agent_type: str,
                              dst_node: str) -> int:
        if not session_id:
            return 0
        return self.state_store.migrate_session(session_id, agent_type,
                                                dst_node)

    def mark_uses_managed_state(self, agent_type: str) -> None:
        spec = self._specs.get(agent_type)
        if spec is not None and not spec.directives.uses_managed_state:
            spec.directives.uses_managed_state = True
            spec.directives.validate()

    def enter_agent_context(self, fut: Future, inst: AgentInstance) -> None:
        prev = get_context() + (get_current_deadline(),)
        stack = getattr(self._agent_ctx, "stack", None)
        if stack is None:
            stack = []
            self._agent_ctx.stack = stack
        stack.append(prev)
        set_context(fut.meta.session_id, fut.meta.request_id,
                    inst.instance_id)
        # child calls made by this execution inherit the running future's
        # remaining deadline budget (stubs read it and take the min)
        set_current_deadline(fut.meta.deadline)
        # open the attempt's state epoch: managed-state writes made by this
        # execution are journaled under (fid, attempt) so a failed attempt
        # rolls back before any retry (exactly-once across retries)
        self.state_store.begin_epoch((fut.fid, fut.meta.attempt))

    def exit_agent_context(self) -> None:
        self.state_store.end_epoch_binding()
        stack = getattr(self._agent_ctx, "stack", None)
        if stack:
            sid, rid, caller, deadline = stack.pop()
            set_context(sid, rid, caller)
            set_current_deadline(deadline)
        else:
            clear_context()

    # --------------------------------------------------------------- drivers
    def submit_request(self, driver_fn: Callable[..., Any], *args,
                       session: Optional[str] = None, priority: float = 0.0,
                       delay: float = 0.0, deadline_s: Optional[float] = None,
                       on_done: Optional[Callable[[Any, Optional[BaseException]], None]] = None,
                       **kwargs) -> str:
        """Run a workflow driver as a request (optionally after ``delay``).

        ``deadline_s`` gives the whole request a budget: every future created
        by the driver (and transitively by agents it calls) inherits the
        remaining budget as an absolute deadline."""
        if session is None:
            session = self.sessions.new_session(self.kernel.now(),
                                                priority).session_id
        rid = self.sessions.new_request(session)

        def launch() -> None:
            self.telemetry.start_request(
                rid, session, self.kernel.now(),
                deadline_s=deadline_s if deadline_s is not None else -1.0)

            def body() -> None:
                set_context(session, rid, f"driver:{rid}")
                abs_deadline = -1.0
                if deadline_s is not None:
                    abs_deadline = self.kernel.now() + deadline_s
                    set_current_deadline(abs_deadline)
                err: Optional[BaseException] = None
                out: Any = None
                try:
                    out = driver_fn(*args, **kwargs)
                except BaseException as e:  # noqa: BLE001 — §5 fault reporting
                    err = e
                finally:
                    clear_context()
                    # the real deadline outcome: a stub call expired, or
                    # the driver finished after its budget ran out
                    missed = isinstance(err, DeadlineExceeded) or (
                        0 <= abs_deadline < self.kernel.now())
                    self.telemetry.end_request(rid, self.kernel.now(),
                                               failed=err is not None,
                                               deadline_exceeded=missed)
                if on_done is not None:
                    on_done(out, err)

            self.kernel.spawn_driver(body, name=f"request:{rid}")

        if delay > 0:
            self.kernel.schedule(delay, launch, tag="request-arrival")
        else:
            launch()
        return rid

    # ------------------------------------------------------------------- run
    def start(self) -> None:
        self.global_controller.start()

    def run(self, max_time: float = float("inf")) -> float:
        t = self.kernel.run(max_time=max_time)
        self.global_controller.stop()
        return t

    def add_shutdown_hook(self, fn: Callable[[], None]) -> None:
        """Run ``fn`` on shutdown (engine bridges stop their pump threads)."""
        self._shutdown_hooks.append(fn)

    def shutdown(self) -> None:
        self.global_controller.stop()
        for fn in reversed(self._shutdown_hooks):
            try:
                fn()
            except Exception:  # noqa: BLE001 — best-effort teardown
                pass
        self._shutdown_hooks.clear()
        if current_runtime() is self:
            _set_current(None)


class deployment:
    """Paper-style entry: ``deployment.main(driver, *args)`` builds a default
    runtime (if none is active), runs one request to completion, returns the
    result."""

    @staticmethod
    def main(driver_fn: Callable[..., Any], *args,
             runtime: Optional[NalarRuntime] = None, **kwargs) -> Any:
        rt = runtime or current_runtime()
        if rt is None:
            raise RuntimeError("no active NalarRuntime; construct one first")
        result: Dict[str, Any] = {}

        def done(out, err):
            result["out"], result["err"] = out, err

        rt.start()
        rt.submit_request(driver_fn, *args, on_done=done, **kwargs)
        rt.run()
        if result.get("err") is not None:
            raise result["err"]
        return result.get("out")
