"""Node-level store: the metadata/telemetry/decision broker (paper §4.1).

The prototype in the paper uses Redis per node.  This reproduction provides an
in-process store with the same API surface (hashes, atomic check-and-set,
pub/sub) so the two control levels never synchronise directly: component
controllers push metrics and local observations; the global controller writes
policy updates; consumers poll or subscribe asynchronously.

The store is deliberately *not* aware of futures or agents — it moves opaque
dicts, exactly like the Redis deployment would.
"""

from __future__ import annotations

import threading
from collections import defaultdict
from typing import Any, Callable, Dict, List, Optional


class NodeStore:
    """One per node.  Thread-safe; all operations O(1)/O(fields)."""

    def __init__(self, node_id: str) -> None:
        self.node_id = node_id
        self._lock = threading.RLock()
        self._hashes: Dict[str, Dict[str, Any]] = defaultdict(dict)
        self._subs: Dict[str, List[Callable[[str, Any], None]]] = defaultdict(list)
        # monotonically increasing version per key, for cheap change detection
        self._versions: Dict[str, int] = defaultdict(int)

    # ---------------------------------------------------------------- hashes
    def hset(self, key: str, field: str, value: Any) -> None:
        with self._lock:
            self._hashes[key][field] = value
            self._versions[key] += 1
            subs = list(self._subs.get(key, ()))
        for fn in subs:
            fn(field, value)

    def hset_many(self, key: str, mapping: Dict[str, Any]) -> None:
        with self._lock:
            self._hashes[key].update(mapping)
            self._versions[key] += 1
            subs = list(self._subs.get(key, ()))
        for fn in subs:
            for f, v in mapping.items():
                fn(f, v)

    def hget(self, key: str, field: str, default: Any = None) -> Any:
        with self._lock:
            return self._hashes.get(key, {}).get(field, default)

    def hgetall(self, key: str) -> Dict[str, Any]:
        with self._lock:
            return dict(self._hashes.get(key, {}))

    def hdel(self, key: str, field: str) -> bool:
        with self._lock:
            h = self._hashes.get(key)
            if h and field in h:
                del h[field]
                self._versions[key] += 1
                return True
            return False

    def delete(self, key: str) -> None:
        with self._lock:
            self._hashes.pop(key, None)
            self._versions[key] += 1

    def keys(self, prefix: str = "") -> List[str]:
        with self._lock:
            return [k for k in self._hashes if k.startswith(prefix)]

    def version(self, key: str) -> int:
        with self._lock:
            return self._versions.get(key, 0)

    # --------------------------------------------------- atomic check-and-set
    def cas(self, key: str, field: str, expect: Any, value: Any) -> bool:
        """Atomically set ``field`` to ``value`` iff it currently == expect."""
        with self._lock:
            cur = self._hashes.get(key, {}).get(field)
            if cur != expect:
                return False
            self._hashes[key][field] = value
            self._versions[key] += 1
            return True

    def incr(self, key: str, field: str, amount: float = 1) -> float:
        with self._lock:
            cur = self._hashes[key].get(field, 0)
            new = cur + amount
            self._hashes[key][field] = new
            self._versions[key] += 1
            return new

    # ---------------------------------------------------------------- pubsub
    def subscribe(self, key: str, fn: Callable[[str, Any], None]) -> None:
        with self._lock:
            self._subs[key].append(fn)

    def unsubscribe(self, key: str, fn: Callable[[str, Any], None]) -> None:
        with self._lock:
            if fn in self._subs.get(key, []):
                self._subs[key].remove(fn)


class StoreCluster:
    """Directory of per-node stores.

    In the real deployment each node's store is a local Redis and the global
    controller reaches them over the network; here the directory hands out
    references.  ``fetch_latency`` lets benchmarks model the network RTT the
    paper measures in Fig. 10 ("collecting state for 1,024 futures from 64
    nodes takes 76 ms").
    """

    def __init__(self) -> None:
        self._stores: Dict[str, NodeStore] = {}
        self._lock = threading.Lock()

    def get(self, node_id: str) -> NodeStore:
        with self._lock:
            if node_id not in self._stores:
                self._stores[node_id] = NodeStore(node_id)
            return self._stores[node_id]

    def nodes(self) -> List[str]:
        with self._lock:
            return list(self._stores)

    def all_stores(self) -> List[NodeStore]:
        with self._lock:
            return list(self._stores.values())
