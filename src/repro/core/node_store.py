"""Node-level store: the metadata/telemetry/decision broker (paper §4.1).

The prototype in the paper uses Redis per node.  This reproduction provides an
in-process store with the same API surface (hashes, atomic check-and-set,
pub/sub) so the two control levels never synchronise directly: component
controllers push metrics and local observations; the global controller writes
policy updates; consumers poll or subscribe asynchronously.

The store is deliberately *not* aware of futures or agents — it moves opaque
dicts, exactly like the Redis deployment would.

Change tracking: every mutation advances a store-wide sequence number, and a
per-prefix *delta index* answers "which keys under this prefix moved since
cursor C" in O(changed) — the primitive the global controller's incremental
view collection is built on (Fig. 10 at the 131K-future scale).  The index is
single-consumer per prefix: calling ``scan_changed(prefix, c)`` acknowledges
every delta at or below ``c``, letting the index compact itself down to the
churn between consecutive scans.
"""

from __future__ import annotations

import threading
from collections import defaultdict
from typing import Any, Callable, Dict, List, Tuple


class _PrefixIndex:
    """Delta index for one key prefix: live key set + pending change/delete
    journals keyed by store sequence number.  All access under the store lock."""

    __slots__ = ("prefix", "live", "changed", "deleted")

    def __init__(self, prefix: str, live_keys: List[str], seq: int) -> None:
        self.prefix = prefix
        self.live = set(live_keys)
        # key -> seq of its latest unacknowledged change (coalesced: N writes
        # to one key between scans cost one journal entry)
        self.changed: Dict[str, int] = {k: seq for k in live_keys}
        self.deleted: Dict[str, int] = {}

    def touch(self, key: str, seq: int) -> None:
        self.live.add(key)
        self.changed[key] = seq
        self.deleted.pop(key, None)

    def drop(self, key: str, seq: int) -> None:
        if key in self.live:
            self.live.discard(key)
            self.changed.pop(key, None)
            self.deleted[key] = seq


class NodeStore:
    """One per node.  Thread-safe; all operations O(1)/O(fields)."""

    def __init__(self, node_id: str) -> None:
        self.node_id = node_id
        self._lock = threading.RLock()
        self._hashes: Dict[str, Dict[str, Any]] = defaultdict(dict)
        self._subs: Dict[str, List[Callable[[str, Any], None]]] = defaultdict(list)
        # monotonically increasing version per key, for cheap change detection
        self._versions: Dict[str, int] = defaultdict(int)
        # store-wide mutation sequence (delta-scan cursor space) and the
        # registered per-prefix delta indexes
        self._seq = 0
        self._indexes: Dict[str, _PrefixIndex] = {}
        # mutating calls served (benchmarks derive pushes-per-round from this)
        self.write_ops = 0

    # -------------------------------------------------------- change tracking
    def _touch_locked(self, key: str) -> None:
        """Record a write to ``key``.  Caller holds the lock."""
        self._seq += 1
        self._versions[key] += 1
        self.write_ops += 1
        for idx in self._indexes.values():
            if key.startswith(idx.prefix):
                idx.touch(key, self._seq)

    def _drop_locked(self, key: str) -> None:
        """Record the deletion of ``key``.  Caller holds the lock."""
        self._seq += 1
        self._versions[key] += 1
        self.write_ops += 1
        for idx in self._indexes.values():
            if key.startswith(idx.prefix):
                idx.drop(key, self._seq)

    def _ensure_index_locked(self, prefix: str) -> _PrefixIndex:
        idx = self._indexes.get(prefix)
        if idx is None:
            # one-time O(total keys) seeding; every key reads as changed at
            # the current sequence so a cursor-0 scan returns the full set
            live = [k for k in self._hashes if k.startswith(prefix)]
            idx = _PrefixIndex(prefix, live, self._seq)
            self._indexes[prefix] = idx
        return idx

    def cursor(self) -> int:
        """Current change-sequence high-water mark.  A consumer that just
        rebuilt its state from a full ``keys()`` scan should resume delta
        scanning from here."""
        with self._lock:
            return self._seq

    def scan_changed(self, prefix: str,
                     since_cursor: int = 0) -> Tuple[List[str], List[str], int]:
        """Delta scan: ``(changed_keys, deleted_keys, new_cursor)`` for every
        key under ``prefix`` that moved after ``since_cursor``.

        O(churn since the previous scan), not O(keys under the prefix): the
        journal coalesces repeated writes per key, and every scan *drains*
        it — entries above the cursor are returned, entries at or below it
        are acknowledged, and both are compacted away, so the next scan pays
        only for what moved in between (a full-rebuild consumer resets the
        journal just by scanning and discarding).  Single consumer per
        prefix — the global controller owns these cursors; side readers must
        use ``keys()``/``hgetall_many`` instead.
        """
        with self._lock:
            idx = self._ensure_index_locked(prefix)
            changed = [k for k, s in idx.changed.items() if s > since_cursor]
            deleted = [k for k, s in idx.deleted.items() if s > since_cursor]
            idx.changed.clear()
            idx.deleted.clear()
            return changed, deleted, self._seq

    # ---------------------------------------------------------------- hashes
    def hset(self, key: str, field: str, value: Any) -> None:
        with self._lock:
            self._hashes[key][field] = value
            self._touch_locked(key)
            subs = list(self._subs.get(key, ()))
        for fn in subs:
            fn(field, value)

    def hset_many(self, key: str, mapping: Dict[str, Any]) -> None:
        with self._lock:
            self._hashes[key].update(mapping)
            self._touch_locked(key)
            subs = list(self._subs.get(key, ()))
        for fn in subs:
            for f, v in mapping.items():
                fn(f, v)

    def hget(self, key: str, field: str, default: Any = None) -> Any:
        with self._lock:
            return self._hashes.get(key, {}).get(field, default)

    def hgetall(self, key: str) -> Dict[str, Any]:
        with self._lock:
            return dict(self._hashes.get(key, {}))

    def hgetall_many(self, keys: List[str],
                     chunk: int = 2048) -> Dict[str, Dict[str, Any]]:
        """Batched ``hgetall``: one lock acquisition per ``chunk`` keys
        instead of one per key (the collect path reads thousands of mirrors
        per round).  Missing keys are omitted from the result."""
        out: Dict[str, Dict[str, Any]] = {}
        for i in range(0, len(keys), chunk):
            with self._lock:
                for k in keys[i:i + chunk]:
                    h = self._hashes.get(k)
                    if h is not None:
                        out[k] = dict(h)
        return out

    def hdel(self, key: str, field: str) -> bool:
        with self._lock:
            h = self._hashes.get(key)
            if h and field in h:
                del h[field]
                self._touch_locked(key)
                return True
            return False

    def delete(self, key: str) -> None:
        with self._lock:
            self._hashes.pop(key, None)
            self._drop_locked(key)

    def delete_many(self, keys: List[str], chunk: int = 2048) -> None:
        """Batched ``delete`` (future-table GC scrubs mirrors in cohorts)."""
        for i in range(0, len(keys), chunk):
            with self._lock:
                for k in keys[i:i + chunk]:
                    self._hashes.pop(k, None)
                    self._drop_locked(k)

    def keys(self, prefix: str = "") -> List[str]:
        """All keys under ``prefix``.  Backed by the delta index when one is
        registered (O(matching)); otherwise the key set is snapshotted under
        the lock and filtered outside it, so concurrent writers never wait on
        a full-map sweep."""
        with self._lock:
            idx = self._indexes.get(prefix)
            if idx is not None:
                return list(idx.live)
            snapshot = list(self._hashes)
        if not prefix:
            return snapshot
        return [k for k in snapshot if k.startswith(prefix)]

    def version(self, key: str) -> int:
        with self._lock:
            return self._versions.get(key, 0)

    # --------------------------------------------------- atomic check-and-set
    def cas(self, key: str, field: str, expect: Any, value: Any) -> bool:
        """Atomically set ``field`` to ``value`` iff it currently == expect."""
        with self._lock:
            cur = self._hashes.get(key, {}).get(field)
            if cur != expect:
                return False
            self._hashes[key][field] = value
            self._touch_locked(key)
            return True

    def incr(self, key: str, field: str, amount: float = 1) -> float:
        with self._lock:
            cur = self._hashes[key].get(field, 0)
            new = cur + amount
            self._hashes[key][field] = new
            self._touch_locked(key)
            return new

    # ---------------------------------------------------------------- pubsub
    def subscribe(self, key: str, fn: Callable[[str, Any], None]) -> None:
        with self._lock:
            self._subs[key].append(fn)

    def unsubscribe(self, key: str, fn: Callable[[str, Any], None]) -> None:
        with self._lock:
            if fn in self._subs.get(key, []):
                self._subs[key].remove(fn)


class StoreCluster:
    """Directory of per-node stores.

    In the real deployment each node's store is a local Redis and the global
    controller reaches them over the network; here the directory hands out
    references.  ``fetch_latency`` lets benchmarks model the network RTT the
    paper measures in Fig. 10 ("collecting state for 1,024 futures from 64
    nodes takes 76 ms").
    """

    def __init__(self) -> None:
        self._stores: Dict[str, NodeStore] = {}
        self._lock = threading.Lock()

    def get(self, node_id: str) -> NodeStore:
        with self._lock:
            if node_id not in self._stores:
                self._stores[node_id] = NodeStore(node_id)
            return self._stores[node_id]

    def nodes(self) -> List[str]:
        with self._lock:
            return list(self._stores)

    def all_stores(self) -> List[NodeStore]:
        with self._lock:
            return list(self._stores.values())
