"""Introspective debugging (paper §5).

NALAR has complete visibility into inter-agent calls, so it can render a
request's workflow path — time in each stage, the agent/instance touched,
queue vs service split — and report failures with the full path.  This is
the text form of the visualization tool the paper describes.
"""

from __future__ import annotations

from typing import List, Optional

from .telemetry import RequestRecord, Telemetry


def format_trace(rec: RequestRecord, width: int = 48) -> str:
    """Render one request's workflow path as a timeline."""
    lines = [f"request {rec.request_id} (session {rec.session_id}) — "
             f"{'FAILED' if rec.failed else 'ok'} "
             f"latency={rec.latency:.3f}s"]
    if not rec.stages:
        return lines[0] + "\n  (no stages recorded)"
    t0 = rec.submitted_at
    t1 = max(rec.finished_at, max(s.ready_at for s in rec.stages))
    span = max(t1 - t0, 1e-9)
    for s in sorted(rec.stages, key=lambda s: s.created_at):
        lo = int((s.created_at - t0) / span * width)
        mid = int((max(s.started_at, s.created_at) - t0) / span * width)
        hi = int((s.ready_at - t0) / span * width)
        bar = (" " * lo + "." * max(mid - lo, 0)
               + "#" * max(hi - mid, 1))[:width].ljust(width)
        mark = " "
        if s.failed:
            mark = "!"
        elif getattr(s, "cancelled", False):
            mark = "x"
        retry = (f" retry#{s.attempt}"
                 if getattr(s, "attempt", 0) > 0 else "")
        lines.append(
            f" {mark}[{bar}] {s.agent_type}.{s.method} @ {s.executor} "
            f"queue={s.queue_time:.3f}s service={s.service_time:.3f}s"
            f"{retry}")
    return "\n".join(lines)


def slowest_stage(rec: RequestRecord):
    if not rec.stages:
        return None
    return max(rec.stages, key=lambda s: s.service_time + s.queue_time)


def session_report(telemetry: Telemetry, session_id: str) -> str:
    """Per-session log: every request, stage counts, agents touched."""
    recs = [r for r in telemetry.requests.values()
            if r.session_id == session_id]
    if not recs:
        return f"session {session_id}: no requests"
    lines = [f"session {session_id}: {len(recs)} requests"]
    for r in sorted(recs, key=lambda r: r.submitted_at):
        agents = sorted({s.agent_type for s in r.stages})
        nodes = sorted({s.executor.split(":")[-1].split("/")[0]
                        for s in r.stages if s.executor})
        lines.append(f"  {r.request_id}: latency={r.latency:.3f}s "
                     f"stages={len(r.stages)} agents={','.join(agents)} "
                     f"nodes={','.join(nodes)}"
                     + (" FAILED" if r.failed else ""))
    return "\n".join(lines)


def failure_report(telemetry: Telemetry) -> List[str]:
    """All failed requests with the agent where the failure occurred."""
    out = []
    for r in telemetry.requests.values():
        if not r.failed:
            continue
        failed_stages = [s for s in r.stages if s.failed]
        where = (f"{failed_stages[-1].agent_type} @ "
                 f"{failed_stages[-1].executor}" if failed_stages
                 else "driver")
        out.append(f"{r.request_id} (session {r.session_id}) failed at "
                   f"{where} after {r.latency:.3f}s; path: "
                   + " -> ".join(f"{s.agent_type}.{s.method}"
                                 for s in sorted(r.stages,
                                                 key=lambda s: s.created_at)))
    return out
