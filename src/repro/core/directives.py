"""Runtime directive (hint) interface — paper §3.4, Table 1.

Directives let developers declare execution properties the runtime exploits:
batching, statefulness, preemptability, instance bounds, resource demands.

Constraint from §5 (Discussion): managed state cannot be combined with
batchable agents — batching aggregates requests across sessions, making state
attribution impossible.  ``validate()`` enforces it.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional


@dataclass
class Directives:
    # True: successive calls of one session route to the same instance, and
    # sessions are never migrated (§5: stricter than managed-state sessions,
    # which may migrate *with* their state).
    stateful: bool = False
    # True: the module accepts a batch of requests.
    batchable: bool = False
    max_batch: int = 8
    # Name of a preemption hook; None means not preemptable.
    preemptable: Optional[Callable] = None
    max_instances: int = 8
    min_instances: int = 1
    # {"GPU": n, "CPU": n, "MEM": gb} per instance.
    resources: Dict[str, float] = field(default_factory=dict)
    # Does this agent keep managed (session) state?  Set automatically when the
    # agent code touches managedList/managedDict; may also be declared.
    uses_managed_state: bool = False
    # ---- failure handling (the retry ladder) --------------------------------
    # Max *local* retries per future: the component controller re-executes a
    # failed attempt in place (state epoch rolled back first) with exponential
    # backoff.  After the budget is exhausted — or immediately when the
    # instance died — the failure escalates to the global controller's
    # RetryPolicy, which reroutes to a surviving replica.  0 = fail fast.
    # A per-call ``_hint={"retry": n}`` overrides this budget.
    max_retries: int = 0
    # Which errors are worth retrying: bool, or a predicate over the raised
    # exception.  Cancellations are never retried regardless.
    retryable: Any = True
    # Base backoff in (virtual) seconds; attempt k waits backoff * 2^k.
    retry_backoff: float = 0.05
    # ---- latency-fault handling ---------------------------------------------
    # Per-call deadline budget in (kernel) seconds: every future of this agent
    # gets an absolute deadline of create-time + deadline_s, and child calls
    # inherit the parent's *remaining* budget (the effective deadline is the
    # min of the inherited one and this budget).  Expired futures fail with a
    # non-retryable DeadlineExceeded.  A per-call ``_hint={"deadline_s": x}``
    # overrides this budget.  None = no deadline.
    deadline_s: Optional[float] = None

    def validate(self) -> None:
        if self.batchable and self.uses_managed_state:
            raise ValueError(
                "directive conflict: managed state cannot be combined with "
                "batchable agents (paper §5) — batching mixes sessions, making "
                "state attribution impossible")
        if self.min_instances > self.max_instances:
            raise ValueError("min_instances > max_instances")
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.retry_backoff < 0:
            raise ValueError("retry_backoff must be >= 0")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError("deadline_s must be > 0 when set")

    def merged(self, **overrides) -> "Directives":
        d = Directives(**{**self.__dict__, **overrides})
        d.validate()
        return d
